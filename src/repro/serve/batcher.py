"""Request batching for the serving loop.

``RequestBatcher`` accumulates live requests (hashed-token feature maps +
a requested lambda each) and drains them as one :class:`PackedBatch` per
scoring dispatch. Two shape-bounding rules keep the compiled-program count
small over a serving process's lifetime:

* the batch extent is quantized to power-of-two capacity classes
  (:func:`batch_capacity`) up to ``max_batch``, mirroring the slab-K
  classes of :func:`~repro.serve.ingest.k_capacity`;
* hashing/encoding happens at ``submit`` time (spreading the host work
  across arrivals), packing at ``drain`` time (one vectorized pass).

Lambdas stay raw floats until scoring: ``PathScorer`` resolves them
against the snapshot it scores with, so a hot-swap that re-grids the path
re-resolves naturally instead of serving stale indices.
"""
from __future__ import annotations

import threading
from typing import List, Tuple

import numpy as np

from repro.serve.ingest import PackedBatch, Request, encode_request, \
    pack_requests


def batch_capacity(b: int, *, b_min: int = 8, b_max: int = 4096) -> int:
    """Power-of-two batch capacity class covering ``b`` rows (clamped to
    ``[b_min, b_max]``) — bounds the distinct scoring-program batch shapes
    to O(log max_batch)."""
    cap = max(b_min, 1)
    while cap < min(b, b_max):
        cap *= 2
    return cap


class RequestBatcher:
    """Thread-safe accumulate/drain bridge between request arrival and the
    batched scoring dispatch.

    ``dp``/``pad_p_to`` fix the packed slab geometry (pass the serving
    store's mesh data extent and ``store.pad_p_to``; the defaults are the
    local single-device geometry). ``max_batch`` caps one drain — leftover
    requests stay queued for the next.
    """

    def __init__(self, p: int, *, max_batch: int = 256, dp: int = 1,
                 pad_p_to: int = 1, k_min: int = 8):
        self.p = p
        self.max_batch = max_batch
        self.dp = dp
        self.pad_p_to = pad_p_to
        self.k_min = k_min
        self._lock = threading.Lock()
        self._pending: List[Tuple[Tuple[np.ndarray, np.ndarray], float]] = []

    def submit(self, request: Request, lam: float) -> None:
        """Enqueue one request (hashed + encoded immediately)."""
        enc = encode_request(request, self.p)
        with self._lock:
            self._pending.append((enc, float(lam)))

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    def drain(self) -> Tuple[PackedBatch, np.ndarray]:
        """Pack up to ``max_batch`` queued requests into one batch.

        Returns ``(batch, lams)``; ``lams[i]`` belongs to batch row ``i``.
        An empty queue drains to an all-padding batch (``n_live == 0``).
        """
        with self._lock:
            take, self._pending = (self._pending[:self.max_batch],
                                   self._pending[self.max_batch:])
        encoded = [enc for enc, _ in take]
        lams = np.asarray([lam for _, lam in take], np.float64)
        cap = batch_capacity(max(len(encoded), 1), b_max=self.max_batch)
        cap += (-cap) % max(self.dp, 1)
        batch = pack_requests(encoded, self.p, batch_cap=cap, dp=self.dp,
                              pad_p_to=self.pad_p_to, k_min=self.k_min)
        return batch, lams
