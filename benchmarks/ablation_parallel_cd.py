"""Ablation: WHY the paper's design (sequential CD within blocks +
block-diagonal Hessian across blocks + global line search) beats naive
fully-parallel coordinate updates (Shotgun-style Jacobi, Bradley et al.
2011 — the conflict problem the paper cites in §1).

Reports iterations-to-tolerance and final objective gap vs the oracle for
cyclic-within-block vs Jacobi updates, across block counts M and feature
correlation levels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core import DGLMNETOptions, fit, lambda_max, margins, objective


def correlated_dataset(key, n, p, rho):
    """Equicorrelated-ish features: x = sqrt(1-rho)*z + sqrt(rho)*shared."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    z = jax.random.normal(k1, (n, p))
    shared = jax.random.normal(k2, (n, 1))
    X = jnp.sqrt(1 - rho) * z + jnp.sqrt(rho) * shared
    beta_true = jnp.where(jax.random.uniform(k3, (p,)) < 0.1,
                          jax.random.normal(k4, (p,)) * 3.0, 0.0)
    y = jnp.where(jax.random.uniform(jax.random.fold_in(k4, 1), (n,))
                  < jax.nn.sigmoid(X @ beta_true), 1.0, -1.0)
    return X, y


def run():
    key = jax.random.key(42)
    n, p = 4096, 256
    print("# rho,method,M,iters,converged,final_gap")
    for rho in (0.0, 0.5, 0.9):
        X, y = correlated_dataset(jax.random.fold_in(key, int(rho * 10)), n, p, rho)
        lam = float(lambda_max(X, y)) / 32
        # reference optimum via well-converged cyclic run
        ref = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=1, method="gram",
                                                 tile=64, max_iters=200,
                                                 rel_tol=1e-10))
        for method in ("gram", "jacobi"):
            for m in (1, 16, 64):
                with Timer() as t:
                    res = fit(X, y, lam,
                              opts=DGLMNETOptions(num_blocks=m, method=method,
                                                  tile=64, max_iters=150))
                gap = (res.f - ref.f) / abs(ref.f)
                print(f"# {rho},{method},{m},{res.n_iters},{res.converged},{gap:.2e}")
                emit(f"ablation.rho{rho}.{method}.M{m}",
                     t.dt * 1e6 / max(res.n_iters, 1),
                     f"iters={res.n_iters};gap={gap:.1e}")


if __name__ == "__main__":
    run()
