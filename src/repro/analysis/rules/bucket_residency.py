"""bucket-residency: slab device placement outside its single home.

Slab-bucket device memory is budgeted by exactly ONE module —
``repro.data.residency``. The :class:`BucketResidencyManager` owns the
padded work buckets (LRU under ``device_budget_bytes``, streamed
host->device prefetch, hit/miss/bytes-moved counters), and transient
slab placements (restricted-solve operands, serve request slabs) go
through its ``put_slab`` door. A raw ``jax.device_put`` of slab arrays
anywhere else is invisible to the budget: it can silently blow past the
HBM ceiling a streamed solve was configured for, and it bypasses the
lost-bucket retry/injection path. Same single-home shape as the
``sharded-concat`` rule.

The heuristic is name-based (this is a lint, not a type system): a
``jax.device_put`` whose first argument's trailing identifier looks like
a slab operand — ``row_idx``/``values``/``rows``/``vals``/``r_b``/
``v_b``/anything containing ``slab`` — is a finding in any mesh-aware
module outside the home. Non-slab placements (betas, margins, labels)
keep their names and stay exempt; a false positive documents itself with
an ``allow[bucket-residency]: reason`` pragma.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from repro.analysis.context import Project
from repro.analysis.findings import Finding

RULE_ID = "bucket-residency"
DOC = ("jax.device_put of slab arrays outside data/residency.py — route "
       "through BucketResidencyManager / put_slab (single home of the "
       "slab device-memory budget)")

#: the one module allowed to device_put slab buckets
_HOME = "data/residency.py"

_SLAB_NAMES = {
    "row_idx", "values", "rows", "vals",
    "r_b", "v_b", "rows_sub", "vals_sub",
}


def _trailing_name(node: ast.AST) -> Optional[str]:
    """The last identifier of the argument expression: ``row_idx`` for
    both ``row_idx`` and ``batch.row_idx``; None for call results etc."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_slabby(name: Optional[str]) -> bool:
    if name is None:
        return False
    return name in _SLAB_NAMES or "slab" in name or "row_idx" in name


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if mod.path.endswith(_HOME) or not mod.mesh_context:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            if mod.qualname(node.func) != "jax.device_put":
                continue
            name = _trailing_name(node.args[0])
            if _is_slabby(name):
                out.append(Finding(
                    file=mod.path, line=node.lineno, rule=RULE_ID,
                    message=(
                        f"jax.device_put({name}, ...) places slab arrays "
                        f"outside the residency budget — use "
                        f"repro.data.residency.put_slab (or the "
                        f"BucketResidencyManager for work buckets; or "
                        f"allow[{RULE_ID}] with why this is not slab data)"),
                ))
    return out
