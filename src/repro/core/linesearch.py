"""Line search (paper Algorithm 3).

All evaluations are O(n + p) from the cached margins m = X@beta and the
all-reduced dm = X@dbeta — never a pass over X:

    f(beta + a*dbeta) = sum_i softplus(-y (m + a dm)) + lam ||beta + a dbeta||_1

Steps:
 1. If a = 1 already satisfies the Armijo sufficient-decrease test, take it
    (sparsity safeguard: dbeta_j = -beta_j zeros survive).
 2. a_init = argmin_{delta<=a<=1} f(beta + a dbeta)  (golden-section).
 3. Armijo backtracking from a_init:  f(a) <= f(0) + a*sigma*D with
    D = grad(L)^T dbeta + gamma dbeta^T H dbeta + lam(||beta+dbeta||_1 - ||beta||_1).
Paper constants: b = 0.5, sigma = 0.01, gamma = 0.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.objective import l1_norm, neg_log_likelihood

GOLD = 0.6180339887498949

# Backtracking budget (paper's b = 0.5 halving): exhausting it without an
# accepted step is the engine's LINESEARCH_STALLED trip-wire, so the
# constant is shared rather than duplicated at the guard site.
MAX_BACKTRACKS = 30


class LineSearchResult(NamedTuple):
    alpha: jnp.ndarray
    f_new: jnp.ndarray
    took_unit_step: jnp.ndarray       # bool: step-1 short-circuit hit
    backtracks: jnp.ndarray


def f_alpha(alpha, m, dm, y, beta, dbeta, lam):
    return neg_log_likelihood(m + alpha * dm, y) + lam * l1_norm(beta + alpha * dbeta)


def armijo_D(grad_dot_dbeta, quad_term, beta, dbeta, lam, gamma=0.0):
    """D = grad(L)^T dbeta + gamma*dbeta^T H dbeta + lam(|beta+dbeta| - |beta|)."""
    return (
        grad_dot_dbeta
        + gamma * quad_term
        + lam * (l1_norm(beta + dbeta) - l1_norm(beta))
    )


def golden_section(fun, lo, hi, iters: int = 24):
    """Minimize a unimodal scalar function on [lo, hi] (fixed iterations)."""

    def body(_, state):
        a, b, c, d, fc, fd = state
        shrink = fc < fd
        b_new = jnp.where(shrink, d, b)
        a_new = jnp.where(shrink, a, c)
        c_new = b_new - GOLD * (b_new - a_new)
        d_new = a_new + GOLD * (b_new - a_new)
        fc_new = fun(c_new)
        fd_new = fun(d_new)
        return a_new, b_new, c_new, d_new, fc_new, fd_new

    c0 = hi - GOLD * (hi - lo)
    d0 = lo + GOLD * (hi - lo)
    state = (lo, hi, c0, d0, fun(c0), fun(d0))
    a, b, *_ = jax.lax.fori_loop(0, iters, body, state)
    return 0.5 * (a + b)


@partial(jax.jit, static_argnames=("max_backtracks", "b", "sigma", "gamma", "delta"))
def line_search(
    m,                 # (n,) margins X@beta
    dm,                # (n,) X@dbeta (all-reduced across feature blocks)
    y,                 # (n,)
    beta,              # (p,)
    dbeta,             # (p,)
    lam,
    grad_dot_dbeta,    # scalar: grad L(beta)^T dbeta
    quad_term=0.0,     # scalar: dbeta^T H~ dbeta (gamma=0 -> unused)
    *,
    f0=None,           # precomputed f(alpha=0) (the engine's fused-stats
                       # pass already holds NLL(m)); None -> evaluate here
    max_backtracks: int = MAX_BACKTRACKS,
    b: float = 0.5,
    sigma: float = 0.01,
    gamma: float = 0.0,
    delta: float = 1e-3,
) -> LineSearchResult:
    if f0 is None:
        f0 = f_alpha(0.0, m, dm, y, beta, dbeta, lam)
    D = armijo_D(grad_dot_dbeta, quad_term, beta, dbeta, lam, gamma)
    f1 = f_alpha(1.0, m, dm, y, beta, dbeta, lam)

    # Step 1: unit step if it already gives sufficient decrease
    unit_ok = f1 <= f0 + sigma * D

    def take_unit(_):
        return LineSearchResult(jnp.float32(1.0), f1, jnp.bool_(True), jnp.int32(0))

    def search(_):
        # Step 2: alpha_init = argmin on [delta, 1]
        fun = lambda a: f_alpha(a, m, dm, y, beta, dbeta, lam)
        a_init = golden_section(fun, jnp.float32(delta), jnp.float32(1.0))

        # Step 3: Armijo backtracking a_init * b^j
        def cond(state):
            a, fa, k = state
            return jnp.logical_and(fa > f0 + a * sigma * D, k < max_backtracks)

        def body(state):
            a, _, k = state
            a_new = a * b
            return a_new, fun(a_new), k + 1

        a0 = a_init
        state = (a0, fun(a0), jnp.int32(0))
        a, fa, k = jax.lax.while_loop(cond, body, state)
        return LineSearchResult(a, fa, jnp.bool_(False), k)

    return jax.lax.cond(unit_ok, take_unit, search, operand=None)
