"""End-to-end driver (the paper's kind): distributed d-GLMNET vs distributed
online learning via truncated gradient, full regularization path, on a mesh
of 8 simulated devices (2 data x 4 model). The same code lowers on the
production 16x16 mesh (see repro/launch/dryrun.py).

Everything runs through the one front door: ``repro.api.LogisticL1`` over
``ShardedDesign``-wrapped layouts. Each distributed solve is one jitted
while_loop on the mesh (core/engine.py) — no per-iteration host sync. The
closing sections run the *distributed screened path* (strong rule + KKT
post-check around mesh restricted solves): the active-set gather reshards
the feature axis into a capacity-bucketed P(model) layout, and in the
sparse flavor the screen streams by-feature (row_idx, values) slabs so no
dense (n, p) X ever exists — the paper's webspam regime — while per-lambda
AUPRC streams from the mesh through a sharded *test* design
(``make_design_eval``) instead of a replicated test matrix.

    python examples/regpath_distributed.py      # sets XLA flags itself
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.api import (  # noqa: E402
    DenseDesign,
    LogisticL1,
    ShardedDesign,
    SlabDesign,
    lambda_max_design,
    make_design_eval,
)
from repro.configs.base import GLMConfig  # noqa: E402
from repro.core import DGLMNETOptions, TGOptions  # noqa: E402
from repro.core.truncated_gradient import truncated_gradient_fit  # noqa: E402
from repro.data.synthetic import make_glm_dataset  # noqa: E402
from repro.launch.mesh import make_dev_mesh  # noqa: E402
from repro.train.metrics import auprc  # noqa: E402


def main():
    cfg = GLMConfig(name="dist", num_examples=16384, num_features=1024,
                    density=0.2)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    X, y = ds.X_train, ds.y_train
    n_trim = (X.shape[0] // 2) * 2
    X, y = X[:n_trim], y[:n_trim]
    mesh = make_dev_mesh(2, 4)
    design = ShardedDesign(DenseDesign(X), mesh, tile=64)
    lmax = float(lambda_max_design(design, y))
    print(f"mesh={dict(mesh.shape)}  n={X.shape[0]}  p={X.shape[1]}")

    print("\n-- d-GLMNET path (feature-sharded over `model`, examples over `data`)")
    est = LogisticL1(opts=DGLMNETOptions(tile=64, max_iters=40),
                     warm_start=True)
    best_d = 0.0
    for i in range(1, 9):
        lam = lmax * 2.0 ** (-i)
        res = est.fit(design, y, lam)           # warm-started from beta_
        ap = auprc(ds.X_test @ res.beta[: ds.X_test.shape[1]], ds.y_test)
        best_d = max(best_d, ap)
        nnz = int((jnp.abs(res.beta) > 0).sum())
        print(f"  lambda={lam:9.3f} nnz={nnz:5d} f={res.f:12.2f} "
              f"iters={res.n_iters:3d} AUPRC={ap:.4f}")

    print("\n-- truncated-gradient baseline (example-sharded, averaged)")
    best_tg = 0.0
    for lr in (0.1, 0.5):
        snaps = truncated_gradient_fit(
            X, y, lmax / 64,
            opts=TGOptions(num_machines=8, passes=6, learning_rate=lr),
            key=jax.random.key(1))
        for pass_idx, b in snaps:
            ap = auprc(ds.X_test @ b, ds.y_test)
            best_tg = max(best_tg, ap)
        print(f"  lr={lr}: best-so-far AUPRC={best_tg:.4f}")

    print(f"\nd-GLMNET best {best_d:.4f} vs TG best {best_tg:.4f} "
          f"-> {'d-GLMNET wins' if best_d >= best_tg else 'TG wins'} "
          f"(paper Figure 1 conclusion)")

    print("\n-- distributed screened path (strong rule + KKT around mesh "
          "restricted solves)")
    import time

    opts = DGLMNETOptions(tile=64, max_iters=40)
    est = LogisticL1(opts=opts)
    t0 = time.perf_counter()
    pts = est.path(design, y, path_len=8)
    dt = time.perf_counter() - t0
    for pt in pts:
        print(f"  lambda={pt.lam:9.3f} nnz={pt.nnz:5d} "
              f"active={pt.screen['active']:5d}/{X.shape[1]} "
              f"kkt_rounds={pt.screen['kkt_rounds']}")
    print(f"  path wall-clock {dt:.2f}s (restricted solves stay on the "
          f"mesh, one compiled while_loop per capacity bucket)")

    print("\n-- same path over by-feature sparse slabs (no dense X anywhere),"
          "\n   per-lambda AUPRC streamed from the mesh via a sharded test "
          "design")
    dp = 2  # data extent of the dev mesh
    slab_design = ShardedDesign(SlabDesign.from_dense(X, dp), mesh, tile=64)
    n_test = (ds.X_test.shape[0] // dp) * dp
    eval_fn = make_design_eval(
        SlabDesign.from_dense(ds.X_test[:n_test], dp), ds.y_test[:n_test],
        mesh=mesh, tile=64)
    t0 = time.perf_counter()
    pts_sp = est.path(slab_design, y, path_len=8, eval_fn=eval_fn)
    dt = time.perf_counter() - t0
    for pt, pt_sp in zip(pts, pts_sp):
        drift = abs(pt_sp.f - pt.f) / max(abs(pt.f), 1e-9)
        print(f"  lambda={pt_sp.lam:9.3f} nnz={pt_sp.nnz:5d} "
              f"active={pt_sp.screen['active']:5d} "
              f"AUPRC={pt_sp.metrics['auprc']:.4f} "
              f"|f-f_dense|/|f|={drift:.2e}")
    print(f"  sparse path wall-clock {dt:.2f}s "
          f"(screen streams (row_idx, values) slabs, psum over data axes)")


if __name__ == "__main__":
    main()
