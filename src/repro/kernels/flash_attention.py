"""Pallas TPU kernel: blocked online-softmax (flash) attention, causal.

The transformer zoo's jnp path already avoids (S,S) materialization via
query chunking + remat (models/attention.py); this kernel is the
TPU-native endpoint of that hillclimb: one pass over KV blocks with
running (max, denom, acc) statistics in VMEM scratch — no re-computation
in the forward and MXU-aligned (128) tiles.

Grid: (batch*heads, n_q_blocks, n_kv_blocks); the kv axis is innermost and
sequential on TPU, so scratch accumulators persist across it (standard
flash pattern: init at kv==0, finalize at the last kv block).

Validated in interpret mode against ``ref.flash_attention_ref`` (= plain
softmax attention); forward-only (training uses the jnp path's remat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (Bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (Bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (Bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (Bq, Bk)

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                                  # (Bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (Bq, Bk)
    alpha = jnp.exp(m_prev - m_new)                      # (Bq, 1)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, block_q: int = 128,
                           block_k: int = 128, interpret: bool = True):
    """q/k/v: (B, S, H, D) -> (B, S, H, D). Full (non-windowed) causal or
    bidirectional attention; S must divide the block sizes."""
    b, s, h, d = q.shape
    assert k.shape == v.shape == (b, s, h, d)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (d ** 0.5)

    # (B,S,H,D) -> (B*H, S, D)
    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    qf, kf, vf = fold(q), fold(k), fold(v)
    nq, nk = s // block_q, s // block_k

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          causal=causal, scale=scale),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),       # running max
            pltpu.VMEM((block_q, 1), jnp.float32),       # running denom
            pltpu.VMEM((block_q, d), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)

    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
