"""nonfinite-guard: unguarded device->host materialization on the serve
boundary.

The serving stack's contract (PR 8) is that poison never reaches a
caller: scores and solver results cross to host exactly once, and that
crossing is where NaN/Inf must be caught — the scorer pins the store
back to its last-good snapshot (``PathScorer.score``), the engine's
``fetch`` validates histories against the typed device-side ``status``.
A new host-crossing added to this layer without a finiteness check is a
hole in that contract: one poisoned coefficient row and the NaN sails
straight into a response.

Scope heuristic: modules in the serve package (or importing from it) and
the solver engine. Within scope, a function that materializes a
*computed* device value on host — ``jax.device_get`` / the engine's
``device_get`` indirection, or ``np.asarray``/``np.array`` applied to a
call result — must mention ``isfinite``/``isnan`` somewhere in the same
function (the guard), or carry an ``allow[nonfinite-guard]`` pragma
saying why the value cannot be poisoned (e.g. it is a reference oracle,
not served output). ``np.asarray`` over literals, comprehensions,
attributes and builtin results is exempt — those are host values already.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "nonfinite-guard"
DOC = ("device->host materialization in the serve/engine layer with no "
       "isfinite/isnan check in scope — poison can reach a caller")

#: np.asarray over results of these builtins is plain host data
_HOST_BUILTINS = {
    "sorted", "list", "tuple", "range", "zip", "map", "len", "min", "max",
    "sum", "dict", "set", "str", "enumerate", "reversed", "float", "int",
}

_MATERIALIZERS = {"numpy.asarray", "numpy.array", "numpy.asanyarray"}


def _in_scope(mod: ModuleInfo) -> bool:
    if "src/repro/serve/" in mod.path or mod.path.endswith("core/engine.py"):
        return True
    return any(m == "repro.serve" or m.startswith("repro.serve.")
               for m in mod.imported_modules)


def _is_device_get(mod: ModuleInfo, node: ast.Call) -> bool:
    q = mod.qualname(node.func)
    return q is not None and (q == "device_get"
                              or q.endswith(".device_get"))


def _materializes_computed(mod: ModuleInfo, node: ast.Call) -> bool:
    """np.asarray/np.array whose operand is itself a call result — the
    only asarray form that can be a fresh device->host crossing (host
    literals/comprehensions/attributes carry no device value)."""
    if mod.qualname(node.func) not in _MATERIALIZERS:
        return False
    if not node.args or not isinstance(node.args[0], ast.Call):
        return False
    inner = node.args[0].func
    if isinstance(inner, ast.Name) and inner.id in _HOST_BUILTINS:
        return False
    return True


def _has_guard(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and node.attr in ("isfinite",
                                                            "isnan"):
            return True
        if isinstance(node, ast.Name) and node.id in ("isfinite", "isnan"):
            return True
    return False


def _check_fn(mod: ModuleInfo, fn: ast.FunctionDef) -> Iterable[Finding]:
    hits: List[ast.Call] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if _is_device_get(mod, node) or _materializes_computed(mod, node):
            hits.append(node)
    if not hits or _has_guard(fn):
        return
    node = hits[0]
    what = ("device_get" if _is_device_get(mod, node)
            else "np.asarray of a computed value")
    yield Finding(
        file=mod.path, line=node.lineno, rule=RULE_ID,
        message=(
            f"{fn.name}() crosses a computed value to host ({what}) with "
            f"no isfinite/isnan check in scope — on the serve/engine "
            f"boundary poison must be caught at the crossing (or "
            f"allow[{RULE_ID}] stating why this value cannot be poisoned)"),
    )


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not _in_scope(mod):
            continue
        for fn in mod.functions():
            out.extend(_check_fn(mod, fn))
    return out
