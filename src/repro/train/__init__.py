from repro.train.metrics import accuracy, auprc, glm_eval_fn, log_loss  # noqa: F401
from repro.train.state import make_train_state, train_state_shapes  # noqa: F401
from repro.train.train_step import (  # noqa: F401
    IGNORE,
    cross_entropy,
    make_loss_fn,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
