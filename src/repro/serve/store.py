"""Device-resident coefficient store for the certified regularization path.

The training side certifies a path — L lambda operating points, each with
its own sparsity/quality trade-off. Serving keeps the ENTIRE stacked
``(L, p)`` coefficient array device-resident (replicated locally,
P(model)-feature-sharded on a mesh) so every request picks its lambda at
scoring time with zero host traffic: the scoring step gathers the chosen
row per request *inside* the kernel (``kernels.ops.slab_path_spmv``).

Hot-swap: :meth:`PathStore.swap` installs a freshly certified path (a new
``PathResult`` from a background refit, or the next points of a still-
running certification) by building the new device stack first and then
publishing it as one reference assignment. Scoring code takes a
:class:`StoreSnapshot` once per batch, so an in-flight batch keeps scoring
against the coefficients it started with — a batch can never mix two
paths' coefficients — while the next batch sees the new version. The old
stack's device memory is released when the last in-flight batch drops its
snapshot (JAX arrays are immutable; nothing is overwritten in place).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.types import PathResult
from repro.obs import registry as obs_registry
from repro.obs import trace as obs_trace
from repro.resilience import InjectedFault, retry_call, take_load_failure, \
    take_swap_failure


@dataclass(frozen=True)
class StoreSnapshot:
    """An immutable view of one published path version.

    ``betas`` is the device-resident ``(L, p_pad)`` stack (feature axis
    zero-padded to the store's alignment); ``lambdas`` stays on host for
    operating-point resolution. Batches resolve lambdas and score against
    ONE snapshot, so a concurrent :meth:`PathStore.swap` can never split a
    batch across versions.
    """

    version: int
    lambdas: np.ndarray          # (L,) descending, host
    betas: jnp.ndarray           # (L, p_pad) device-resident
    p: int                       # original feature count (pre-padding)

    @property
    def num_points(self) -> int:
        return int(self.lambdas.shape[0])

    @property
    def p_pad(self) -> int:
        return int(self.betas.shape[1])

    def index_of(self, lam: float) -> int:
        """Nearest stored lambda in log space (the grid is geometric)."""
        lams = np.maximum(np.asarray(self.lambdas, np.float64), 1e-300)
        return int(np.argmin(np.abs(np.log(lams) - np.log(max(lam, 1e-300)))))

    def indices_of(self, lams) -> np.ndarray:
        """Vectorized :meth:`index_of` for a batch of requested lambdas."""
        grid = np.log(np.maximum(np.asarray(self.lambdas, np.float64),
                                 1e-300))
        q = np.log(np.maximum(np.asarray(lams, np.float64), 1e-300))
        return np.argmin(np.abs(grid[None, :] - q[:, None]),
                         axis=1).astype(np.int32)


class PathStore:
    """Holds the certified path device-resident and versioned.

    ``mesh=None`` keeps the stack on the default device (single-process
    serving); with a mesh the stack lands P(None, "model") — features
    sharded exactly like the training layout's beta, so the scoring
    shard_map pairs each coefficient block with its slab block and only
    psums the (batch,)-sized partial scores. ``tile`` aligns the feature
    padding with the slab partition (``model_dim * tile``), matching
    ``ShardedDesign``'s residency so served scores are bit-identical to
    ``LogisticL1.decision_function`` through the same mesh.
    """

    def __init__(self, result: Optional[PathResult] = None, *, mesh=None,
                 tile: int = 128):
        self.mesh = mesh
        self.tile = tile
        self._snap: Optional[StoreSnapshot] = None
        self._prev: Optional[StoreSnapshot] = None   # last-good fallback
        self._version = 0
        self.quarantined: list = []   # versions rolled back by quarantine()
        if result is not None:
            self.swap(result)

    # -- geometry -----------------------------------------------------------

    @property
    def pad_p_to(self) -> int:
        """Feature-axis alignment: mesh stores pad to model_dim * tile
        (the slab partition unit); local stores don't pad."""
        if self.mesh is None:
            return 1
        return self.mesh.shape["model"] * self.tile

    @property
    def snapshot(self) -> StoreSnapshot:
        if self._snap is None:
            raise ValueError("PathStore is empty — swap() a PathResult in")
        return self._snap

    @property
    def version(self) -> int:
        return self._version

    # -- publish ------------------------------------------------------------

    def swap(self, result: PathResult, *, attempts: int = 3) -> StoreSnapshot:
        """Atomically publish a new path version.

        The new stack is built and placed on device(s) BEFORE the snapshot
        reference flips, so concurrent scorers only ever observe a fully
        materialized version (the flip is one reference assignment —
        atomic under the GIL). In-flight batches holding the previous
        snapshot are unaffected.

        Transient build/placement failures (device OOM races, injected
        chaos faults) are retried with exponential backoff up to
        ``attempts`` times; the store keeps serving the current snapshot
        throughout — a failed swap never leaves it empty or half-built.
        Validation errors (empty path, feature-space mismatch) are not
        retried.
        """
        if len(result) == 0:
            raise ValueError("cannot publish an empty path")
        p = int(result.betas.shape[1])
        snap = self._snap
        if snap is not None and p != snap.p:
            raise ValueError(
                f"new path has p={p} but the store serves p={snap.p} — "
                f"a feature-space change needs a new store"
            )
        return retry_call(lambda: self._publish(result, p),
                          attempts=attempts, base_delay_s=0.01)

    def _publish(self, result: PathResult, p: int) -> StoreSnapshot:
        """One build-then-flip attempt (the retryable unit of :meth:`swap`).

        The ``swap`` span closes at the existing ``block_until_ready``
        sync + reference flip — tracing adds no new device round-trip."""
        with obs_trace.span("swap", points=len(result)):
            if take_swap_failure():
                raise InjectedFault("injected PathStore.swap failure")
            betas = jnp.asarray(result.betas, jnp.float32)
            pad = (-p) % self.pad_p_to
            if pad:
                betas = jnp.pad(betas, ((0, 0), (0, pad)))
            if self.mesh is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P

                betas = jax.device_put(
                    betas, NamedSharding(self.mesh, P(None, "model")))
            else:
                betas = jax.device_put(betas)
            betas.block_until_ready()  # fully materialized before publishing
            self._version += 1
            new = StoreSnapshot(version=self._version,
                                lambdas=np.asarray(result.lambdas,
                                                   np.float64),
                                betas=betas, p=p)
            self._prev = self._snap   # keep last-good for quarantine()
            self._snap = new          # the atomic publish
        obs_registry.counter("serve.swaps").inc()
        return new

    # -- rollback -----------------------------------------------------------

    def quarantine(self, version: int) -> bool:
        """Pin the store back to the previous snapshot if ``version`` is
        the one currently published.

        The scorer's non-finite guard calls this when a published version
        produces NaN/Inf scores: the store reverts to the last-good
        snapshot (one reference assignment, same atomicity as swap) and
        records the bad version in :attr:`quarantined`. Returns whether a
        rollback happened — False when ``version`` is already superseded
        (a newer swap won the race) or there is no previous snapshot to
        fall back to.
        """
        if (self._snap is not None and self._snap.version == version
                and self._prev is not None):
            self._snap = self._prev
            self._prev = None         # don't ping-pong back to the bad one
            self.quarantined.append(version)
            return True
        return False

    # -- persistence --------------------------------------------------------

    @classmethod
    def from_checkpoint(cls, directory: str, *, mesh=None, tile: int = 128,
                        attempts: int = 3) -> "PathStore":
        """Fit-once/serve-many: load a ``PathResult.save`` checkpoint and
        publish it (the serving process needs no training code or data).

        The load is retried with backoff (transient filesystem errors and
        injected chaos faults); persistent corruption still surfaces as
        :class:`~repro.checkpoint.CheckpointCorruption` after ``attempts``
        tries, wrapped in ``RetriesExhausted`` with the cause chained.
        """
        def _load() -> PathResult:
            if take_load_failure():
                raise InjectedFault("injected checkpoint-load failure")
            return PathResult.load(directory)

        return cls(retry_call(_load, attempts=attempts, base_delay_s=0.01),
                   mesh=mesh, tile=tile)
