"""Data pipeline: by-feature layout (paper Table 1), synthetic twins, LM
batches."""
import io

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLMConfig
from repro.configs.glm import GLM_EPSILON, GLM_WEBSPAM, twin
from repro.data.byfeature import (
    densify,
    densify_tile,
    partition_features,
    read_table1,
    to_by_feature,
    write_table1,
)
from repro.data.lm_data import batches, zipf_corpus
from repro.data.synthetic import make_glm_dataset


def _rand_sparse(n=64, p=24, density=0.3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p)) * (rng.random((n, p)) < density)
    return jnp.asarray(X, jnp.float32)


def test_by_feature_round_trip():
    X = _rand_sparse()
    bf = to_by_feature(X)
    np.testing.assert_allclose(densify(bf), X, atol=0)
    assert bf.nnz == int((np.asarray(X) != 0).sum())


def test_densify_tile_matches_slice():
    X = _rand_sparse(n=50, p=32)
    bf = to_by_feature(X)
    np.testing.assert_allclose(densify_tile(bf, 8, 16), X[:, 8:24], atol=0)


def test_table1_text_round_trip():
    X = _rand_sparse(n=20, p=10)
    bf = to_by_feature(X)
    buf = io.StringIO()
    write_table1(bf, buf)
    buf.seek(0)
    bf2 = read_table1(buf, bf.n)
    np.testing.assert_allclose(densify(bf2), densify(bf), atol=0)


def test_partition_features_covers_all():
    parts = partition_features(103, 16)
    allidx = np.concatenate(parts)
    assert len(allidx) == 103
    assert len(np.unique(allidx)) == 103


def test_synthetic_twin_density():
    ds = make_glm_dataset(twin(GLM_WEBSPAM, scale=0.002), jax.random.key(0))
    X = np.asarray(ds.X_train)
    density = (X != 0).mean()
    assert density < 0.01  # webspam twin is very sparse
    assert set(np.unique(np.asarray(ds.y_train))) <= {-1.0, 1.0}


def test_synthetic_learnable():
    """Bayes-ish: the true beta scores the test set well above chance."""
    cfg = GLMConfig(name="t", num_examples=2048, num_features=64, density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(1))
    from repro.train.metrics import auprc

    ap = auprc(ds.X_test @ ds.beta_true, ds.y_test)
    base = float((np.asarray(ds.y_test) > 0).mean())
    assert ap > base + 0.2


def test_zipf_corpus_and_batches():
    rng = np.random.default_rng(0)
    corpus = zipf_corpus(rng, 1000, 10_000)
    assert corpus.min() >= 0 and corpus.max() < 1000
    it = batches(corpus, 4, 16, rng=rng)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))
