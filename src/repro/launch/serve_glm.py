"""GLM path-serving launcher: batched online scoring of a certified path.

    PYTHONPATH=src python -m repro.launch.serve_glm --smoke
    PYTHONPATH=src python -m repro.launch.serve_glm --smoke --mesh 2x4
    PYTHONPATH=src python -m repro.launch.serve_glm --load-path ckpt/ \
        --batch 256 --steps 50

Fits (or loads via ``--load-path``, see ``PathResult.save``) a certified
regularization path, publishes it into a device-resident
:class:`repro.serve.PathStore`, then drives synthetic hashed-token request
traffic through the :class:`RequestBatcher` -> :class:`PathScorer` loop —
one jitted slab dispatch per batch, every request row picking its own
lambda — and reports scores/sec. ``--smoke`` additionally self-checks
served scores bit-equal to ``LogisticL1.decision_function`` at every
operating point and exercises a hot-swap mid-traffic.

``--trace PATH`` runs the whole launcher under ``repro.obs.observe()``
and writes ``PATH.trace.json`` (Perfetto-loadable), ``PATH.events.jsonl``
and ``PATH.summary.json`` — the summary carries the submit->score
latency histogram (p50/p95/p99) and the serve/drain/score/swap span
totals; render it with ``python -m repro.obs.report PATH.summary.json``.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
import time

if "--mesh" in sys.argv:
    # fake-device flag must land before the first jax import (same dance
    # as benchmarks.regpath_bench); fail loudly on an unraisable count
    try:
        _spec = sys.argv[sys.argv.index("--mesh") + 1]
    except IndexError:
        _spec = ""
    _need = 1
    for _d in re.findall(r"\d+", _spec):
        _need *= int(_d)
    if _need > 1:
        _flags = os.environ.get("XLA_FLAGS", "")
        _m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                       _flags)
        if _m is None:
            os.environ["XLA_FLAGS"] = (
                _flags + f" --xla_force_host_platform_device_count={_need}"
            )
        elif int(_m.group(1)) < _need:
            sys.exit(
                f"--mesh {_spec} needs >= {_need} fake devices but "
                f"XLA_FLAGS already forces {_m.group(1)}"
            )

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import LogisticL1, PathResult, SlabDesign, ShardedDesign
from repro.configs.base import GLMConfig
from repro.data.synthetic import make_glm_dataset
from repro.obs import observe, trace as obs_trace
from repro.serve import PathScorer, PathStore, RequestBatcher, hash_token


def make_traffic(rng, p: int, count: int, lambdas, *, tokens_per: int = 12):
    """Synthetic hashed-token requests + per-request lambda picks."""
    reqs, lams = [], []
    for _ in range(count):
        k = int(rng.integers(1, tokens_per + 1))
        toks = rng.integers(0, 4 * p, size=k)
        reqs.append({f"tok{t}": float(v)
                     for t, v in zip(toks, rng.normal(size=k))})
        lams.append(float(lambdas[int(rng.integers(0, len(lambdas)))]))
    return reqs, lams


def serve_loop(scorer, batcher, reqs, lams, *, steps: int):
    """Drive ``steps`` drain->score rounds over the traffic; returns
    (total scores, elapsed seconds, versions seen).

    Under an active ``repro.obs`` tracer the rounds run inside a
    ``serve`` span (the encode/drain/score spans come from the serve
    layer itself), and each scored drain feeds the submit->score
    ``serve.latency_s`` histogram via :meth:`RequestBatcher.mark_scored`
    — called right after ``scorer.score`` returns host numpy, the
    loop's existing sync point."""
    total, versions = 0, set()
    per = max(1, len(reqs) // steps)
    t0 = time.perf_counter()
    with obs_trace.span("serve", steps=steps):
        for s in range(steps):
            for r, l in zip(reqs[s * per:(s + 1) * per],
                            lams[s * per:(s + 1) * per]):
                batcher.submit(r, l)
            batch, blams = batcher.drain()
            scores, ver = scorer.score(batch, blams)
            batcher.mark_scored()
            total += len(scores)
            versions.add(ver)
    # allow[bench-timing]: scorer.score returns host numpy — every batch is synced before the clock stops
    return total, time.perf_counter() - t0, versions


def smoke_check(est, store, scorer, batch, n_live: int, path) -> None:
    """Served-vs-``decision_function`` bit-equality at every lambda."""
    inner = SlabDesign(jnp.asarray(batch.row_idx),
                       jnp.asarray(batch.values), batch.batch_cap)
    design = (ShardedDesign(inner, store.mesh, tile=store.tile)
              if store.mesh is not None else inner)
    for l in range(len(path)):
        beta = path.betas[l]
        if batch.p_pad != beta.shape[0]:
            beta = jnp.pad(beta, (0, batch.p_pad - beta.shape[0]))
        # allow[nonfinite-guard]: decision_function is the reference oracle; the served side of the bit-equality IS the guarded path
        ref = np.asarray(est.decision_function(design, beta=beta))[:n_live]
        got, _ = scorer.score(batch, np.full(n_live, path.lambdas[l]))
        if not np.array_equal(got, ref):
            raise SystemExit(
                f"FAIL: served scores not bit-equal to decision_function "
                f"at lambda index {l} "
                f"(max |diff| {np.max(np.abs(got - ref)):.3e})")
    print(f"# smoke: served scores bit-equal to decision_function at all "
          f"{len(path)} lambdas")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + bit-equality and hot-swap "
                         "self-checks")
    ap.add_argument("--mesh", default="local",
                    help="'local' (default) or a mesh spec like '2x4' "
                         "(P(model)-sharded coefficient stack)")
    ap.add_argument("--batch", type=int, default=64,
                    help="max requests per scoring dispatch")
    ap.add_argument("--steps", type=int, default=20,
                    help="drain->score rounds to time")
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--p", type=int, default=512)
    ap.add_argument("--path-len", type=int, default=6)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--save-path", default=None,
                    help="directory to PathResult.save the fitted path")
    ap.add_argument("--load-path", default=None,
                    help="serve a PathResult.save checkpoint instead of "
                         "fitting (no training data touched)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="run under repro.obs and write PATH.trace.json "
                         "(Perfetto-loadable) / PATH.events.jsonl / "
                         "PATH.summary.json with span totals and the "
                         "submit->score latency histogram")
    args = ap.parse_args()
    if args.smoke:
        args.n, args.p, args.path_len = min(args.n, 256), min(args.p, 128), \
            min(args.path_len, 4)

    mesh = None
    if args.mesh != "local":
        from repro.launch.mesh import parse_mesh

        mesh = parse_mesh(args.mesh)

    if args.trace is None:
        _run(args, mesh)
        return
    with observe() as obs:
        _run(args, mesh)
    summary = obs.summary()
    hist = summary.get("histograms", {}).get("serve.latency_s")
    if hist and hist["count"]:
        print(f"# submit->score latency ({hist['count']} requests): "
              f"p50 {hist['p50'] * 1e3:.2f}ms / "
              f"p95 {hist['p95'] * 1e3:.2f}ms / "
              f"p99 {hist['p99'] * 1e3:.2f}ms")
    files = obs.export(args.trace)
    print(f"# trace: {files['trace']} (open in Perfetto) | "
          f"summary: {files['summary']} "
          f"(python -m repro.obs.report {files['summary']})")


def _run(args, mesh):
    est = LogisticL1(mesh=mesh) if mesh is not None else LogisticL1()
    if args.load_path:
        path = PathResult.load(args.load_path)
        print(f"# loaded path: L={len(path)} p={path.betas.shape[1]} "
              f"from {args.load_path}")
    else:
        cfg = GLMConfig(name="serve-glm", num_examples=args.n,
                        num_features=args.p, density=0.1)
        ds = make_glm_dataset(cfg, jax.random.key(0))
        X, y = ds.X_train, ds.y_train
        if mesh is not None:
            from repro.core.distributed import _data_extent

            n_trim = (X.shape[0] // _data_extent(mesh)) * _data_extent(mesh)
            X, y = X[:n_trim], y[:n_trim]
        path = est.path(X, y, path_len=args.path_len)
        print(f"# fitted path: L={len(path)} p={args.p} "
              f"nnz={path.nnz.tolist()}")
    if args.save_path:
        path.save(args.save_path)
        print(f"# saved path to {args.save_path}")

    store = PathStore(path, mesh=mesh, tile=args.tile)
    scorer = PathScorer(store)
    p = store.snapshot.p
    dp = 1
    if mesh is not None:
        from repro.core.distributed import _data_extent

        dp = _data_extent(mesh)
    batcher = RequestBatcher(p, max_batch=args.batch, dp=dp,
                             pad_p_to=store.pad_p_to)

    rng = np.random.default_rng(0)
    reqs, lams = make_traffic(rng, p, args.batch * args.steps, path.lambdas)

    # warm the compiled program, then time
    for r, l in zip(reqs[:args.batch], lams[:args.batch]):
        batcher.submit(r, l)
    warm_batch, warm_lams = batcher.drain()
    scorer.score(warm_batch, warm_lams)

    total, secs, versions = serve_loop(scorer, batcher, reqs, lams,
                                       steps=args.steps)
    rate = total / max(secs, 1e-12)
    print(f"# served {total} scores in {secs:.3f}s -> {rate:,.0f} "
          f"scores/sec (batch<= {args.batch}, mesh={args.mesh})")

    if args.smoke:
        smoke_check(est if args.load_path is None else LogisticL1(mesh=mesh),
                    store, scorer, warm_batch, warm_batch.n_live, path)
        # hot-swap: publish a truncated path mid-traffic; batches must
        # score against exactly one version each
        sub = PathResult(lambdas=path.lambdas[:2], betas=path.betas[:2],
                         nnz=path.nnz[:2], f=path.f[:2],
                         n_iters=path.n_iters[:2], metrics=path.metrics[:2],
                         screen=path.screen[:2])
        v_before = scorer.score(warm_batch, warm_lams)[1]
        store.swap(sub)
        got, v_after = scorer.score(warm_batch, warm_lams)
        if v_after != v_before + 1 or len(got) != warm_batch.n_live:
            raise SystemExit("FAIL: hot-swap version bookkeeping broken")
        print(f"# smoke: hot-swap v{v_before} -> v{v_after} served "
              f"{len(got)} scores without dropping the batch")
        print("SERVE SMOKE OK")


if __name__ == "__main__":
    main()
