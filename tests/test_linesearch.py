"""Line-search (paper Algorithm 3) behaviour."""
import jax
import jax.numpy as jnp

from repro.core import line_search, margins, objective, working_stats
from repro.core.dglmnet import DGLMNETOptions, dglmnet_iteration
from repro.core.linesearch import f_alpha, golden_section


def _setup(small_glm, lam_div=16):
    X, y = small_glm.X_train, small_glm.y_train
    from repro.core import lambda_max

    lam = float(lambda_max(X, y)) / lam_div
    beta = jnp.zeros(X.shape[1])
    m = margins(X, beta)
    dbeta, dm, grad_dot = dglmnet_iteration(
        X, y, beta, m, lam, DGLMNETOptions(num_blocks=4))
    return X, y, lam, beta, m, dbeta, dm, grad_dot


def test_alpha_in_unit_interval(small_glm):
    X, y, lam, beta, m, dbeta, dm, grad_dot = _setup(small_glm)
    res = line_search(m, dm, y, beta, dbeta, lam, grad_dot)
    a = float(res.alpha)
    assert 0.0 < a <= 1.0


def test_armijo_sufficient_decrease(small_glm):
    X, y, lam, beta, m, dbeta, dm, grad_dot = _setup(small_glm)
    res = line_search(m, dm, y, beta, dbeta, lam, grad_dot)
    f0 = float(f_alpha(0.0, m, dm, y, beta, dbeta, lam))
    assert float(res.f_new) < f0  # strict improvement


def test_fnew_matches_objective(small_glm):
    X, y, lam, beta, m, dbeta, dm, grad_dot = _setup(small_glm)
    res = line_search(m, dm, y, beta, dbeta, lam, grad_dot)
    beta2 = beta + res.alpha * dbeta
    f_direct = float(objective(margins(X, beta2), y, beta2, lam))
    assert abs(f_direct - float(res.f_new)) / abs(f_direct) < 1e-4


def test_golden_section_quadratic():
    fun = lambda a: (a - 0.37) ** 2
    xmin = float(golden_section(fun, jnp.float32(0.0), jnp.float32(1.0)))
    assert abs(xmin - 0.37) < 1e-3


def test_unit_step_preserves_exact_zeros(small_glm):
    """Sparsity safeguard: when the unit step is accepted, coordinates with
    dbeta_j = -beta_j land exactly on zero."""
    X, y, lam, beta, m, dbeta, dm, grad_dot = _setup(small_glm, lam_div=4)
    res = line_search(m, dm, y, beta, dbeta, lam, grad_dot)
    if bool(res.took_unit_step):
        new_beta = beta + res.alpha * dbeta
        # coordinates the CD solver zeroed stay exactly zero
        zeroed = jnp.abs(beta + dbeta) < 1e-12
        assert bool(jnp.all(new_beta[zeroed] == 0.0))
