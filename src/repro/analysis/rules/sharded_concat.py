"""sharded-concat: concatenation of possibly-mesh-sharded values.

``jnp.concatenate`` of P(model)-sharded pieces of unequal length
miscompiles on the JAX pinned in this environment (wrong-extent
dynamic-update window — garbage tails; see ``sharding/collect.py``). The
repo's guard is architectural: the replicate-then-concat dance lives in
exactly ONE place, ``repro.sharding.collect``, and mesh-aware call sites
must go through it. This rule enforces the single-home invariant: any
direct ``jnp.concatenate/stack/hstack/vstack/column_stack/append`` in a
module that imports sharding machinery is a finding.

Modules that never touch a mesh (pure-local math, host-side assembly) are
exempt — a concat there cannot see a sharded operand.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.context import Project
from repro.analysis.findings import Finding

RULE_ID = "sharded-concat"
DOC = ("direct jnp concat/stack in a mesh-aware module — route through "
       "sharding.collect.concat_replicated (single home of the "
       "P(model)-concat miscompile guard)")

_BANNED = {
    "jax.numpy.concatenate", "jax.numpy.stack", "jax.numpy.hstack",
    "jax.numpy.vstack", "jax.numpy.column_stack", "jax.numpy.append",
    "jax.numpy.concat",
}

#: the one module allowed to concatenate mesh values
_HOME = "sharding/collect.py"


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if mod.path.endswith(_HOME) or not mod.mesh_context:
            continue
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            q = mod.qualname(node.func)
            if q in _BANNED:
                short = q.replace("jax.numpy.", "jnp.")
                out.append(Finding(
                    file=mod.path, line=node.lineno, rule=RULE_ID,
                    message=(
                        f"{short} in a mesh-aware module — sharded pieces "
                        f"miscompile; use sharding.collect.concat_replicated "
                        f"(or allow[{RULE_ID}] with why the operands can "
                        f"never be sharded)"),
                ))
    return out
