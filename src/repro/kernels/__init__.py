from repro.kernels.ops import flash_attention, gram_cd, logistic_stats  # noqa: F401
