"""Config registry: ``get_config("<arch-id>")`` for every assigned arch.

Arch ids match the assignment table verbatim (dashes/dots); module names are
the pythonized versions.

The LM model-zoo configs load lazily (PEP 562): the GLM path imports
``GLMConfig``/``GLM_CONFIGS`` from here without executing ten LM config
modules, and the dead-code inventory rule
(``repro.analysis.rules.dead_code``) treats the ``__getattr__`` boundary
as "not part of the import-time surface". ``from repro.configs import
MODEL_CONFIGS`` still works — the zoo materializes on first access.
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ARCH_TYPES,
    AttentionConfig,
    EncDecConfig,
    FrontendStub,
    GLMConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.configs.glm import GLM_CONFIGS
from repro.configs.shapes import SHAPES, InputShape, get_shape  # noqa: F401

GLM_IDS = tuple(GLM_CONFIGS)

_LM_MODULES = (
    "qwen2_5_3b",
    "mamba2_2p7b",
    "zamba2_7b",
    "qwen1_5_4b",
    "internlm2_1p8b",
    "tinyllama_1p1b",
    "deepseek_v3_671b",
    "qwen2_vl_72b",
    "llama4_scout_17b_a16e",
    "seamless_m4t_medium",
)


def _model_configs() -> dict:
    cached = globals().get("MODEL_CONFIGS")
    if cached is None:
        import importlib

        cached = {}
        for m in _LM_MODULES:
            c = importlib.import_module(f"repro.configs.{m}").CONFIG
            cached[c.name] = c
        globals()["MODEL_CONFIGS"] = cached
        globals()["ALL_CONFIGS"] = {**cached, **GLM_CONFIGS}
        globals()["ARCH_IDS"] = tuple(cached)
    return cached


def __getattr__(name: str):
    if name in ("MODEL_CONFIGS", "ALL_CONFIGS", "ARCH_IDS"):
        _model_configs()
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals())
                  | {"MODEL_CONFIGS", "ALL_CONFIGS", "ARCH_IDS"})


def get_config(name: str):
    """Look up any registered config (model arch or GLM workload)."""
    if name in GLM_CONFIGS:
        return GLM_CONFIGS[name]
    _model_configs()
    all_configs = globals()["ALL_CONFIGS"]
    try:
        return all_configs[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; have {sorted(all_configs)}"
        ) from None
