"""The paper's "by feature" data layout (§3, Table 1).

d-GLMNET partitions the dataset by features: machine m stores
X_m = {L_j | j in S_m}, L_j = {(i, x_ij) | x_ij != 0}. The paper produces
this with a Map/Reduce pass; here the layout transformation is an explicit,
tested function pair:

* ``to_by_feature`` — CSC-like padded arrays (row_idx (p, K), values (p, K)),
  K = max nnz per feature, sentinel row = n. JAX-friendly fixed shapes; this
  is what lets webspam-scale (16.6M features, 1.2e9 nnz) fit on the mesh
  where a dense X cannot (DESIGN.md §2.3).
* ``densify_tile`` — scatter a tile of features back to a dense (n, F) block
  for the MXU Gram stage (on-the-fly densification).
* text round-trip of the paper's Table-1 line format for interop:
  ``feature_id (example_id:value) (example_id:value) ...``
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TextIO, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ByFeature:
    row_idx: jnp.ndarray     # (p, K) int32, sentinel = n for padding
    values: jnp.ndarray      # (p, K) float32
    n: int                   # number of examples

    @property
    def p(self) -> int:
        return self.row_idx.shape[0]

    @property
    def nnz(self) -> int:
        return int((self.row_idx < self.n).sum())


def to_by_feature(X) -> ByFeature:
    """Dense (n, p) -> by-feature padded CSC (the Reduce step of paper §3)."""
    Xn = np.asarray(X)
    n, p = Xn.shape
    cols = [np.nonzero(Xn[:, j])[0] for j in range(p)]
    k = max((len(c) for c in cols), default=1) or 1
    row_idx = np.full((p, k), n, np.int32)
    values = np.zeros((p, k), np.float32)
    for j, c in enumerate(cols):
        row_idx[j, : len(c)] = c
        values[j, : len(c)] = Xn[c, j]
    return ByFeature(jnp.asarray(row_idx), jnp.asarray(values), n)


def densify_tile(bf: ByFeature, start: int, width: int) -> jnp.ndarray:
    """Features [start, start+width) -> dense (n, width) block via scatter."""
    rows = jax.lax.dynamic_slice(bf.row_idx, (start, 0), (width, bf.row_idx.shape[1]))
    vals = jax.lax.dynamic_slice(bf.values, (start, 0), (width, bf.values.shape[1]))
    out = jnp.zeros((bf.n + 1, width), jnp.float32)  # +1 row swallows sentinels
    cols = jnp.broadcast_to(jnp.arange(width)[:, None], rows.shape)
    out = out.at[rows.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))
    return out[: bf.n]


def densify(bf: ByFeature) -> jnp.ndarray:
    return densify_tile(bf, 0, bf.p)


# ---------------------------------------------------------------------------
# Table-1 text format
# ---------------------------------------------------------------------------

def write_table1(bf: ByFeature, fh: TextIO) -> None:
    ri = np.asarray(bf.row_idx)
    vv = np.asarray(bf.values)
    for j in range(bf.p):
        live = ri[j] < bf.n
        cells = " ".join(f"({int(i)}:{float(v):.9g})" for i, v in zip(ri[j][live], vv[j][live]))
        fh.write(f"{j} {cells}\n".rstrip() + "\n")


def read_table1(fh: TextIO, n: int) -> ByFeature:
    rows_all, vals_all = [], []
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        entries = [p.strip("()").split(":") for p in parts[1:]]
        rows_all.append([int(i) for i, _ in entries])
        vals_all.append([float(v) for _, v in entries])
    p = len(rows_all)
    k = max((len(r) for r in rows_all), default=1) or 1
    row_idx = np.full((p, k), n, np.int32)
    values = np.zeros((p, k), np.float32)
    for j, (r, v) in enumerate(zip(rows_all, vals_all)):
        row_idx[j, : len(r)] = r
        values[j, : len(v)] = v
    return ByFeature(jnp.asarray(row_idx), jnp.asarray(values), n)


def partition_features(p: int, num_machines: int) -> Tuple[np.ndarray, ...]:
    """Contiguous feature blocks S_1..S_M (paper's Reduce-side partitioning)."""
    bounds = np.linspace(0, p, num_machines + 1).astype(int)
    return tuple(np.arange(bounds[i], bounds[i + 1]) for i in range(num_machines))
