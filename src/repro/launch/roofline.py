"""Roofline term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * PEAK_FLOPS_BF16)
    memory term     = HLO_bytes / (chips * HBM_BW)
    collective term = collective_bytes / (chips * ICI_BW_PER_LINK)

cost_analysis() provides flops/bytes. collective_bytes is NOT there: we
parse the compiled (post-SPMD-partitioning) HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops. Sizes come from the result-shape string on each op line; HLO is
per-device after partitioning, so the sum is per-device collective traffic
(matching the per-chip link-bandwidth denominator).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every shape literal in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand/result bytes from compiled HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match op lines: `%name = <shape> all-reduce(...)`, also fusion-free starts
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        for coll in _COLLECTIVES:
            if opname == coll or opname.startswith(coll + "-start"):
                out[coll] += _shape_bytes(result_type)
                break
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                      # total HLO FLOPs = per-device * chips
    hbm_bytes: float                  # per-device bytes accessed (cost_analysis)
    collective_bytes: float           # per-device collective bytes
    collectives: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0          # 6*N*D (or 6*N_active*D)
    peak_memory_bytes: float = 0.0    # per-device, from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW_PER_LINK

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collectives": self.collectives,
            "model_flops": self.model_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float = 0.0) -> Roofline:
    from repro.compat import cost_analysis

    cost = cost_analysis(compiled)
    # cost_analysis reports the per-device (post-SPMD-partitioning) module;
    # scale FLOPs to the global total (uniform across devices). bytes and
    # collective bytes stay per-device to match per-chip bandwidth terms.
    flops = float(cost.get("flops", 0.0)) * chips
    hbm = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    except Exception:
        peak = 0.0
    colls = collective_bytes_from_hlo(compiled.as_text())
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips, flops=flops,
        hbm_bytes=hbm, collective_bytes=float(sum(colls.values())),
        collectives=colls, model_flops=model_flops, peak_memory_bytes=peak,
    )
