"""Sharding rules: params / inputs / caches -> PartitionSpec pytrees.

Policy (DESIGN.md §2.5/§2.6):
  * batch shards over ("pod","data")
  * weight "feature/output" dims shard over "model" (tensor parallel);
    the other big dim shards over "data" (FSDP) — standard 2-D sharding,
    required for the >=70B configs to fit 16 GB/chip.
  * MoE expert stacks shard E over "model" (expert parallelism) and d_model
    over "data".
  * every rule is guarded by divisibility — non-divisible dims fall back to
    the next candidate axis or replicate (e.g. qwen1.5's 20 heads, kv_heads
    < 16, mamba2's 50280 vocab handled by padding at the embedding).

All decisions are *name/shape-based* over the param pytree, so they apply
uniformly to the stacked per-segment leaves (leading layer axis -> None).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


def _spec2d(mesh: Mesh, rows: int, cols: int, row_ax, col_ax):
    """Shard a (rows, cols) matrix on (row_ax, col_ax) with divisibility
    fallbacks (drop an axis rather than produce an invalid sharding)."""
    r = row_ax if (row_ax and _fits(mesh, rows, row_ax)) else None
    c = col_ax if (col_ax and _fits(mesh, cols, col_ax)) else None
    return r, c


def param_pspecs(cfg: ModelConfig, params_shapes: Any, mesh: Mesh,
                 *, fsdp: bool = True):
    """PartitionSpec pytree matching ``params_shapes`` (ShapeDtypeStructs)."""
    daxes = batch_axes(mesh)
    fax = daxes if (fsdp and daxes) else None       # FSDP axis group
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)

    def rule(path: str, shape: Tuple[int, ...]) -> P:
        # stacked layer axis? (hybrid periods stack twice: (n_periods, k, ...))
        lead: Tuple[Optional[Any], ...] = ()
        core = shape
        if "segments" in path or "encoder" in path or "decoder" in path or "'layer'" in path:
            lead, core = (None,), shape[1:]
            if "'sub'" in path:
                lead, core = (None, None), shape[2:]
        if not core:
            return P(*lead) if lead else P()

        name = path

        if "moe" in name and any(k in name for k in ("w_gate", "w_up", "w_down")) \
                and "shared" not in name and len(core) == 3:
            # expert stack (E, din, dout): E -> model (expert parallel),
            # din -> fsdp over data. moe_forward explicitly re-gathers the
            # fsdp shards before the expert einsum so the contraction is
            # conflict-free with the capacity dim (which shards over data).
            e, din, dout = core
            eax = "model" if _fits(mesh, e, "model") else None
            dax = fax if _fits(mesh, din, fax) else None
            return P(*lead, eax, dax, None)

        if "embed" in name and len(core) == 2:       # (V, D)
            r, c = _spec2d(mesh, core[0], core[1], "model", fax)
            return P(*lead, r, c)

        if "lm_head" in name and len(core) == 2:     # (D, V)
            r, c = _spec2d(mesh, core[0], core[1], fax, "model")
            return P(*lead, r, c)

        if "router" in name:
            return P(*lead, *(None,) * len(core))

        if len(core) == 2:
            rows, cols = core
            # contraction-side vs output-side heuristic: shard the larger
            # "feature" dim on model, the d_model dim on fsdp.
            if any(k in name for k in ("w_down", "wo", "out_proj")):
                r, c = _spec2d(mesh, rows, cols, "model", fax)
            else:
                r, c = _spec2d(mesh, rows, cols, fax, "model")
            return P(*lead, r, c)

        if len(core) == 1:
            d = core[0]
            if any(k in name for k in ("scale", "bias_ln")) or "norm" in name:
                return P(*lead, None)
            # projection biases / per-head vectors: model if divisible
            ax = "model" if _fits(mesh, d, "model") else None
            return P(*lead, ax)

        # conv weights (W, conv_dim) handled by 2D rule above; fallback:
        return P(*lead, *(None,) * len(core))

    specs = [rule(jax.tree_util.keystr(p), v.shape) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_pspecs(cfg: ModelConfig, opt_state_shapes: Any, param_specs: Any,
                     mesh: Mesh):
    """Optimizer state mirrors param sharding; factored accumulators and
    scalars replicate along the reduced dim."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state_shapes)
    # build a path->spec map from params (m/v mirror params exactly by shape)
    pflat, _ = jax.tree_util.tree_flatten_with_path(param_specs)

    # match by stripped path suffix: opt paths look like ['m']['segments'][0]...
    def find_spec(path_str: str, shape) -> P:
        for pp, spec in pflat:
            if jax.tree_util.keystr(pp) in path_str and len(spec) == len(shape):
                return spec
        # adafactor vr/vc or scalars: replicate (cheap, O(rows+cols))
        return P(*(None,) * len(shape))

    specs = [find_spec(jax.tree_util.keystr(p), v.shape) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# inputs / caches
# ---------------------------------------------------------------------------

def input_pspecs(cfg: ModelConfig, inputs_shapes: Any, mesh: Mesh):
    """Token/label/embedding inputs: batch over ("pod","data") when it
    divides, else replicate (long_500k has batch 1)."""
    daxes = batch_axes(mesh)

    def rule(path, leaf):
        b = leaf.shape[0] if leaf.shape else 1
        bax = daxes if (daxes and b % _axis_size(mesh, daxes) == 0) else None
        rest = (None,) * (len(leaf.shape) - 1)
        return P(bax, *rest)

    flat, treedef = jax.tree_util.tree_flatten_with_path(inputs_shapes)
    return jax.tree_util.tree_unflatten(treedef, [rule(p, v) for p, v in flat])


def cache_pspecs(cfg: ModelConfig, cache_shapes: Any, mesh: Mesh):
    """KV/SSM cache sharding for decode.

    Leaves are stacked (L_seg, B, S, H, D) / (L_seg, B, S, C) / SSM states
    (L_seg, B, H, P, N) / conv (L_seg, B, W, Cd). Preference order:
    batch -> data; heads/state-channels -> model; else seq -> model/data;
    else replicate.
    """
    daxes = batch_axes(mesh)

    def rule(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = leaf.shape
        lead = (None,)                     # stacked layer dim
        core = shape[1:]
        if "memory" in name:               # enc-dec memory (B, S_enc, D)
            lead, core = (), shape
        # hybrid-period ssm cache stacks twice: (n_periods, k, B, ...)
        if ("conv" in name and len(shape) == 5) or ("ssd" in name and len(shape) == 6):
            lead, core = (None, None), shape[2:]
        spec: list = [None] * len(core)
        # batch dim is core[0]
        if core and core[0] % _axis_size(mesh, daxes) == 0 and _axis_size(mesh, daxes) > 1:
            spec[0] = daxes
            batch_sharded = True
        else:
            batch_sharded = False

        if "conv" in name and len(core) == 3:          # (B, W-1, conv_dim)
            if core[2] % _axis_size(mesh, "model") == 0:
                spec[2] = "model"
        elif "ssd" in name and len(core) == 4:          # (B, H, P, N)
            if core[1] % _axis_size(mesh, "model") == 0:
                spec[1] = "model"
        elif ("'k'" in name or "'v'" in name) and len(core) == 4:  # (B, S, Hk, dh)
            if core[2] % _axis_size(mesh, "model") == 0:
                spec[2] = "model"
                if not batch_sharded and core[1] % _axis_size(mesh, daxes) == 0:
                    spec[1] = daxes
            else:
                # heads indivisible: shard seq as finely as possible
                full = (tuple(daxes) + ("model",)) if (daxes and not batch_sharded) else ("model",)
                if core[1] % _axis_size(mesh, full) == 0:
                    spec[1] = full
                elif core[1] % _axis_size(mesh, "model") == 0:
                    spec[1] = "model"
        elif ("latent" in name or "k_rope" in name) and len(core) == 3:  # (B, S, C)
            full = (tuple(daxes) + ("model",)) if (daxes and not batch_sharded) else ("model",)
            if core[1] % _axis_size(mesh, full) == 0:
                spec[1] = full
            elif core[1] % _axis_size(mesh, "model") == 0:
                spec[1] = "model"
        elif "memory" in name and len(core) == 3:       # (B, S_enc, D)
            pass
        return P(*lead, *spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [rule(p, v) for p, v in flat])
