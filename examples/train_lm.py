"""LM pretraining driver over the architecture zoo (substrate demo).

Default: a ~100M-param llama-family model for a few hundred steps on CPU.
``--smoke`` uses the reduced config (seconds instead of hours); ``--arch``
selects any assigned architecture.

    PYTHONPATH=src python examples/train_lm.py --smoke --steps 50
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import MODEL_CONFIGS
from repro.configs.base import AttentionConfig, ModelConfig
from repro.data.lm_data import batches, zipf_corpus
from repro.optim import warmup_cosine
from repro.train import make_train_state, make_train_step

# ~100M params: 12L, d=768, llama-style
LM100M = ModelConfig(
    name="lm-100m", arch_type="dense",
    citation="example driver config (~100M params)",
    num_layers=12, d_model=768, d_ff=2048, vocab_size=32000,
    attention=AttentionConfig(num_heads=12, num_kv_heads=4, head_dim=64),
    param_dtype="float32", compute_dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m",
                    choices=["lm-100m"] + list(MODEL_CONFIGS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for zoo archs")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = LM100M if args.arch == "lm-100m" else MODEL_CONFIGS[args.arch]
    if args.smoke and args.arch != "lm-100m":
        cfg = cfg.smoke()
    if args.smoke and args.arch == "lm-100m":
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=256, d_ff=512,
                                  vocab_size=2048, name="lm-100m-smoke")

    state = make_train_state(jax.random.key(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name}  params={n_params/1e6:.1f}M  "
          f"steps={args.steps}  batch={args.batch}x{args.seq}")

    sched = warmup_cosine(3e-4, min(50, args.steps // 10 + 1), args.steps)
    step_fn = jax.jit(make_train_step(cfg, lr_schedule=sched))

    rng = np.random.default_rng(0)
    corpus = zipf_corpus(rng, cfg.vocab_size, 2_000_000)
    it = batches(corpus, args.batch, args.seq, cfg=cfg, rng=rng)

    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step_fn(state, next(it))
        if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
            print(f"step {i:5d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt:
        save_pytree(state, args.ckpt, step=args.steps)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
