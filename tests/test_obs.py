"""Tier-1 tests for ``repro.obs`` — registry, spans, exporters, report.

The fast lane runs this file: everything here is stdlib + tiny numpy
shapes except the two integration tests at the bottom, which trace one
tiny real path solve and one serve drain->score round to pin the wiring
(span tree shape, per-phase accounting, legacy-counter bit-identity).
"""
import json
import threading

import numpy as np
import pytest

import jax.numpy as jnp

from repro.obs import (
    MetricsRegistry,
    ObsSession,
    Tracer,
    chrome_trace,
    observe,
    render_summary,
    summarize,
)
from repro.obs import registry as obs_registry
from repro.obs import trace as obs_trace
from repro.obs.registry import _NULL_COUNTER, _NULL_GAUGE, _NULL_HISTOGRAM
from repro.obs.report import main as report_main
from repro.obs.trace import _NULL_SPAN


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("hits") is c            # get-or-create
    reg.gauge("depth").set(7)
    assert reg.gauge("depth").value == 7.0
    h = reg.histogram("lat")
    for v in (0.001, 0.002, 0.004):
        h.observe(v)
    assert h.count == 3 and h.sum == pytest.approx(0.007)


def test_labels_key_separate_instruments():
    reg = MetricsRegistry()
    reg.counter("faults", kind="swap").inc()
    reg.counter("faults", kind="kill").inc(2)
    snap = reg.collect()["counters"]
    assert snap["faults{kind=swap}"] == 1
    assert snap["faults{kind=kill}"] == 2


def test_value_returns_none_for_never_created():
    reg = MetricsRegistry()
    assert reg.value("nope") is None
    reg.counter("yes").inc()
    assert reg.value("yes") == 1


def test_histogram_percentiles_sane():
    h = MetricsRegistry().histogram("lat")
    vals = [i * 1e-3 for i in range(1, 101)]    # 1ms .. 100ms
    for v in vals:
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["min"] == pytest.approx(1e-3)
    assert snap["max"] == pytest.approx(0.1)
    # log-bucketed interpolation: right order of magnitude, clamped range
    assert 0.02 <= snap["p50"] <= 0.08
    assert snap["p50"] <= snap["p95"] <= snap["p99"] <= snap["max"]


def test_empty_histogram_is_json_safe():
    snap = MetricsRegistry().histogram("lat").snapshot()
    assert snap == {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "p50": None, "p95": None, "p99": None}
    json.dumps(snap)                            # no NaN anywhere


def test_callback_mirrors_legacy_dict_lazily():
    reg = MetricsRegistry()
    legacy = {"drained": 0}
    reg.register_callback("serve.batcher", lambda: legacy)
    legacy["drained"] = 9                       # mutate AFTER registration
    assert reg.collect()["callbacks"]["serve.batcher"] == {"drained": 9}


def test_dead_callback_does_not_kill_collect():
    reg = MetricsRegistry()
    reg.register_callback("bad", lambda: 1 / 0)
    out = reg.collect()["callbacks"]["bad"]
    assert "error" in out and "ZeroDivisionError" in out["error"]


def test_disabled_helpers_return_null_singletons():
    assert obs_registry.get_registry() is None
    assert obs_registry.counter("x") is _NULL_COUNTER
    assert obs_registry.gauge("x") is _NULL_GAUGE
    assert obs_registry.histogram("x") is _NULL_HISTOGRAM
    assert obs_trace.get_tracer() is None
    assert obs_trace.span("x") is _NULL_SPAN
    # all no-ops, no errors
    obs_registry.counter("x").inc()
    obs_registry.gauge("x").set(1)
    obs_registry.histogram("x").observe(0.1)
    with obs_trace.span("x") as sp:
        sp.set(k=1)
    obs_trace.event("x")


def test_use_registry_is_reentrant():
    outer, inner = MetricsRegistry(), MetricsRegistry()
    with obs_registry.use_registry(outer):
        obs_registry.counter("n").inc()
        with obs_registry.use_registry(inner):
            obs_registry.counter("n").inc(10)
        obs_registry.counter("n").inc()
    assert obs_registry.get_registry() is None
    assert outer.value("n") == 2 and inner.value("n") == 10


def test_counter_inc_is_thread_safe():
    reg = MetricsRegistry()
    c = reg.counter("n")

    def worker():
        for _ in range(1000):
            c.inc()

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 4000


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def _fake_clock(times):
    it = iter(times)
    return lambda: next(it)


def test_spans_nest_and_record_parents():
    tr = Tracer(clock=_fake_clock([0.0, 1.0, 2.0, 3.0, 4.0]))
    with tr.span("outer", a=1) as outer:
        with tr.span("inner") as inner:
            inner.set(ok=True)
        outer.set(points=2)
    inner_rec, outer_rec = tr.spans          # completion order
    assert inner_rec["name"] == "inner" and inner_rec["args"] == {"ok": True}
    assert inner_rec["parent"] == outer_rec["sid"]
    assert outer_rec["parent"] is None
    assert outer_rec["args"] == {"a": 1, "points": 2}
    # rel to tracer start: construction ate tick 0, outer opened at 1
    assert outer_rec["ts"] == pytest.approx(1.0)
    assert outer_rec["dur"] == pytest.approx(3.0)
    assert inner_rec["dur"] == pytest.approx(1.0)
    assert tr.wall_s() == pytest.approx(4.0)


def test_sibling_threads_get_own_stacks():
    tr = Tracer()
    seen = {}

    def worker(name):
        with tr.span(name):
            pass

    with tr.span("main"):
        t = threading.Thread(target=worker, args=("side",))
        t.start()
        t.join()
    for r in tr.spans:
        seen[r["name"]] = r
    # the side thread's span must NOT have the main thread's span as
    # parent (stacks are thread-local) and gets its own small tid
    assert seen["side"]["parent"] is None
    assert seen["side"]["tid"] != seen["main"]["tid"]


# ---------------------------------------------------------------------------
# export + summary + report
# ---------------------------------------------------------------------------

def _toy_tracer():
    tr = Tracer(clock=_fake_clock([float(i) for i in range(20)]))
    with tr.span("path", path_len=2):
        with tr.span("lambda_point", index=0, lam=0.5) as sp:
            with tr.span("restricted_solve"):
                pass
            sp.set(nnz=3, status=0)
        with tr.span("lambda_point", index=1, lam=0.25) as sp:
            with tr.span("restricted_solve"):
                pass
            sp.set(nnz=5, status=0)
    return tr


def test_chrome_trace_events_are_complete_events():
    doc = chrome_trace(_toy_tracer())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) == 5
    assert all(e["ph"] == "X" for e in evs)
    assert all(set(e) >= {"name", "ts", "dur", "pid", "tid", "args"}
               for e in evs)
    # microseconds: the 1s-per-tick fake clock makes every dur >= 1e6
    assert all(e["dur"] >= 1e6 for e in evs)
    json.dumps(doc)


def test_summarize_phases_and_per_lambda():
    reg = MetricsRegistry()
    reg.counter("faults.kill").inc()
    s = summarize(_toy_tracer(), reg)
    assert s["spans"]["lambda_point"]["count"] == 2
    assert [r["name"] for r in s["roots"]] == ["path"]
    # phases = direct children of the root, grouped by name
    assert set(s["phases"]["path"]) == {"lambda_point"}
    assert len(s["per_lambda"]) == 2
    row = s["per_lambda"][0]
    assert row["index"] == 0 and row["nnz"] == 3
    assert set(row["phases"]) == {"restricted_solve"}
    assert s["counters"]["faults.kill"] == 1


def test_obs_session_export_and_report_cli(tmp_path, capsys):
    sess = ObsSession(_toy_tracer(), MetricsRegistry())
    files = sess.export(str(tmp_path / "run"))
    assert set(files) == {"trace", "events", "summary"}
    with open(files["trace"]) as fh:
        assert json.load(fh)["traceEvents"]
    with open(files["events"]) as fh:
        lines = [json.loads(ln) for ln in fh]
    assert len(lines) == 5 and all("sid" in r for r in lines)
    assert report_main([files["summary"]]) == 0
    out = capsys.readouterr().out
    assert "per-lambda phases" in out and "root span path" in out


def test_render_summary_serve_and_counter_lines():
    reg = MetricsRegistry()
    for v in (0.001, 0.002, 0.003):
        reg.histogram("serve.latency_s").observe(v)
    reg.counter("faults.swap").inc()
    reg.register_callback("residency.tile8",
                          lambda: {"hits": 3, "misses": 1, "evictions": 2,
                                   "bytes_h2d": 64})
    text = render_summary(summarize(None, reg))
    assert "serve submit->score latency (3 requests)" in text
    assert "residency.tile8: hit rate 0.75" in text
    assert "faults.swap=1" in text


# ---------------------------------------------------------------------------
# integration: adapters stay bit-identical; a traced real solve adds up
# ---------------------------------------------------------------------------

def _drive_batcher(batcher):
    from repro.serve import Overloaded

    for i in range(12):
        try:
            batcher.submit({f"tok{i}": 1.0}, 0.5)
        except Overloaded:
            pass
    batcher.drain()
    return dict(batcher.stats)


def test_batcher_stats_bit_identical_with_and_without_obs():
    from repro.serve import RequestBatcher

    def build():
        return RequestBatcher(16, max_batch=8, max_pending=8)

    stats_off = _drive_batcher(build())
    with observe() as obs:
        stats_on = _drive_batcher(build())
        mirrored = obs.registry.collect()["callbacks"]["serve.batcher"]
    assert stats_on == stats_off                 # legacy dict untouched
    assert mirrored == stats_on                  # registry mirrors it


def test_traced_tiny_path_phases_add_up():
    from repro.api import DenseDesign, LogisticL1
    from repro.core.dglmnet import DGLMNETOptions

    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(60, 24)), jnp.float32)
    y = jnp.asarray((rng.random(60) < 0.5).astype(np.float32))
    est = LogisticL1(opts=DGLMNETOptions(num_blocks=4, tile=8, max_iters=5))
    with observe() as obs:
        path = est.path(DenseDesign(X), y, path_len=3)
    s = obs.summary()
    root = s["roots"][0]
    assert root["name"] == "path" and root["args"]["path_len"] == 3
    assert root["args"]["points"] == len(path) == 3
    assert len(s["per_lambda"]) == 3
    for row in s["per_lambda"]:
        assert {"index", "lam", "nnz", "status", "dur_s"} <= set(row)
    # acceptance: direct-child phase totals account for the root wall
    # time to within 5% (gaps = strategy resolution, loop bookkeeping)
    covered = sum(s["phases"]["path"].values())
    assert covered <= root["dur_s"] * 1.0001
    assert covered >= root["dur_s"] * 0.95, (covered, root["dur_s"])
    # untraced rerun is bit-identical (tracing changed no math)
    path2 = est.path(DenseDesign(X), y, path_len=3)
    assert np.array_equal(np.asarray(path.betas), np.asarray(path2.betas))
