"""Per-layer blocks: init + forward for every layer kind.

Kinds:
  attn        — pre-norm attention + dense MLP (SwiGLU/GELU)
  moe         — pre-norm attention + MoE FFN (+ shared expert)
  ssm         — Mamba2 block (norm + SSD + residual)
  hybrid_attn — Zamba2-style: shared attention+MLP block (weights passed in,
                stored once at model level) followed by the layer's own
                Mamba2 block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    attention_forward,
    cross_attention_forward,
    init_attention,
    init_cross_attention,
    init_kv_cache,
)
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import init_moe, moe_forward
from repro.models.ssm import init_mamba2, init_ssm_cache, mamba2_forward
from repro.sharding.ctx import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind == "attn":
        return {
            "ln1": init_norm(d, dtype, cfg.norm),
            "attn": init_attention(ks[0], cfg.attention, d, dtype),
            "ln2": init_norm(d, dtype, cfg.norm),
            "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype, act=cfg.act),
        }
    if kind == "moe":
        return {
            "ln1": init_norm(d, dtype, cfg.norm),
            "attn": init_attention(ks[0], cfg.attention, d, dtype),
            "ln2": init_norm(d, dtype, cfg.norm),
            "moe": init_moe(ks[1], cfg.moe, d, dtype),
        }
    if kind == "ssm":
        return {
            "ln": init_norm(d, dtype, cfg.norm),
            "mamba": init_mamba2(ks[0], cfg.ssm, d, dtype),
        }
    if kind == "hybrid_attn":
        # own mamba block; the shared attention block params live at model level
        return {
            "ln": init_norm(d, dtype, cfg.norm),
            "mamba": init_mamba2(ks[0], cfg.ssm, d, dtype),
        }
    if kind == "hybrid_period":
        # one zamba2 period: attn_every sub-layers (last one applies the
        # shared attention block), stacked on a leading sub-layer axis
        k = cfg.hybrid.attn_every
        sub = jax.random.split(key, k)
        return {"sub": jax.vmap(lambda kk: init_layer(kk, cfg, "ssm", dtype))(sub)}
    raise ValueError(f"unknown layer kind {kind!r}")


def init_shared_attn_block(key, cfg: ModelConfig, dtype):
    """Zamba2 shared transformer block (attention + MLP), stored once."""
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(d, dtype, cfg.norm),
        "attn": init_attention(ks[0], cfg.attention, d, dtype),
        "ln2": init_norm(d, dtype, cfg.norm),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype, act=cfg.act),
    }


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int, dtype):
    """Decode-time cache for one layer of the given kind."""
    if kind in ("attn", "moe"):
        return {"kv": init_kv_cache(cfg.attention, cfg.d_model, batch, cache_len, dtype)}
    if kind == "ssm":
        return {"ssm": init_ssm_cache(cfg.ssm, cfg.d_model, batch, dtype)}
    if kind == "hybrid_attn":
        return {
            "kv": init_kv_cache(cfg.attention, cfg.d_model, batch, cache_len, dtype),
            "ssm": init_ssm_cache(cfg.ssm, cfg.d_model, batch, dtype),
        }
    if kind == "hybrid_period":
        k = cfg.hybrid.attn_every
        one = init_ssm_cache(cfg.ssm, cfg.d_model, batch, dtype)
        return {
            "ssm": jax.tree.map(lambda x: jnp.broadcast_to(x[None], (k,) + x.shape), one),
            # only the last sub-layer attends; a single KV cache per period
            "kv": init_kv_cache(cfg.attention, cfg.d_model, batch, cache_len, dtype),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn_sub(p, x, cfg, positions, mode, cache, cache_index, window, window_slice):
    h = apply_norm(p["ln1"], x, kind=cfg.norm, eps=cfg.norm_eps)
    y, new_kv = attention_forward(
        p["attn"], h, cfg=cfg.attention, d_model=cfg.d_model, positions=positions,
        mode=mode, cache=cache, cache_index=cache_index, window=window,
        window_slice=window_slice,
    )
    return x + y, new_kv


def layer_forward(
    p,
    x: jnp.ndarray,
    *,
    cfg: ModelConfig,
    kind: str,
    positions: jnp.ndarray,
    mode: str = "train",                   # train | prefill | decode
    cache: Optional[dict] = None,
    cache_index=None,
    window: int = 0,
    window_slice: bool = False,
    shared_block=None,                      # zamba2 shared attn+mlp params
    deterministic: bool = True,
) -> Tuple[jnp.ndarray, Optional[dict], dict]:
    aux = {}
    new_cache = {}

    # layer-boundary residual: shard d_model over `model` so the remat-saved
    # per-layer stack is 1/16th (sequence-parallel-style; XLA re-gathers at
    # the first use inside the layer). Critical for the 512-dev dry-run fit.
    if mode == "train":
        x = constrain(x, "batch", None, "model")

    if kind in ("attn", "moe"):
        kv = cache.get("kv") if cache else None
        x, new_kv = _attn_sub(p, x, cfg, positions, mode, kv, cache_index, window, window_slice)
        if new_kv is not None:
            new_cache["kv"] = new_kv
        h = apply_norm(p["ln2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        if kind == "attn":
            x = x + apply_mlp(p["mlp"], h, act=cfg.act)
        else:
            y, aux = moe_forward(p["moe"], h, cfg=cfg.moe, deterministic=deterministic)
            x = x + y
        return x, (new_cache or None), aux

    if kind == "hybrid_period":
        # k-1 plain mamba sub-layers, then one hybrid (shared-attn + mamba)
        k = cfg.hybrid.attn_every
        new_ssm, kv_cache = [], None
        for j in range(k):
            p_j = jax.tree.map(lambda a: a[j], p["sub"])
            sub_kind = "hybrid_attn" if j == k - 1 else "ssm"
            c_j = None
            if cache is not None:
                c_j = {"ssm": jax.tree.map(lambda a: a[j], cache["ssm"])}
                if sub_kind == "hybrid_attn":
                    c_j["kv"] = cache["kv"]
            x, new_c, _ = layer_forward(
                p_j, x, cfg=cfg, kind=sub_kind, positions=positions, mode=mode,
                cache=c_j, cache_index=cache_index, window=window,
                window_slice=window_slice, shared_block=shared_block,
                deterministic=deterministic,
            )
            if new_c is not None:
                new_ssm.append(new_c["ssm"])
                if "kv" in new_c:
                    kv_cache = new_c["kv"]
        if new_ssm:
            out_cache = {"ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_ssm)}
            if kv_cache is not None:
                out_cache["kv"] = kv_cache
            return x, out_cache, aux
        return x, None, aux

    if kind in ("ssm", "hybrid_attn"):
        if kind == "hybrid_attn":
            assert shared_block is not None, "hybrid layer needs the shared block"
            kv = cache.get("kv") if cache else None
            x, new_kv = _attn_sub(
                shared_block, x, cfg, positions, mode, kv, cache_index,
                window or (cfg.long_context_window if window_slice else 0), window_slice,
            )
            if new_kv is not None:
                new_cache["kv"] = new_kv
            hmlp = apply_norm(shared_block["ln2"], x, kind=cfg.norm, eps=cfg.norm_eps)
            x = x + apply_mlp(shared_block["mlp"], hmlp, act=cfg.act)
        h = apply_norm(p["ln"], x, kind=cfg.norm, eps=cfg.norm_eps)
        y, new_ssm = mamba2_forward(
            p["mamba"], h, cfg=cfg.ssm, d_model=cfg.d_model,
            mode=mode, cache=(cache.get("ssm") if cache else None),
        )
        if new_ssm is not None:
            new_cache["ssm"] = new_ssm
        return x + y, (new_cache or None), aux

    raise ValueError(f"unknown layer kind {kind!r}")


# ---------------------------------------------------------------------------
# encoder / decoder blocks (seamless-m4t)
# ---------------------------------------------------------------------------

def init_encoder_layer(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(d, dtype, cfg.norm),
        "attn": init_attention(ks[0], cfg.attention, d, dtype),
        "ln2": init_norm(d, dtype, cfg.norm),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, dtype, act=cfg.act),
    }


def encoder_layer_forward(p, x, *, cfg: ModelConfig, positions):
    h = apply_norm(p["ln1"], x, kind=cfg.norm, eps=cfg.norm_eps)
    y, _ = attention_forward(
        p["attn"], h, cfg=cfg.attention, d_model=cfg.d_model,
        positions=positions, mode="train", causal=False,  # bidirectional
    )
    x = x + y
    h = apply_norm(p["ln2"], x, kind=cfg.norm, eps=cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, act=cfg.act)


def init_decoder_layer(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "ln1": init_norm(d, dtype, cfg.norm),
        "attn": init_attention(ks[0], cfg.attention, d, dtype),
        "ln_x": init_norm(d, dtype, cfg.norm),
        "xattn": init_cross_attention(ks[1], cfg.attention, d, dtype),
        "ln2": init_norm(d, dtype, cfg.norm),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, dtype, act=cfg.act),
    }


def decoder_layer_forward(
    p, x, memory, *, cfg: ModelConfig, positions, mode="train",
    cache=None, cache_index=None,
):
    kv = cache.get("kv") if cache else None
    x, new_kv = _attn_sub(p, x, cfg, positions, mode, kv, cache_index, 0, False)
    h = apply_norm(p["ln_x"], x, kind=cfg.norm, eps=cfg.norm_eps)
    x = x + cross_attention_forward(p["xattn"], h, memory, cfg=cfg.attention, d_model=cfg.d_model)
    h = apply_norm(p["ln2"], x, kind=cfg.norm, eps=cfg.norm_eps)
    x = x + apply_mlp(p["mlp"], h, act=cfg.act)
    new_cache = {"kv": new_kv} if new_kv is not None else None
    return x, new_cache
