"""Core NN layers, functional style.

Parameters are plain dict pytrees created by ``init_*`` helpers; forward
functions are pure. No flax/haiku — the substrate is hand-rolled per the
assignment. Initializers mirror common practice (truncated-normal fan-in
for projections, ones for norm scales).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, dtype, stddev):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float = 1.0):
    """Fan-in scaled init for a (d_in, d_out) projection."""
    return _normal(key, (d_in, d_out), dtype, scale / math.sqrt(max(d_in, 1)))


def embed_init(key, vocab: int, d: int, dtype):
    return _normal(key, (vocab, d), dtype, 1.0)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(d: int, dtype, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, *, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    y = y * p["scale"].astype(jnp.float32)
    if kind == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def gated_rmsnorm(scale, x, gate, *, eps: float = 1e-5):
    """Mamba2's norm: RMSNorm(x * silu(gate)) (norm-before-gate=False)."""
    xf = x.astype(jnp.float32) * jax.nn.silu(gate.astype(jnp.float32))
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, *, act: str = "silu"):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU: gate + up + down
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def apply_mlp(p, x, *, act: str = "silu"):
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, Dh) or (..., S, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                      # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    if x.ndim == ang.ndim + 1:                         # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions_thw: jnp.ndarray,  # (..., S, 3): temporal/height/width position ids
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """Qwen2-VL M-RoPE (arXiv:2409.12191): the rotary half-dim is split into
    (t, h, w) sections, each rotated by its own position stream."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                      # (half,)
    # section id per frequency index
    sec_sizes = jnp.array(sections)
    sec_id = jnp.repeat(jnp.arange(3), sec_sizes, total_repeat_length=half)  # (half,)
    # pick the position stream per frequency
    pos = jnp.take_along_axis(
        positions_thw.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions_thw.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )                                                   # (..., S, half)
    ang = pos * freqs                                   # (..., S, half)
    if x.ndim == ang.ndim + 1:
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """For pure-text streams all three M-RoPE position ids coincide."""
    return jnp.stack([positions, positions, positions], axis=-1)
