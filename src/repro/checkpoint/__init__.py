from repro.checkpoint.checkpointer import (  # noqa: F401
    load_pytree,
    read_meta,
    save_pytree,
)
