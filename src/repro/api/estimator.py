"""``LogisticL1`` — the single front door for every d-GLMNET solve.

One estimator replaces the five parallel entry points that accreted over
the scaling PRs (``fit``, ``fit_distributed``, ``fit_distributed_sparse``,
``regularization_path``, ``regularization_path_distributed``; all still
importable as thin delegating shims):

* ``fit(design, y, lam)``   — one solve, any layout, local or mesh;
* ``path(design, y)``       — the warm-started, screened regularization
  path (paper Algorithm 5) with the strong-rule/KKT engine, blitz-style
  working-set carry, and per-lambda metric streaming;
* ``predict_proba`` / ``decision_function`` — scoring through the design
  (on-mesh margins for sharded designs — no replicated test matrix).

The estimator never branches on layout itself: the
:class:`~repro.api.design.Design` answers the data questions and the
:mod:`~repro.api.strategy` resolver picks the execution plan, so a new
layout is a new Design (plus, at most, a resolver rule) — not a sixth
entry point.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.design import (
    BucketedSlabDesign,
    DenseDesign,
    Design,
    ShardedDesign,
    SlabDesign,
    as_design,
)
from repro.api.strategy import Strategy, resolve
from repro.core import engine
from repro.core.dglmnet import DGLMNETOptions, FitResult
from repro.core.dglmnet import _solver_for as _local_solver_for
from repro.core.distributed import (
    DistributedFitResult,
    _data_extent,
    _finish,
    _solver_for as _mesh_solver_for,
    _solver_sparse_for,
    check_slab_shapes,
    make_slab_densifier,
    make_slab_margins,
)
from repro.core.objective import margins, objective
from repro.core.screening import (
    budgeted_admission,
    capacity_bucket,
    kkt_violations,
    strong_rule_mask,
)
from repro.api.types import (  # noqa: F401  (re-export: path output)
    PathPoint,
    PathResult,
    _jsonable,
)
from repro.core.screening import _nll_residual
from repro.data.byfeature import k_class, scatter_features
from repro.data.residency import put_slab
from repro.obs import trace as obs_trace
from repro.resilience import PathProgress, maybe_kill
from repro.sharding.collect import replicate


def lambda_max_design(design: Design, y):
    """Smallest lambda for which beta* = 0, from the design's correlation
    pass: ``max_j |x_j^T (0.5 y)|`` (at beta = 0 the NLL residual is
    exactly ``-y/2``). The ONE lambda_max implementation — the dense
    ``core.objective.lambda_max`` and the sparse screen's m = 0 pass both
    route through it, so dense and slab layouts agree bit-for-bit."""
    y = jnp.asarray(y, jnp.float32)
    return jnp.max(jnp.abs(design.correlation(0.5 * y)))


def _lambda_grid(lmax: float, path_len: int,
                 extra_lams: Optional[List[float]]) -> List[float]:
    lams = [lmax * 2.0 ** (-i) for i in range(1, path_len + 1)]
    if extra_lams:
        lams = sorted(set(lams) | set(extra_lams), reverse=True)
    return lams


def _screened_point(p_cap, lam, lam_prev, beta, m, *, grad_abs,
                    restricted_solve, empty_result, cap_tile, kkt_tol,
                    max_kkt_rounds, prev_mask=None,
                    violation_budget: Optional[int] = 512):
    """One path point of the strong-rule/KKT loop, solver- and
    layout-agnostic (masks and beta live on the original feature axis;
    ``p_cap`` is the capacity ceiling — the mesh-padded work extent for
    sharded slab designs).

    ``grad_abs(m) -> |g|`` is the full-gradient pass (the design's
    correlation at the NLL residual); ``restricted_solve(mask, cap, beta)
    -> (res, beta_full, m_full)`` solves the capacity-``cap`` restricted
    problem warm-started from ``beta``. Only the active-set and violation
    *counts* are synced to host (to pick the capacity bucket and decide
    termination) — the solves themselves stay device-resident.

    Blitz-style dynamic working-set growth (Johnson & Guestrin):
    ``prev_mask`` carries the working set across path points instead of
    resetting it to the strong rule each lambda. Within a point, violators
    re-enter under a per-round budget of ``min(violation_budget, 2 * |A|)``
    (the strongest first). The final certification is unchanged: the loop
    only exits on a clean KKT pass over everything outside the working set
    (the penultimate round lifts the budget so certification can always
    complete within ``max_kkt_rounds``). Returns the certified mask
    alongside the result for the driver to carry.

    Trace spans (``repro.obs``) bracket the phases at the host syncs the
    loop already performs — the working-set count fetch (screen_round),
    the restricted solve's own fetch (restricted_solve), the violation
    count fetch (kkt_check). Async dispatch between syncs is attributed
    to the span owning the next sync; no new fetch is ever added.
    """
    g_abs = grad_abs(m)
    mask = strong_rule_mask(g_abs, lam, lam_prev, beta)
    if prev_mask is not None:
        mask = jnp.logical_or(mask, prev_mask)

    res = None
    rounds = 0
    cap = 0
    deferred = 0
    for rounds in range(1, max_kkt_rounds + 1):
        with obs_trace.span("screen_round", round=rounds) as sr:
            count = int(engine.device_get(mask.sum()))
            sr.set(active=count)
        if count == 0:
            # empty working set: beta stays 0 (strong rule + no support)
            beta_new, m_new = beta, m
            res = empty_result(beta)
        else:
            cap = capacity_bucket(count, p_cap, tile=cap_tile)
            with obs_trace.span("restricted_solve", active=count,
                                capacity=cap):
                res, beta_new, m_new = restricted_solve(mask, cap, beta)
            if getattr(res, "status", 0):
                # Guardrail trip inside the restricted solve: certification
                # cannot proceed on a degraded iterate. Bail out with the
                # *input* state (the last certified path point) intact —
                # the path driver's degradation ladder owns the recovery.
                info = {"active": count, "capacity": cap,
                        "kkt_rounds": rounds, "deferred": deferred,
                        "status": int(res.status)}
                return res, beta, m, info, mask
        with obs_trace.span("kkt_check", round=rounds) as kk:
            g_abs = grad_abs(m_new)
            viol = kkt_violations(g_abs, lam, mask, tol=kkt_tol)
            n_viol = int(engine.device_get(viol.sum()))
            kk.set(violations=n_viol)
        if n_viol == 0:
            break
        if violation_budget is not None and rounds < max_kkt_rounds - 1:
            budget = min(violation_budget, 2 * max(count, 1))
            admitted = budgeted_admission(viol, g_abs, budget)
            # ties at the cutoff may admit more than the budget — count
            # what actually stayed out, not the nominal overflow
            deferred += n_viol - int(engine.device_get(admitted.sum()))
        else:
            admitted = viol                       # safety valve: admit all
        mask = jnp.logical_or(mask, admitted)     # violators re-enter
        beta, m = beta_new, m_new                 # keep this round's progress
    else:
        raise RuntimeError(
            f"KKT check failed to certify within {max_kkt_rounds} rounds "
            f"at lambda={lam} (last violation count > 0)"
        )

    info = {"active": int(engine.device_get(mask.sum())), "capacity": cap,
            "kkt_rounds": rounds, "deferred": deferred}
    return res, beta_new, m_new, info, mask


def _save_progress(progress: PathProgress, pt_idx: int, lams, lam_prev,
                   beta, m, carry_mask, points, p: int, p_cap: int) -> None:
    """Checkpoint the path driver's warm-start chain + emitted points as
    one rotated :class:`repro.resilience.PathProgress` slot (atomic
    publish, CRC-verified payload). float32 arrays round-trip npz exactly
    and the JSON meta round-trips Python floats exactly, so a resume
    continues bit-identically."""
    tree = {
        "beta": beta,
        "m": m,
        "carry_mask": (carry_mask.astype(jnp.int8) if carry_mask is not None
                       else jnp.zeros((1,), jnp.int8)),
        # allow[sharded-concat]: path-point betas are replicated rows (mesh points collect through sharding.collect.replicate before emission)
        "point_betas": (jnp.stack([pt.beta for pt in points]) if points
                        else jnp.zeros((0, p), jnp.float32)),
    }
    meta = {
        "kind": "PathProgress",
        "next_index": pt_idx + 1,
        "lam_prev": float(lam_prev),
        "lams": [float(v) for v in lams],
        "p": int(p),
        "p_cap": int(p_cap),
        "has_carry_mask": carry_mask is not None,
        "points": [
            {"lam": float(pt.lam), "nnz": int(pt.nnz), "f": float(pt.f),
             "n_iters": int(pt.n_iters), "metrics": _jsonable(pt.metrics),
             "screen": _jsonable(pt.screen), "status": int(pt.status)}
            for pt in points
        ],
    }
    progress.save(pt_idx, tree, meta)


# ---------------------------------------------------------------------------
# solve implementations (one per strategy cell; the legacy entry points
# used to own these bodies)
# ---------------------------------------------------------------------------

def _fit_local_dense(X, y, lam, opts: DGLMNETOptions, beta0,
                     verbose: bool) -> FitResult:
    """Single-process dense solve: paper Algorithm 1 with the Algorithm 3
    line search, run entirely on device as one jitted while_loop
    (core/engine.py)."""
    n, p = X.shape
    beta = (jnp.zeros(p, jnp.float32) if beta0 is None
            else beta0.astype(jnp.float32))
    m = margins(X, beta)

    state = _local_solver_for(opts)(X, y, beta, m, lam)
    host, hist, alphas = engine.fetch(state)       # the one d2h transfer
    it = int(host.it)
    if verbose:
        for k in range(1, it + 1):
            print(f"  iter {k:3d}  f={hist[k]:.6f}  alpha={alphas[k - 1]:.4f}")

    return FitResult(
        beta=state.beta,
        f=hist[-1],
        n_iters=it,
        objective_history=hist,
        alpha_history=alphas,
        unit_step_frac=int(host.unit_steps) / max(it, 1),
        converged=bool(host.converged),
        status=int(host.status),
    )


def _fit_mesh_dense(X, y, lam, mesh, opts: DGLMNETOptions, beta0,
                    verbose: bool) -> DistributedFitResult:
    """Mesh dense solve (X P(data, model), beta P(model)) — the same
    device-resident engine loop as the local driver, subproblems under
    shard_map."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import _data_axes

    daxes = _data_axes(mesh)
    n, p = X.shape
    ddim = _data_extent(mesh)
    mdim = mesh.shape["model"]
    if n % ddim:
        raise ValueError(
            f"data extent {ddim} must divide n={n} (trim or pad upstream)"
        )
    # zero feature columns are safe padding: their coordinates stay at 0
    pad = (-p) % (mdim * opts.tile)
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        if beta0 is not None:
            beta0 = jnp.pad(beta0, (0, pad))
    xsharding = NamedSharding(mesh, P(daxes, "model"))
    vsharding = NamedSharding(mesh, P(daxes))
    bsharding = NamedSharding(mesh, P("model"))

    X = jax.device_put(X, xsharding)
    y = jax.device_put(y, vsharding)
    beta = (
        jnp.zeros(X.shape[1], jnp.float32) if beta0 is None
        else beta0.astype(jnp.float32)
    )
    beta = jax.device_put(beta, bsharding)
    m = jax.device_put(margins(X, beta), vsharding)

    state = _mesh_solver_for(mesh, opts, "model")(X, y, beta, m, lam)
    return _finish(state, p, pad, verbose, "dist")


def _fit_mesh_slab(row_idx, values, y, lam, mesh, strat: Strategy, beta0,
                   verbose: bool) -> DistributedFitResult:
    """Mesh by-feature slab solve (p, DP, K) — the webspam-scale layout
    where a dense X can never exist on any machine. The subproblem family
    is the strategy's per-solve densify decision (``prefer_slab_gram``
    heuristic or explicit override): sparse-native slab kernels, or one
    O(nnz) on-mesh densify per solve feeding the dense MXU subproblem."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import _data_axes

    opts = strat.opts
    daxes = _data_axes(mesh)
    n = y.shape[0]
    n_loc = check_slab_shapes(row_idx, values, mesh, n)
    mdim = mesh.shape["model"]
    p = row_idx.shape[0]
    # sentinel-row feature padding is safe: all-sentinel slabs contribute
    # nothing to any Gram tile, so their coordinates stay at 0
    pad = (-p) % (mdim * opts.tile)
    if pad:
        row_idx = jnp.pad(row_idx, ((0, pad), (0, 0), (0, 0)),
                          constant_values=n_loc)
        values = jnp.pad(values, ((0, pad), (0, 0), (0, 0)))
        if beta0 is not None:
            beta0 = jnp.pad(beta0, (0, pad))
    slab_sharding = NamedSharding(mesh, P("model", daxes, None))
    vsharding = NamedSharding(mesh, P(daxes))
    bsharding = NamedSharding(mesh, P("model"))

    # transient working-set slabs go through the residency module's door
    # (single-home rule); they are not budget-managed — a restricted
    # solve's operands must be resident for the solve regardless
    row_idx, values = put_slab(row_idx, values, slab_sharding)
    y = jax.device_put(y, vsharding)
    beta = (
        jnp.zeros(row_idx.shape[0], jnp.float32)
        if beta0 is None else beta0.astype(jnp.float32)
    )
    beta = jax.device_put(beta, bsharding)
    if beta0 is None:
        m = jax.device_put(jnp.zeros(n, jnp.float32), vsharding)
    else:
        m = make_slab_margins(mesh, n_loc)(row_idx, values, beta)

    if strat.use_densify(n_loc, row_idx.shape[2]):
        X = make_slab_densifier(mesh, n_loc)(row_idx, values)
        state = _mesh_solver_for(mesh, opts, "model")(X, y, beta, m, lam)
        return _finish(state, p, pad, verbose, "dist-sparse-dense")

    state = _solver_sparse_for(mesh, opts, "model")(
        (row_idx, values), y, beta, m, lam
    )
    return _finish(state, p, pad, verbose, "dist-sparse")


def _solve(design: Design, y, lam, strat: Strategy, *, beta0=None,
           verbose: bool = False):
    """Dispatch one solve to the strategy's implementation cell."""
    if strat.execution == "local":
        X = design.X if design.layout == "dense" else design.densify()
        return _fit_local_dense(X, y, lam, strat.opts, beta0, verbose)
    inner = design.inner
    if design.layout == "dense":
        return _fit_mesh_dense(inner.X, y, lam, design.mesh, strat.opts,
                               beta0, verbose)
    if design.layout == "slab":
        return _fit_mesh_slab(inner.row_idx, inner.values, y, lam,
                              design.mesh, strat, beta0, verbose)
    # bucketed on a mesh: flatten through the bucket gather at the max K
    # class, solve the flat slab problem, scatter back to original order
    # (one work axis throughout: strat.opts.tile, not the design default)
    tile = strat.opts.tile
    st = design._mesh_state(tile)
    p = design.shape[1]
    beta_full = (jnp.zeros(p, jnp.float32) if beta0 is None
                 else beta0.astype(jnp.float32))
    beta_work = jnp.take(beta_full, st.feat_map, mode="fill", fill_value=0.0)
    mask_work = jnp.ones(st.p_work, bool)
    sub, beta_sub, idx = design._gather_work(beta_work, mask_work,
                                             st.p_work, st.k_max, tile=tile)
    res = _fit_mesh_slab(sub.inner.row_idx, sub.inner.values, y, lam,
                         design.mesh, strat, beta_sub, verbose)
    res.beta = design._work_to_original(
        scatter_features(res.beta, idx, st.p_work), tile=tile)
    return res


# ---------------------------------------------------------------------------
# the estimator
# ---------------------------------------------------------------------------

@dataclass
class LogisticL1:
    """L1-regularized logistic regression via d-GLMNET, any layout.

    ``opts`` carries the solver knobs (validated eagerly); ``mesh`` (or a
    :class:`ShardedDesign` input) selects distributed execution. With
    ``warm_start=True``, successive ``fit`` calls seed from the previous
    solution (``beta_``).
    """

    opts: DGLMNETOptions = field(default_factory=DGLMNETOptions)
    mesh: Optional[object] = None
    warm_start: bool = False
    beta_: Optional[jnp.ndarray] = field(default=None, repr=False)
    lam_: Optional[float] = field(default=None, repr=False)

    def _design(self, data, y=None) -> Design:
        n = None if y is None else int(jnp.shape(y)[0])
        design = as_design(data, n=n, mesh=self.mesh, tile=self.opts.tile,
                           device_budget_bytes=self.opts.device_budget_bytes)
        if (self.mesh is not None and isinstance(design, ShardedDesign)
                and design.mesh is not self.mesh):
            raise ValueError(
                "design is sharded over a different mesh than the estimator's"
            )
        if (isinstance(design, ShardedDesign)
                and self.opts.device_budget_bytes is not None
                and design.device_budget_bytes
                != self.opts.device_budget_bytes):
            if design._states:
                # residency already built under the design's own budget —
                # rebuilding would double device memory, mirroring the
                # tile-mismatch warning below
                import warnings

                warnings.warn(
                    f"ShardedDesign residency was already built with "
                    f"device_budget_bytes={design.device_budget_bytes} but "
                    f"the estimator opts say "
                    f"{self.opts.device_budget_bytes}; keeping the existing "
                    f"residency — construct the design with the same budget "
                    f"to silence this", stacklevel=3)
            else:
                design.device_budget_bytes = self.opts.device_budget_bytes
        if (isinstance(design, ShardedDesign) and design.layout != "dense"
                and design._states and self.opts.tile not in design._states):
            # the estimator threads opts.tile through every work-axis
            # helper (one consistent axis regardless of the design's own
            # tile), and public design methods lazily reuse whatever
            # residency exists — so a duplicate O(nnz) slab residency only
            # arises when the design is *already* resident at a different
            # tile. Warn rather than silently doubling device memory.
            import warnings

            warnings.warn(
                f"ShardedDesign is mesh-resident at tile="
                f"{sorted(design._states)} but the estimator uses "
                f"tile={self.opts.tile}; this puts a second copy of the "
                f"slabs on the mesh — construct the design with "
                f"tile={self.opts.tile} (or reuse one DGLMNETOptions) to "
                f"share one residency", stacklevel=3)
        return design

    # -- one solve ---------------------------------------------------------

    def fit(self, data, y, lam: float, *, beta0=None, verbose: bool = False,
            densify: Optional[bool] = None):
        """One solve at ``lam``. Returns :class:`FitResult` (local) or
        :class:`DistributedFitResult` (mesh). ``densify`` overrides the
        slab solver's densify-once heuristic."""
        design = self._design(data, y)
        strat = resolve(design, self.opts, densify=densify)
        if beta0 is None and self.warm_start and self.beta_ is not None:
            beta0 = self.beta_
        res = _solve(design, y, lam, strat, beta0=beta0, verbose=verbose)
        self.beta_, self.lam_ = res.beta, lam
        return res

    # -- scoring -----------------------------------------------------------

    def decision_function(self, data, *, beta=None):
        """X @ beta through the design (on-mesh slab margins for sharded
        designs, replicated before returning).

        Lambda selection: with ``beta=None`` the scores come from the
        estimator's current coefficients (``beta_`` — the LAST solve, i.e.
        the smallest lambda after ``path``). To score at a specific path
        operating point, pass ``beta=`` a row of ``PathResult.betas`` (or
        serve the whole path batched via :class:`repro.serve.PathStore`,
        which keeps every lambda device-resident)."""
        design = self._design(data)
        beta = self.beta_ if beta is None else beta
        if beta is None:
            raise ValueError("not fitted and no beta= given")
        scores = design.margins(beta)
        if isinstance(design, ShardedDesign):
            scores = replicate(scores, design.mesh)
        return scores

    def predict_proba(self, data, *, beta=None):
        """P(y = +1 | x) = sigmoid(X @ beta). Lambda selection follows
        :meth:`decision_function` (``beta=None`` = last fitted lambda;
        pass a ``PathResult`` beta row for a specific operating point)."""
        return jax.nn.sigmoid(self.decision_function(data, beta=beta))

    def predict(self, data, *, beta=None, threshold: float = 0.0):
        """Hard labels in {-1, +1} at a margin ``threshold`` (0.0 =
        P(y=+1) >= 0.5), matching the +-1 label convention the logistic
        NLL is written in."""
        scores = self.decision_function(data, beta=beta)
        return jnp.where(scores >= threshold, 1.0, -1.0).astype(jnp.float32)

    # -- sklearn-style surface ---------------------------------------------

    @property
    def coef_(self):
        """Fitted coefficients (p,) — sklearn naming for ``beta_``."""
        return self.beta_

    @property
    def intercept_(self) -> float:
        """Always 0.0: d-GLMNET (paper Algorithm 1) fits no intercept —
        append a constant feature column if one is needed."""
        return 0.0

    _PARAM_NAMES = ("opts", "mesh", "warm_start")

    def get_params(self, deep: bool = True) -> dict:
        """sklearn-style constructor-parameter dict (``deep`` accepted for
        signature compatibility; ``opts`` is returned as-is)."""
        return {name: getattr(self, name) for name in self._PARAM_NAMES}

    def set_params(self, **params) -> "LogisticL1":
        """sklearn-style parameter update; unknown names raise."""
        for name, value in params.items():
            if name not in self._PARAM_NAMES:
                raise ValueError(
                    f"unknown parameter {name!r} for LogisticL1: valid "
                    f"parameters are {self._PARAM_NAMES}"
                )
            setattr(self, name, value)
        return self

    # -- the regularization path -------------------------------------------

    def path(
        self,
        data,
        y,
        *,
        path_len: int = 20,
        eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
        extra_lams: Optional[List[float]] = None,
        verbose: bool = False,
        screen: bool = True,
        kkt_tol: float = 1e-3,
        max_kkt_rounds: int = 8,
        carry_working_set: bool = True,
        violation_budget: Optional[int] = 512,
        densify: Optional[bool] = None,
        checkpoint_every: Optional[int] = None,
        resume_from: Optional[str] = None,
    ) -> PathResult:
        """Warm-started screened regularization path (paper Algorithm 5):
        lambda = lambda_max * 2^{-i}, i = 1..path_len, each point solved
        restricted to the strong-rule/KKT-certified working set
        (capacity-bucketed so the whole path reuses a handful of compiled
        programs), warm-started from the previous solution.

        Returns a :class:`PathResult` — the whole path's coefficients as
        one stacked ``(L, p)`` array plus per-lambda metrics/telemetry.
        It iterates and indexes like the historical list of
        :class:`PathPoint`, and ``PathResult.save``/``load`` persist it
        for fit-once/serve-many (:class:`repro.serve.PathStore`).

        ``eval_fn(beta)`` computes per-lambda test metrics (the paper's
        Figure 1); pair it with :func:`make_design_eval` to stream
        AUPRC/accuracy through a (sharded) test design instead of
        replicating a test matrix on the host. ``screen=False`` reproduces
        the full-p warm-started loop (the screening tests' oracle).
        ``carry_working_set``/``violation_budget`` are the blitz-style
        growth knobs (see :func:`_screened_point`).

        Robustness (PR 8): each point's solve carries the engine's typed
        ``status``; on a guardrail trip the driver degrades per-lambda —
        re-warm-start from the previous certified point without the
        carried working set, then (``cycle_mode="blocked"``) fall back to
        the sequential cycle, then skip-and-mark the point (beta/m stay at
        the last certified state so the warm-start chain never ingests
        garbage). ``resume_from=`` names a progress directory
        (:class:`repro.resilience.PathProgress`): existing progress there
        is resumed bit-identically from the last certified point;
        ``checkpoint_every=k`` (requires ``resume_from``) checkpoints
        every k-th point into it with atomic publish + CRC integrity.

        Observability: under an active ``repro.obs`` tracer the solve
        emits the ``path > lambda_grid / lambda_point > {screen_round,
        restricted_solve, kkt_check, point_finish}`` span tree, with
        per-point nnz/f/status attached to each ``lambda_point``. Spans
        close at host syncs the driver already performs — tracing adds
        no device->host transfer and no compile, and with no tracer
        active every span call is a no-op (certified by
        ``tests/test_sanitizers.py``).
        """
        with obs_trace.span("path", path_len=path_len,
                            screen=screen) as sp:
            result = self._path_impl(
                data, y, path_len=path_len, eval_fn=eval_fn,
                extra_lams=extra_lams, verbose=verbose, screen=screen,
                kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
                carry_working_set=carry_working_set,
                violation_budget=violation_budget, densify=densify,
                checkpoint_every=checkpoint_every,
                resume_from=resume_from,
            )
            sp.set(points=len(result))
            return result

    def _path_impl(
        self,
        data,
        y,
        *,
        path_len: int,
        eval_fn: Optional[Callable[[jnp.ndarray], dict]],
        extra_lams: Optional[List[float]],
        verbose: bool,
        screen: bool,
        kkt_tol: float,
        max_kkt_rounds: int,
        carry_working_set: bool,
        violation_budget: Optional[int],
        densify: Optional[bool],
        checkpoint_every: Optional[int],
        resume_from: Optional[str],
    ) -> PathResult:
        design = self._design(data, y)
        strat = resolve(design, self.opts, densify=densify)
        opts = strat.opts
        n = int(jnp.shape(y)[0])
        n_d, p = design.shape
        if n_d != n:
            raise ValueError(f"X rows {n_d} != len(y) {n}")

        sharded = isinstance(design, ShardedDesign)
        # the work-axis fast path only matters under screening (grad
        # passes + masked gathers); screen=False carries beta in design
        # order through full solves
        slab_mesh = (sharded and screen
                     and design.layout in ("slab", "bucketed"))
        front_packed = getattr(
            design.inner if sharded else design, "front_packed", True)
        to_output = None               # work-axis beta -> original order

        if slab_mesh:
            # Work-axis fast path: the driver state (beta, masks, g_abs)
            # lives on the mesh-padded bucket-permuted feature axis, so
            # every per-lambda pass is the per-bucket jitted screen — no
            # eager elementwise dispatch on sharded arrays and no order
            # conversion until a PathPoint is emitted.
            st = design._mesh_state(opts.tile)
            p_cap = st.p_work
            y = jax.device_put(jnp.asarray(y, jnp.float32),
                               design.vsharding())
            m = jax.device_put(jnp.zeros(n, jnp.float32), design.vsharding())

            def grad_abs(m_cur):
                return design._screen_abs_work(y, m_cur, tile=opts.tile)

            def make_restricted_solve(lam, strat_=strat):
                def restricted_solve(mask_work, cap, beta_work):
                    if front_packed:
                        # slab-capacity class of this working set: heavy
                        # features only make a solve pay for K they carry
                        k_need = int(engine.device_get(jnp.max(
                            jnp.where(mask_work, st.k_arr, 0))))
                        k_cap = k_class(k_need, st.k_max)
                    else:
                        k_cap = st.k_max
                    sub, beta_sub, idx = design._gather_work(
                        beta_work, mask_work, cap, k_cap, tile=opts.tile)
                    res = _solve(sub, y, lam, strat_, beta0=beta_sub)
                    return res, scatter_features(res.beta, idx, st.p_work), \
                        res.m
                return restricted_solve

            def to_output(beta_work):
                return design._work_to_original(beta_work, tile=opts.tile)
        else:
            p_cap = p
            m = jnp.zeros(n, jnp.float32)

            def grad_abs(m_cur):
                return jnp.abs(design.correlation(_nll_residual(m_cur, y)))

            def make_restricted_solve(lam, strat_=strat):
                def restricted_solve(mask, cap, beta_cur):
                    sub, beta_sub, idx = design.gather(beta_cur, mask, cap)
                    res = _solve(sub, y, lam, strat_, beta0=beta_sub)
                    beta_full = design.scatter(res.beta, idx)
                    m_full = res.m if getattr(res, "m", None) is not None \
                        else sub.margins(res.beta)
                    return res, beta_full, m_full
                return restricted_solve

        with obs_trace.span("lambda_grid"):
            if slab_mesh:
                # at beta = 0 the NLL gradient is -0.5 * X^T y, so the
                # sparse screen pass at zero margins *is* lambda_max —
                # same program every later screen reuses, no dense X needed
                lmax = float(engine.device_get(jnp.max(grad_abs(m))))
            else:
                lmax = float(engine.device_get(
                    lambda_max_design(design, y)))
            lams = _lambda_grid(lmax, path_len, extra_lams)
        beta = jnp.zeros(p_cap, jnp.float32)

        def empty_result(beta_cur):
            if strat.execution == "mesh":
                return DistributedFitResult(beta=beta_cur, f=float("nan"),
                                            n_iters=0, objective_history=[])
            return FitResult(beta=beta_cur, f=float("nan"), n_iters=0,
                             objective_history=[], alpha_history=[])

        # -- resumable progress (repro.resilience.PathProgress) -------------
        if checkpoint_every is not None:
            if checkpoint_every < 1:
                raise ValueError(
                    f"checkpoint_every must be >= 1, got {checkpoint_every}")
            if resume_from is None:
                raise ValueError(
                    "checkpoint_every= requires resume_from= (the progress "
                    "directory checkpoints are written to and resumed from)")
        progress = PathProgress(resume_from) if resume_from else None

        lam_prev = lmax
        carry_mask = None
        points: List[PathPoint] = []
        start = 0
        if progress is not None:
            state = progress.load_latest()
            if state is not None:
                idx, arrays, meta = state
                if meta.get("kind") != "PathProgress":
                    raise ValueError(
                        f"{resume_from} is not a path-progress directory")
                if meta["lams"] != lams or meta["p"] != p \
                        or meta["p_cap"] != int(p_cap):
                    raise ValueError(
                        f"progress in {resume_from} was written for a "
                        f"different path (grid/shape mismatch) — point it "
                        f"at a fresh directory or rerun with the original "
                        f"arguments")
                beta = jnp.asarray(arrays["beta"], jnp.float32)
                m = jnp.asarray(arrays["m"], jnp.float32)
                if slab_mesh:
                    m = jax.device_put(m, design.vsharding())
                if meta["has_carry_mask"]:
                    carry_mask = jnp.asarray(arrays["carry_mask"] != 0)
                lam_prev = float(meta["lam_prev"])
                for j, d in enumerate(meta["points"]):
                    points.append(PathPoint(
                        lam=float(d["lam"]), nnz=int(d["nnz"]),
                        f=float(d["f"]), n_iters=int(d["n_iters"]),
                        beta=jnp.asarray(arrays["point_betas"][j]),
                        metrics=dict(d["metrics"]), screen=dict(d["screen"]),
                        status=int(d["status"]),
                    ))
                start = int(meta["next_index"])
                if verbose:
                    print(f"resuming path at point {start}/{len(lams)} "
                          f"from {progress.slot(idx)}")

        def solve_point(lam, prev_mask, strat_):
            return _screened_point(
                p_cap, lam, lam_prev, beta, m, grad_abs=grad_abs,
                restricted_solve=make_restricted_solve(lam, strat_),
                empty_result=empty_result, cap_tile=strat_.cap_tile,
                kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
                prev_mask=prev_mask, violation_budget=violation_budget,
            )

        for pt_idx in range(start, len(lams)):
            lam = lams[pt_idx]
            with obs_trace.span("lambda_point", index=pt_idx,
                                lam=float(lam)) as pt_sp:
                if screen:
                    res, beta_new, m_new, info, mask = solve_point(
                        lam, carry_mask, strat)
                    pt_status = int(getattr(res, "status", 0))
                    # Per-lambda degradation ladder: a tripped solve never
                    # feeds the warm-start chain. (1) drop the carried
                    # working set and re-warm-start from the previous
                    # certified point; (2) blocked cycles fall back to the
                    # sequential chain; (3) skip-and-mark, keeping the last
                    # certified state.
                    if pt_status:
                        res, beta_new, m_new, info, mask = solve_point(
                            lam, None, strat)
                        pt_status = int(getattr(res, "status", 0))
                        info["degraded"] = "rewarm"
                    if pt_status and opts.cycle_mode == "blocked":
                        seq_strat = resolve(
                            design,
                            _dc_replace(opts, cycle_mode="sequential"),
                            densify=densify)
                        res, beta_new, m_new, info, mask = solve_point(
                            lam, None, seq_strat)
                        pt_status = int(getattr(res, "status", 0))
                        info["degraded"] = "sequential"
                    if pt_status:
                        # skipped: beta/m stay at the previous certified
                        # point
                        beta_new, m_new, mask = beta, m, carry_mask
                        info = {**info, "skipped": True,
                                "degraded": "skipped"}
                    beta, m = beta_new, m_new
                    if carry_working_set and not pt_status:
                        carry_mask = mask
                else:
                    res = _solve(design, y, lam, strat, beta0=beta)
                    pt_status = int(getattr(res, "status", 0))
                    if pt_status:
                        # unscreened oracle loop: mark the point, hold the
                        # warm-start chain at the last certified state
                        info = {"skipped": True, "degraded": "skipped"}
                    else:
                        beta = res.beta
                        m = res.m if getattr(res, "m", None) is not None \
                            else design.margins(beta)
                        info = {}
                lam_prev = lam
                with obs_trace.span("point_finish"):
                    beta_out = to_output(beta) if to_output is not None \
                        else beta
                    # one audited fetch for the per-point telemetry
                    # (engine's device_get door — countable under the
                    # transfer sanitizer)
                    f_dev = (res.f if res.n_iters and not pt_status
                             else objective(m, y, beta, lam))
                    nnz_h, f_h = engine.device_get(
                        (jnp.sum(jnp.abs(beta_out) > 0), f_dev))
                    nnz, f = int(nnz_h), float(f_h)
                    metrics = eval_fn(beta_out) if eval_fn else {}
                    points.append(
                        PathPoint(lam=lam, nnz=nnz, f=f,
                                  n_iters=0 if pt_status else res.n_iters,
                                  beta=beta_out, metrics=metrics,
                                  screen=info, status=pt_status)
                    )
                    if verbose:
                        print(
                            f"lambda={lam:10.4f} nnz={nnz:6d} "
                            f"f={points[-1].f:12.4f} "
                            f"iters={points[-1].n_iters:3d} {info} {metrics}"
                        )
                    if progress is not None and checkpoint_every is not None \
                            and (pt_idx + 1 - start) % checkpoint_every == 0:
                        _save_progress(progress, pt_idx, lams, lam_prev,
                                       beta, m, carry_mask, points, p,
                                       int(p_cap))
                pt_sp.set(nnz=nnz, f=f, status=pt_status)
                # fault-injection hook: simulated process death between
                # points (after the checkpoint lands, like a real mid-path
                # kill)
                maybe_kill(pt_idx + 1)
        self.beta_ = points[-1].beta if points else None
        self.lam_ = lams[-1] if lams else None
        return PathResult.from_points(points)


# ---------------------------------------------------------------------------
# streamed per-lambda evaluation
# ---------------------------------------------------------------------------

def make_design_eval(test_data, y_test, *, mesh=None,
                     tile: int = 128) -> Callable[[jnp.ndarray], dict]:
    """``eval_fn`` for :meth:`LogisticL1.path` that scores through a test
    *design* instead of a replicated host matrix.

    For a sharded slab test design the per-lambda scores are the on-mesh
    slab margins (shard_map SpMV + psum over ``model``): only the (n_test,)
    score vector — resharded to replicated via the shared
    ``sharding.collect`` guard — ever reaches the host, closing the
    ROADMAP "stream eval_fn metrics from the mesh" item. Metrics are the
    paper's Figure-1 set (``train.metrics``: AUPRC, accuracy, logloss).
    """
    design = as_design(test_data, n=int(jnp.shape(y_test)[0]), mesh=mesh,
                       tile=tile)
    y_host = np.asarray(y_test)

    def fn(beta):
        from repro.train.metrics import metrics_from_scores

        scores = design.margins(beta)
        if isinstance(design, ShardedDesign):
            scores = replicate(scores, design.mesh)
        return metrics_from_scores(np.asarray(scores), y_host)

    return fn
