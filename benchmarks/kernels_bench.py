"""Kernel micro-benchmarks.

Two groups:

* solver-oracle timings (the pure-jnp forms the CPU paths actually run;
  interpret-mode Pallas is a correctness surface, not a fast path — TPU
  wall-times come from the roofline analysis);
* the sparse slab suite (``--kernels`` section of the path benchmark and
  the CI densify-regression gate): ``kernels.slab_gram`` / ``slab_spmv``
  against the per-tile densify-scatter they replaced, at webspam-like
  per-feature nnz (K = 4..16, the ``prefer_slab_gram`` regime) and at the
  dense-fallback K where the scatter+MXU path is the right call.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.subproblem import (
    blocked_cycle_modes,
    cd_cycle_blocked_tile,
    cd_cycle_gram_tile,
)
from repro.kernels import ops
from repro.kernels.ref import logistic_stats_ref, slab_gram_ref, slab_spmv_ref


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def _time_best(fn, *args, reps=10, chunks=5):
    """Min-of-chunk-means timing: robust to bursty co-tenant load (a CI
    gate fed by a mean over one noisy window flaps; the best chunk tracks
    the actual cost of the op)."""
    return min(_time(fn, *args, reps=reps) for _ in range(chunks))


def _make_slab(t, k, n_loc, seed=0):
    rng = np.random.default_rng(seed)
    rows = np.full((t, k), n_loc, np.int32)
    vals = np.zeros((t, k), np.float32)
    for f in range(t):
        kk = int(rng.integers(max(1, k // 2), k + 1))
        rr = np.sort(rng.choice(n_loc, size=kk, replace=False))
        rows[f, :kk] = rr
        vals[f, :kk] = rng.standard_normal(kk)
    return jnp.asarray(rows), jnp.asarray(vals)


def bench_slab_suite(*, n_loc: int = 1024, tile: int = 128,
                     ks=(4, 8, 16, 64), reps: int = 10) -> dict:
    """Times the sparse-native slab kernels against the densify-scatter
    reference at matched shapes. Returns a JSON-able dict; ``speedup`` > 1
    means the slab kernel beats re-densifying the tile (expected in the
    ``prefer_slab_gram`` regime, i.e. small K)."""
    key = jax.random.key(0)
    w = jnp.abs(jax.random.normal(key, (n_loc,))) * 0.2 + 0.01
    r = jax.random.normal(jax.random.fold_in(key, 1), (n_loc,))
    d = jax.random.normal(jax.random.fold_in(key, 2), (tile,))

    gram_sparse = jax.jit(ops.slab_gram)
    gram_densify = jax.jit(slab_gram_ref)
    spmv_sparse = jax.jit(lambda rw, vl, dd: ops.slab_spmv(rw, vl, dd,
                                                           n_loc=n_loc))
    spmv_densify = jax.jit(lambda rw, vl, dd: slab_spmv_ref(rw, vl, dd,
                                                            n_loc))
    out = {"n_loc": n_loc, "tile": tile}
    for k in ks:
        rows, vals = _make_slab(tile, k, n_loc, seed=k)
        ts = _time(gram_sparse, rows, vals, w, r, reps=reps)
        td = _time(gram_densify, rows, vals, w, r, reps=reps)
        out[f"slab_gram_K{k}"] = {
            "sparse_us": ts * 1e6, "densify_us": td * 1e6,
            "speedup": td / max(ts, 1e-12),
            "preferred": ops.prefer_slab_gram(n_loc, k),
        }
        ts = _time(spmv_sparse, rows, vals, d, reps=reps)
        td = _time(spmv_densify, rows, vals, d, reps=reps)
        out[f"slab_spmv_K{k}"] = {
            "sparse_us": ts * 1e6, "densify_us": td * 1e6,
            "speedup": td / max(ts, 1e-12),
        }
    return out


def bench_cycle_tile(*, f: int = 128, n_loc: int = 2048,
                     density: float = 0.2, block: int = 16,
                     reps: int = 20) -> dict:
    """Per-tile blocked-vs-sequential CD cycle timing on a bench-shaped
    weighted Gram tile (the ``--cycle`` section of the path benchmark and
    the CI re-serialization gate).

    Two granularities:

    * cycle-only (``speedup``, the gated number): the F-step scalar chain
      vs the F/B-step blocked cycle on the same (F, F) tile — the
      dependent-step reduction itself;
    * full tile step (``step_speedup``): Gram build + cycle at ``n_loc``
      local rows. At deep data-sharding (production 16x16 mesh,
      n_loc = n/256) the tile cycle is a large share of the step and the
      blocked win carries through; at shallow sharding the O(n_loc F^2)
      MXU-destined Gram matmul dominates on CPU and the end-to-end win
      awaits the TPU kernel.

    ``modes`` records how many blocks ran full-B / halved / sequential
    under the Gershgorin safeguard, so a collapse toward all-sequential is
    visible in the report."""
    key = jax.random.key(0)
    k1, k2, k3 = jax.random.split(key, 3)
    Xf = jax.random.normal(k1, (n_loc, f)) * (
        jax.random.uniform(k2, (n_loc, f)) < density)
    w = jnp.abs(jax.random.normal(k3, (n_loc,))) * 0.2 + 0.01
    r = jax.random.normal(jax.random.fold_in(k3, 1), (n_loc,))
    G = Xf.T @ (w[:, None] * Xf)
    c = Xf.T @ (w * r)
    beta = jnp.zeros(f)
    lam = 0.3

    def tile_step(solver):
        def step(Xf, w, r, b):
            wX = w[:, None] * Xf
            G = Xf.T @ wX
            c = wX.T @ r
            d = solver(G, c, b, b * 0, lam, 1e-6)
            return r - Xf @ d
        return jax.jit(step)

    def cycle_scan(solver, nt=32):
        # the hot paths run the cycle inside a scan over tiles; timing a
        # single ~25us dispatch is noise-bound, the scanned form measures
        # the chain itself (the carry feeds c so tiles can't be CSE'd)
        def one(carry, _):
            d = solver(G, c + carry[:1], beta, beta * 0, lam, 1e-6)
            return d, None

        fn = jax.jit(
            lambda: jax.lax.scan(one, jnp.zeros(f), None, length=nt)[0])
        return _time_best(fn, reps=reps) / nt

    ts = cycle_scan(cd_cycle_gram_tile)
    tb = cycle_scan(lambda *a: cd_cycle_blocked_tile(*a, block=block))
    step_seq = tile_step(cd_cycle_gram_tile)
    step_blk = tile_step(lambda *a: cd_cycle_blocked_tile(*a, block=block))
    tss = _time_best(step_seq, Xf, w, r, beta, reps=reps)
    tsb = _time_best(step_blk, Xf, w, r, beta, reps=reps)
    modes = np.bincount(np.asarray(blocked_cycle_modes(G, block)),
                        minlength=3)
    return {"f": f, "block": block, "n_loc": n_loc, "density": density,
            "sequential_us": ts * 1e6, "blocked_us": tb * 1e6,
            "speedup": ts / max(tb, 1e-12),
            "step_sequential_us": tss * 1e6, "step_blocked_us": tsb * 1e6,
            "step_speedup": tss / max(tsb, 1e-12),
            "modes": [int(x) for x in modes]}


def run():
    key = jax.random.key(0)
    for f in (128, 256, 512):
        A = jax.random.normal(key, (2 * f, f))
        G = A.T @ A / f
        c = jax.random.normal(key, (f,))
        beta = jnp.zeros(f)
        jitted = jax.jit(lambda G, c, b: cd_cycle_gram_tile(G, c, b, b * 0, 0.1, 1e-6))
        dt = _time(jitted, G, c, beta)
        emit(f"kernel.gram_cd_oracle.F{f}", dt * 1e6, f"flops~{2*f*f}")
    for n in (65536, 262144):
        m = jax.random.normal(key, (n,))
        y = jnp.sign(jax.random.normal(key, (n,)))
        jitted = jax.jit(lambda m, y: logistic_stats_ref(m, y))
        dt = _time(jitted, m, y)
        emit(f"kernel.logistic_stats_ref.n{n}", dt * 1e6, f"bytes~{n*16}")
    slab = bench_slab_suite()
    for name, row in slab.items():
        if isinstance(row, dict):
            emit(f"kernel.{name}.sparse", row["sparse_us"],
                 f"speedup_vs_densify={row['speedup']:.2f}x")
    for f, block in ((128, 8), (128, 16), (256, 16)):
        row = bench_cycle_tile(f=f, block=block)
        emit(f"kernel.blocked_cycle.F{f}.B{block}", row["blocked_us"],
             f"speedup_vs_sequential={row['speedup']:.2f}x;"
             f"modes={row['modes']}")


if __name__ == "__main__":
    run()
