"""Golden fixture for dead-code: an orphan module nothing imports.

The rule only inventories ``src/`` modules, so this file is inert where
it sits (tests/fixtures/); ``tests/test_analysis.py`` re-parses this
source under the synthetic path ``src/repro/orphan_scaffold.py`` and
asserts exactly one dead-code finding.
"""


def unused_helper():
    return 0
