from repro.checkpoint.checkpointer import (  # noqa: F401
    CheckpointCorruption,
    load_pytree,
    read_meta,
    save_pytree,
    verify_payload,
)
