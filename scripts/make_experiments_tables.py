"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/*.json."""
from __future__ import annotations

import glob
import json
import sys


def load(pattern):
    rows = []
    for f in sorted(glob.glob(pattern)):
        rows.extend(json.load(open(f)))
    return rows


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def temp_bytes(r):
    import re

    m = re.search(r"temp_size_in_bytes=(\d+)", r.get("memory_analysis", ""))
    return int(m.group(1)) if m else None


def roofline_table(rows):
    print("| arch | shape | chips | t_comp | t_mem | t_coll | bottleneck | "
          "model/HLO flops | temp/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | - | SKIP | - | "
                  f"{r['reason'][:60]}... |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | - | - | - | - | ERROR | - | - |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {r['chips']} "
            f"| {r['t_compute']*1e3:.1f}ms | {r['t_memory']*1e3:.1f}ms "
            f"| {r['t_collective']*1e3:.1f}ms | **{r['bottleneck']}** "
            f"| {r.get('useful_flops_ratio', 0):.3f} "
            f"| {fmt_bytes(temp_bytes(r))} |"
        )


def dryrun_table(rows):
    print("| arch | shape | mesh | status | compile | args/dev | temp/dev |")
    print("|---|---|---|---|---|---|---|")
    import re

    for r in rows:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip | - | - | - |")
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | - | - | - |")
            continue
        m = re.search(r"argument_size_in_bytes=(\d+)", r.get("memory_analysis", ""))
        args_b = int(m.group(1)) if m else None
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
              f"| {r.get('compile_s', 0):.0f}s | {fmt_bytes(args_b)} "
              f"| {fmt_bytes(temp_bytes(r))} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    pattern = sys.argv[2] if len(sys.argv) > 2 else "results/single_*.json"
    rows = load(pattern)
    if which == "roofline":
        roofline_table(rows)
    else:
        dryrun_table(rows)
