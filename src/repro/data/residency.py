"""Bucket residency: budgeted device placement of slab work buckets.

The d-GLMNET premise is data too large for one machine, yet until this
module every path solve required the *whole* padded slab layout resident
in device memory — aggregate HBM, not the dataset, was the scale
ceiling. :class:`BucketResidencyManager` makes residency an explicit,
budgeted policy over the mesh-padded work buckets that
``ShardedDesign._mesh_state`` builds:

* **resident** (no budget, or budget >= total slab bytes): every bucket
  is device-put once at construction and pinned for the design's
  lifetime — byte-identical to the pre-manager behavior.
* **streamed** (budget < total slab bytes): buckets live host-side and
  are *double-buffered* through each screened pass — bucket t+1's
  ``device_put`` is dispatched (async on the JAX dispatch stream) before
  bucket t is yielded to its Gram/SpMV work, so the host->device copy
  overlaps compute. A budgeted LRU evicts cold buckets by dropping their
  Python references (XLA frees the buffers once in-flight uses retire;
  an explicit delete would race the async dispatch).

The two modes run the *same op sequence in the same bucket order* — the
manager only changes where buckets live, never the math — which is what
makes streamed solves bit-identical to resident ones.

This module is also the **single home** of slab-bucket
``jax.device_put`` (enforced by the ``bucket-residency`` analysis rule):
transient slab placements outside the managed work buckets (restricted-
solve operands, serve request slabs) go through :func:`put_slab`.

Failure model: every put attempt consults
``repro.resilience.take_prefetch_failure`` and runs under
``retry_call`` — a transient lost bucket is retried with backoff and the
solve proceeds bit-identically; exhaustion surfaces as a typed
``RetriesExhausted`` that the path driver's ``PathProgress`` checkpoints
make resumable (drill: ``repro.launch.chaos_glm --scenario lost-bucket``).

The budget is a residency high-water target for the *managed* buckets:
because puts are dispatched ahead of compute, transiently in-flight
buffers (and unmanaged operands like restricted-solve working sets) can
briefly exceed it.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import jax

from repro.obs import registry as obs_registry
from repro.obs import trace as obs_trace
from repro.resilience.inject import InjectedFault, take_prefetch_failure
from repro.resilience.retry import retry_call


def put_slab(row_idx, values, sharding=None):
    """Device-put one transient slab pair (the sanctioned door for slab
    placements that are *not* residency-managed work buckets: restricted
    solve operands, serve request slabs). Managed buckets go through
    :class:`BucketResidencyManager` so the budget can see them."""
    if sharding is None:
        return jax.device_put(row_idx), jax.device_put(values)
    return jax.device_put(row_idx, sharding), jax.device_put(values, sharding)


@dataclass
class ResidencyCounters:
    """Mutable telemetry for one manager (all monotone)."""

    hits: int = 0          # get() served from device
    misses: int = 0        # get() had to stream the bucket in
    evictions: int = 0     # LRU drops under budget pressure
    puts: int = 0          # successful host->device bucket puts
    retries: int = 0       # put attempts that failed and were retried
    bytes_h2d: int = 0     # payload bytes moved host->device


class BucketResidencyManager:
    """Budgeted LRU residency over padded slab work buckets.

    ``buckets`` is the tuple of mesh-padded ``(row_idx, values,
    feat_idx)`` triples (host or committed arrays — the manager never
    mutates them); ``sharding`` is the slab ``NamedSharding`` every
    device copy lands in; ``budget_bytes=None`` (or a budget covering
    ``total_bytes``) selects resident mode.

    Streamed mode needs room to double-buffer: the budget must cover the
    largest *adjacent pair* of buckets (:attr:`min_budget_bytes`), else
    construction raises with the number to raise the budget to.
    """

    def __init__(self, buckets, *, sharding=None,
                 budget_bytes: Optional[int] = None,
                 retry_attempts: int = 3, retry_base_s: float = 0.05):
        self.n_buckets = len(buckets)
        self.bucket_bytes: Tuple[int, ...] = tuple(
            int(r.nbytes) + int(v.nbytes) for r, v, _ in buckets)
        self.total_bytes = sum(self.bucket_bytes)
        pairs = [self.bucket_bytes[i] + self.bucket_bytes[i + 1]
                 for i in range(self.n_buckets - 1)]
        self.min_budget_bytes = max(pairs) if pairs else (
            self.bucket_bytes[0] if self.n_buckets else 0)
        self.budget_bytes = None if budget_bytes is None else int(budget_bytes)
        self.streamed = (self.budget_bytes is not None
                         and self.budget_bytes < self.total_bytes)
        self.counters = ResidencyCounters()
        self._feat = tuple(b[2] for b in buckets)
        self._sharding = sharding
        self._retry_attempts = retry_attempts
        self._retry_base_s = retry_base_s
        self._resident: "OrderedDict[int, tuple]" = OrderedDict()
        self._resident_bytes = 0
        self._pinned: set = set()
        self._iterating = False
        if self.streamed:
            if self.budget_bytes < self.min_budget_bytes:
                raise ValueError(
                    f"device_budget_bytes={self.budget_bytes} cannot "
                    f"double-buffer these work buckets: the largest "
                    f"adjacent bucket pair is {self.min_budget_bytes} bytes "
                    f"(of {self.total_bytes} total over {self.n_buckets} "
                    f"buckets) — raise the budget to >= "
                    f"{self.min_budget_bytes}, or drop it to run resident")
            self._host = tuple((r, v) for r, v, _ in buckets)
        else:
            # resident: one put per bucket, pinned for the manager's
            # lifetime; host references dropped (no re-put ever happens)
            self._host = None
            for i, (r, v, _) in enumerate(buckets):
                self._admit(i, self._put(i, r, v))

    # -- device placement --------------------------------------------------

    def _put(self, i: int, r, v):
        """One counted, retried host->device bucket put. The injection
        consult + retry wrapper is what the lost-bucket drill drives."""
        def attempt():
            if take_prefetch_failure():
                raise InjectedFault(
                    f"injected prefetch failure (bucket {i})")
            return put_slab(r, v, self._sharding)

        def count_retry(_k, _err):
            self.counters.retries += 1

        pair = retry_call(attempt, attempts=self._retry_attempts,
                          base_delay_s=self._retry_base_s,
                          retry_on=(RuntimeError,), on_retry=count_retry)
        self.counters.puts += 1
        self.counters.bytes_h2d += self.bucket_bytes[i]
        return pair

    def _admit(self, i: int, pair) -> None:
        self._resident[i] = pair
        self._resident_bytes += self.bucket_bytes[i]

    def _ensure_room(self, need: int, keep) -> None:
        if not self.streamed:
            return
        while self._resident_bytes + need > self.budget_bytes:
            victim = next((j for j in self._resident
                           if j not in self._pinned and j not in keep), None)
            if victim is None:
                raise RuntimeError(
                    f"residency budget {self.budget_bytes} exhausted with "
                    f"every resident bucket pinned — min_budget_bytes="
                    f"{self.min_budget_bytes} should have prevented this")
            # dropping the reference is the eviction: XLA frees the
            # buffers once any in-flight compute on them retires
            self._resident.pop(victim)
            self._resident_bytes -= self.bucket_bytes[victim]
            self.counters.evictions += 1

    # -- access ------------------------------------------------------------

    def get(self, i: int):
        """The device ``(row_idx, values)`` pair for bucket ``i``,
        streaming it in (and evicting LRU cold buckets) on a miss."""
        if not 0 <= i < self.n_buckets:
            raise IndexError(f"bucket {i} out of range [0, {self.n_buckets})")
        pair = self._resident.get(i)
        if pair is not None:
            self._resident.move_to_end(i)
            self.counters.hits += 1
            return pair
        self.counters.misses += 1
        # the span brackets eviction + the (async-dispatch) re-put — on
        # the CPU fake-device mesh that is bookkeeping + memcpy, on a
        # real accelerator it is the h2d dispatch the double buffer hides
        with obs_trace.span("bucket_stream", bucket=i):
            self._ensure_room(self.bucket_bytes[i], keep={i})
            pair = self._put(i, *self._host[i])
        self._admit(i, pair)
        return pair

    def iter_buckets(self) -> Iterator[tuple]:
        """Yield ``(row_idx, values, feat_idx)`` in bucket order, with
        bucket t+1's put dispatched *before* bucket t is yielded to its
        compute — the double buffer that hides the host->device copy
        behind the Gram/SpMV work. Not reentrant (every screened pass
        fully consumes its iteration before the next starts)."""
        if self._iterating:
            raise RuntimeError(
                "bucket iteration is not reentrant — consume the previous "
                "pass before starting another")
        self._iterating = True
        try:
            for i in range(self.n_buckets):
                self._pinned = ({i, i + 1} if i + 1 < self.n_buckets
                                else {i})
                pair = self.get(i)
                if i + 1 < self.n_buckets:
                    self.get(i + 1)       # async prefetch ahead of compute
                yield pair[0], pair[1], self._feat[i]
        finally:
            self._pinned = set()
            self._iterating = False

    # -- telemetry ---------------------------------------------------------

    def register_metrics(self, registry=None, *,
                         name: str = "residency") -> None:
        """Mirror this manager's counters onto a ``repro.obs`` metrics
        registry as a lazy read-only callback. The :class:`ResidencyCounters`
        dataclass stays the single source of truth — ``stats()`` /
        ``residency_stats()`` values are bit-identical whether or not a
        registry is active. No-op when no registry is given or armed."""
        reg = obs_registry.get_registry() if registry is None else registry
        if reg is None:
            return
        reg.register_callback(name, self.stats)

    # -- introspection -----------------------------------------------------

    def resident_indices(self) -> Tuple[int, ...]:
        """Resident bucket ids in LRU order (least recent first)."""
        return tuple(self._resident)

    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes

    def stats(self) -> dict:
        c = self.counters
        access = c.hits + c.misses
        return {
            "streamed": self.streamed,
            "n_buckets": self.n_buckets,
            "budget_bytes": self.budget_bytes,
            "total_bytes": self.total_bytes,
            "resident_bytes": self._resident_bytes,
            "hits": c.hits,
            "misses": c.misses,
            "evictions": c.evictions,
            "puts": c.puts,
            "retries": c.retries,
            "bytes_h2d": c.bytes_h2d,
            "hit_rate": (c.hits / access) if access else 0.0,
        }
