"""Quickstart: L1-regularized logistic regression through the one front
door (``repro.api.LogisticL1`` over a ``Design``), with the path solve
traced through ``repro.obs`` (per-lambda phase report at the end).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import DenseDesign, LogisticL1, SlabDesign, lambda_max_design
from repro.configs.base import GLMConfig
from repro.core import DGLMNETOptions
from repro.data.synthetic import make_glm_dataset
from repro.obs import observe, render_summary
from repro.train.metrics import glm_eval_fn


def main():
    cfg = GLMConfig(name="quickstart", num_examples=8192, num_features=256,
                    density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    design = DenseDesign(ds.X_train)
    y = ds.y_train
    lmax = float(lambda_max_design(design, y))
    n, p = design.shape
    print(f"n={n}  p={p}  lambda_max={lmax:.2f}")

    # single solve, simulating 8 machines (feature blocks)
    est = LogisticL1(opts=DGLMNETOptions(num_blocks=8, method="gram", tile=32))
    res = est.fit(design, y, lmax / 64, verbose=True)
    print(f"\nfit: status={res.status_name}  f={res.f:.4f}  nnz={res.nnz}/{p}"
          f"  iters={res.n_iters}  unit-step={res.unit_step_frac:.0%}")

    # the same solve from the by-feature slab layout — one front door,
    # any Design; the strategy resolver picks the execution
    res_slab = est.fit(SlabDesign.from_dense(ds.X_train), y, lmax / 64)
    print(f"slab layout: f={res_slab.f:.4f} (same solve, different Design)")

    # regularization path (paper Algorithm 5) with test metrics, traced:
    # observe() activates repro.obs for the block, so the driver's spans
    # (screen rounds, restricted solves, KKT checks) land in a summary
    print("\nregularization path:")
    est = LogisticL1(opts=DGLMNETOptions(num_blocks=8, tile=32))
    with observe() as obs:
        pts = est.path(design, y, path_len=8,
                       eval_fn=glm_eval_fn(ds.X_test, ds.y_test),
                       verbose=True)
    best = max(pts, key=lambda pt: pt.metrics["auprc"])
    print(f"\nbest: lambda={best.lam:.3f} nnz={best.nnz} "
          f"AUPRC={best.metrics['auprc']:.4f}")

    # score through the estimator (margins via the Design)
    proba = est.predict_proba(DenseDesign(ds.X_test), beta=best.beta)
    print(f"test P(y=+1) range: [{float(proba.min()):.3f}, "
          f"{float(proba.max()):.3f}]")

    # where did the path spend its time? (same report as
    # `python -m repro.obs.report <file>` on an exported summary)
    print("\nobservability — per-phase path report:")
    print(render_summary(obs.summary()))


if __name__ == "__main__":
    main()
