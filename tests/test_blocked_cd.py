"""Blocked semi-parallel CD cycle: B=1 bit-identity with the sequential
chain, quadratic descent under the Gershgorin safeguard, adversarial
duplicated-feature tiles (where full Jacobi ascends), Pallas kernel parity
in interpret mode (sentinel-padded tails included), and the dispatch
heuristic/option plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DGLMNETOptions, fit, lambda_max
from repro.core.subproblem import (
    blocked_cycle_modes,
    cd_cycle_blocked_tile,
    cd_cycle_gram_tile,
    cd_cycle_jacobi_tile,
    make_tile_solver,
    solve_subproblem,
)
from repro.kernels import ops
from repro.kernels.ref import blocked_cd_ref


def random_tile(f, seed, corr=0.0):
    key = jax.random.key(seed)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    A = jax.random.normal(k1, (2 * f, f))
    if corr:
        shared = jax.random.normal(k5, (2 * f, 1))
        A = jnp.sqrt(1 - corr) * A + jnp.sqrt(corr) * shared
    G = A.T @ A / f
    c = 3.0 * jax.random.normal(k2, (f,))
    beta = 0.5 * jax.random.normal(k3, (f,))
    db0 = 0.1 * jax.random.normal(k4, (f,))
    return G, c, beta, db0


def duplicated_tile(f, seed, w_scale=1.0):
    """Adversarial perfectly-correlated tile: one feature duplicated f
    times, so every off-diagonal Gram entry equals the diagonal — the
    construction where simultaneous (Jacobi) updates overshoot by ~f."""
    key = jax.random.key(seed)
    x = jax.random.normal(key, (64, 1))
    X = jnp.tile(x, (1, f))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (64,))) * w_scale + 0.1
    G = X.T @ (w[:, None] * X)
    r = jax.random.normal(jax.random.fold_in(key, 2), (64,))
    c = X.T @ (w * r)
    return G, c


def qobj(G, c, beta, lam, d):
    return float(0.5 * d @ G @ d - c @ d + lam * jnp.sum(jnp.abs(beta + d)))


# ---------------------------------------------------------------------------
# oracle properties
# ---------------------------------------------------------------------------

def test_block1_bit_identical_to_sequential():
    """cd_cycle_blocked_tile with B=1 IS the sequential chain, bit for bit
    (same float ops in the same order)."""
    for f in (8, 32, 128):
        for lam in (0.0, 0.3, 10.0):
            G, c, beta, db0 = random_tile(f, f * 7 + int(lam * 10))
            d_seq = cd_cycle_gram_tile(G, c, beta, db0, lam)
            d_blk = cd_cycle_blocked_tile(G, c, beta, db0, lam, block=1)
            np.testing.assert_array_equal(np.asarray(d_seq), np.asarray(d_blk))


@pytest.mark.parametrize("f,block", [(32, 4), (64, 8), (128, 16), (128, 32)])
@pytest.mark.parametrize("corr", [0.0, 0.5, 0.95])
def test_blocked_cycle_decreases_quadratic(f, block, corr):
    """The safeguarded blocked cycle never increases the penalized
    quadratic model, at any correlation level (the dominance check demotes
    conflicted blocks to halved/sequential updates)."""
    G, c, beta, _ = random_tile(f, f + block + int(corr * 10), corr=corr)
    lam = 0.5
    d = cd_cycle_blocked_tile(G, c, beta, jnp.zeros(f), lam, block=block)
    assert qobj(G, c, beta, lam, d) <= qobj(G, c, beta, lam, jnp.zeros(f)) + 1e-4


def test_duplicated_features_jacobi_ascends_blocked_descends():
    """On a perfectly duplicated-feature tile, full Jacobi overshoots
    (ascends the quadratic model) while the blocked cycle's safeguard
    detects the correlation (modes -> sequential) and matches the chain."""
    f = 16
    G, c = duplicated_tile(f, seed=3)
    beta = jnp.zeros(f)
    lam = 0.01
    d_jac = cd_cycle_jacobi_tile(G, c, beta, jnp.zeros(f), lam)
    assert qobj(G, c, beta, lam, d_jac) > qobj(G, c, beta, lam, jnp.zeros(f)), \
        "expected the Shotgun conflict to ascend on duplicated features"
    for block in (4, 8):
        modes = np.asarray(blocked_cycle_modes(G, block))
        assert (modes == 2).all(), modes       # pathological -> sequential
        d_blk = cd_cycle_blocked_tile(G, c, beta, jnp.zeros(f), lam, block=block)
        d_seq = cd_cycle_gram_tile(G, c, beta, jnp.zeros(f), lam)
        np.testing.assert_allclose(np.asarray(d_blk), np.asarray(d_seq),
                                   atol=1e-6)


def test_blocked_cycle_modes_tiers():
    """The three safeguard tiers are each reachable: identity-like tiles
    pass at full B, cross-half-coupled tiles pass only at B/2, and
    duplicated-feature tiles fall through to the sequential chain."""
    f, block = 8, 4
    assert (np.asarray(blocked_cycle_modes(jnp.eye(f), block)) == 0).all()
    # couple only *across* the two halves of each block: the full-B ratio
    # fails the dominance check but each half is internally diagonal
    G = jnp.eye(f)
    for b0 in range(0, f, block):
        for i in range(block // 2):
            for j in range(block // 2, block):
                G = G.at[b0 + i, b0 + j].set(0.5).at[b0 + j, b0 + i].set(0.5)
    assert (np.asarray(blocked_cycle_modes(G, block)) == 1).all()
    G_dup, _ = duplicated_tile(f, seed=1)
    assert (np.asarray(blocked_cycle_modes(G_dup, block)) == 2).all()
    # B=1 has no within-block coupling by construction
    assert (np.asarray(blocked_cycle_modes(G_dup, 1)) == 0).all()


def test_blocked_block_must_divide_tile():
    G, c, beta, db0 = random_tile(32, 0)
    with pytest.raises(ValueError, match="must divide"):
        cd_cycle_blocked_tile(G, c, beta, db0, 0.1, block=5)


# ---------------------------------------------------------------------------
# hypothesis property: B=1 bit-identity over random tiles
# ---------------------------------------------------------------------------

def test_block1_bit_identical_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(f=st.sampled_from([8, 16, 64]), seed=st.integers(0, 2**31 - 1),
           lam=st.floats(0.0, 5.0, allow_nan=False))
    @settings(max_examples=25, deadline=None)
    def check(f, seed, lam):
        G, c, beta, db0 = random_tile(f, seed)
        d_seq = cd_cycle_gram_tile(G, c, beta, db0, lam)
        d_blk = cd_cycle_blocked_tile(G, c, beta, db0, lam, block=1)
        np.testing.assert_array_equal(np.asarray(d_seq), np.asarray(d_blk))

    check()


# ---------------------------------------------------------------------------
# kernel-vs-oracle parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("f,block", [(32, 4), (64, 1), (128, 8), (128, 16),
                                     (256, 32)])
@pytest.mark.parametrize("lam", [0.0, 0.3, 10.0])
def test_blocked_cd_kernel_matches_oracle(f, block, lam):
    G, c, beta, db0 = random_tile(f, f * 3 + block, corr=0.3)
    d_kernel = ops.blocked_cd(G, c, beta, db0, lam, block=block)
    d_ref = blocked_cd_ref(G, c, beta, db0, lam, 1e-6, block=block)
    np.testing.assert_allclose(np.asarray(d_kernel), np.asarray(d_ref),
                               atol=1e-5, rtol=1e-5)


def test_blocked_cd_kernel_adversarial_modes():
    """Kernel parity on a tile that exercises the sequential-fallback
    branch (duplicated features -> mode 2 everywhere)."""
    f = 32
    G, c = duplicated_tile(f, seed=9)
    beta = 0.2 * jax.random.normal(jax.random.key(5), (f,))
    d_kernel = ops.blocked_cd(G, c, beta, jnp.zeros(f), 0.05, block=8)
    d_ref = blocked_cd_ref(G, c, beta, jnp.zeros(f), 0.05, 1e-6, block=8)
    np.testing.assert_allclose(np.asarray(d_kernel), np.asarray(d_ref),
                               atol=1e-5, rtol=1e-5)


def test_blocked_cd_kernel_sentinel_padded_tail():
    """Capacity padding (all-zero trailing feature columns, h = nu only)
    must produce exact zeros in the tail and no NaNs anywhere."""
    f, live, block = 64, 40, 8
    key = jax.random.key(11)
    A = jax.random.normal(key, (2 * f, live))
    Xp = jnp.pad(A, ((0, 0), (0, f - live)))
    G = Xp.T @ Xp / f
    c = jnp.pad(3.0 * jax.random.normal(jax.random.fold_in(key, 1), (live,)),
                (0, f - live))
    beta = jnp.zeros(f)
    d_kernel = ops.blocked_cd(G, c, beta, jnp.zeros(f), 0.3, block=block)
    d_ref = blocked_cd_ref(G, c, beta, jnp.zeros(f), 0.3, 1e-6, block=block)
    assert np.isfinite(np.asarray(d_kernel)).all()
    assert (np.asarray(d_kernel[live:]) == 0).all()
    np.testing.assert_allclose(np.asarray(d_kernel), np.asarray(d_ref),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# dispatch + solver plumbing
# ---------------------------------------------------------------------------

def test_prefer_blocked_cd_heuristic():
    assert not ops.prefer_blocked_cd(128, 1)       # B=1 == sequential
    assert not ops.prefer_blocked_cd(16, 16)       # single block, tiny tile
    assert not ops.prefer_blocked_cd(16, 8)        # tile below crossover
    assert ops.prefer_blocked_cd(128, 16)
    assert ops.prefer_blocked_cd(64, 8)


def test_make_tile_solver_resolution():
    seq = make_tile_solver(cycle_mode="sequential", tile=128)
    assert seq is cd_cycle_gram_tile
    blk = make_tile_solver(cycle_mode="blocked", tile=128, block=8)
    assert blk.func is cd_cycle_blocked_tile and blk.keywords["block"] == 8
    # auto: heuristic picks blocked for wide tiles, sequential below it
    assert make_tile_solver(cycle_mode="auto", tile=128,
                            block=16).func is cd_cycle_blocked_tile
    assert make_tile_solver(cycle_mode="auto", tile=16,
                            block=16) is cd_cycle_gram_tile
    with pytest.raises(ValueError, match="cycle_mode"):
        make_tile_solver(cycle_mode="bogus", tile=128)


def test_solve_subproblem_blocked_b1_equals_gram(small_glm):
    """method="blocked" with B=1 must reproduce the exact Gram path."""
    X, y = small_glm.X_train, small_glm.y_train
    n, p = X.shape
    key = jax.random.key(2)
    w = jnp.abs(jax.random.normal(key, (n,))) * 0.2 + 0.01
    z = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    beta = 0.1 * jax.random.normal(jax.random.fold_in(key, 2), (p,))
    lam = 0.5
    d1, dm1 = solve_subproblem(X, w, z, beta, lam, method="gram", tile=32)
    d2, dm2 = solve_subproblem(X, w, z, beta, lam, method="blocked",
                               tile=32, block=1)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(dm1), np.asarray(dm2))


def test_fit_blocked_monotone_and_matches_sequential_adversarial():
    """End-to-end descent on an adversarially correlated design (every
    feature duplicated 4x): blocked cycles + the global line search stay
    monotone and land on the sequential objective."""
    key = jax.random.key(0)
    n, base_p, dup = 512, 16, 4
    Xb = jax.random.normal(key, (n, base_p))
    X = jnp.repeat(Xb, dup, axis=1)                  # (n, 64) duplicated
    beta_true = jnp.zeros(base_p * dup).at[::dup].set(
        jax.random.normal(jax.random.fold_in(key, 1), (base_p,)) * 2.0)
    y = jnp.where(
        jax.random.uniform(jax.random.fold_in(key, 2), (n,))
        < jax.nn.sigmoid(X @ beta_true), 1.0, -1.0)
    lam = float(lambda_max(X, y)) / 16
    seq = fit(X, y, lam, opts=DGLMNETOptions(tile=16, max_iters=60))
    blk = fit(X, y, lam, opts=DGLMNETOptions(tile=16, max_iters=60,
                                             cycle_mode="blocked", block=8))
    h = blk.objective_history
    assert all(h[i + 1] <= h[i] + 1e-4 * abs(h[i]) for i in range(len(h) - 1)), h
    assert abs(blk.f - seq.f) / abs(seq.f) < 1e-3, (blk.f, seq.f)
