"""qwen1.5-4b [dense] — QKV bias, MHA-style GQA(kv==H) [hf:Qwen/Qwen1.5-0.5B family].

20 heads do not divide the 16-way model axis: attention shards on the
d_model input dim instead of heads (repro.sharding.rules fallback).
"""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    citation="hf:Qwen/Qwen1.5-0.5B (family card); assignment table",
    num_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=20,
        num_kv_heads=20,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
    optimizer="adamw",
    long_context_mode="sliding_window",
)
