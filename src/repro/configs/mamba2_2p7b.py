"""mamba2-2.7b [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    citation="arXiv:2405.21060 (Mamba-2 / SSD), mamba2-2.7b card",
    num_layers=64,
    d_model=2560,
    d_ff=0,                      # attention-free, no separate MLP: Mamba2 blocks only
    vocab_size=50280,            # padded to 50432 for 16-way vocab sharding
    ssm=SSMConfig(
        d_state=128,
        head_dim=64,             # -> 80 SSD heads (d_inner = 5120)
        expand=2,
        conv_width=4,
        chunk_size=256,
    ),
    norm="rmsnorm",
    tie_embeddings=True,
    optimizer="adamw",
    long_context_mode="native",  # O(1)-state decode; long_500k runs natively
)
