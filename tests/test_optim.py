"""Optimizer substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adamw, clip_by_global_norm, sgd, warmup_cosine
from repro.optim.optimizers import apply_updates


@pytest.mark.parametrize("make", [
    lambda: sgd(momentum=0.9),
    lambda: adamw(weight_decay=0.0),
    lambda: adafactor(),
])
def test_optimizer_decreases_quadratic(make):
    opt = make()
    params = {"w": jnp.array([[3.0, -2.0], [1.5, 4.0]]), "b": jnp.array([1.0, -1.0])}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, jnp.float32(0.05))
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32))}
    state = opt.init(params)
    acc = state["acc"]["w"]
    assert acc["vr"].shape == (64,)
    assert acc["vc"].shape == (32,)
    # O(rows+cols), not O(rows*cols)
    assert acc["vr"].size + acc["vc"].size < 64 * 32 // 4


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(10)), 1.0, rtol=1e-5)
    assert float(s(100)) < 0.2
    assert float(s(5)) == pytest.approx(0.5, rel=1e-5)
