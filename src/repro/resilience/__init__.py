"""repro.resilience — fault injection, typed failure status, recovery.

The paper's premise is clusters big enough that partial failure, numeric
blowup and overload are the norm; this package is the robustness layer
the solver/serve stack consults:

* :mod:`~repro.resilience.inject` — a deterministic, seeded
  fault-injection harness (NaN/Inf poisoning of margins or working stats
  at a chosen outer iteration, forced line-search failure, checkpoint
  corruption, kill-after-N-path-points, serve latency/overload knobs),
  driveable from tests and ``python -m repro.launch.chaos_glm``;
* :mod:`~repro.resilience.retry` — bounded exponential-backoff retry for
  the serve loop's swap/load edges;
* :mod:`~repro.resilience.progress` — the per-lambda progress store
  behind ``LogisticL1.path(checkpoint_every=, resume_from=)``: rotated
  slots, atomic pointer update, roll-back to last-good on corruption.

The numerical guardrails themselves live on the solver carry
(``core.engine``: the device-resident ``status`` code) — this package
never imports JAX, so the chaos harness loads even where the runtime
can't.
"""
from repro.resilience.inject import (  # noqa: F401
    EngineFault,
    FaultPlan,
    InjectedFault,
    InjectedKill,
    active_plan,
    arm_engine_fault,
    corrupt_checkpoint,
    inject_faults,
    maybe_kill,
    serve_delay,
    take_load_failure,
    take_prefetch_failure,
    take_swap_failure,
)
from repro.resilience.progress import PathProgress  # noqa: F401
from repro.resilience.retry import RetriesExhausted, retry_call  # noqa: F401
