"""Golden fixture: trips pallas-conventions and nothing else.

A public pallas_call entry point without an ``interpret`` parameter
cannot be validated against its CPU oracle (tests) nor forced native
(TPU) by the caller. No sibling ref.py exists here, so only the
``interpret`` convention fires.
"""
import jax
import jax.experimental.pallas as pl


def scale_pallas(x):
    shape = jax.ShapeDtypeStruct(x.shape, x.dtype)
    return pl.pallas_call(_scale_kernel, out_shape=shape)(x)


def _scale_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0
