"""psum-axis: collective axis-name discipline + shard_map spec arity.

Two invariant families this repo leans on across ~18 mesh files:

1. Every collective (``lax.psum``/``pmean``/``pmax``/``pmin``/
   ``axis_index``/``all_gather``) must name an axis that the surrounding
   sharding constructs actually declare. A literal axis string that
   appears in no ``P(...)`` spec, ``Mesh`` declaration or ``*_axis``
   parameter default in the file is a typo'd collective: under
   ``shard_map`` it fails at trace time *only* on the code path that runs,
   so dead branches ship broken.

   Axis expressions are considered declared when they are (a) a literal
   found in the module's declared-axis set, (b) a parameter of an
   enclosing function (axis injected by the caller — the repo's
   ``model_axis="model"`` convention), or (c) bound by a ``for`` loop over
   a parameter/value whose name ends in ``axes`` (the ``for ax in
   data_axes`` idiom, mesh-derived by construction).

2. A ``shard_map`` decoration with a literal ``in_specs`` tuple must have
   exactly one spec per positional parameter of the decorated function —
   an arity mismatch is a guaranteed trace error on the first call, but
   factory-cached call sites can hide it until a cold path runs.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.context import (
    ModuleInfo,
    Project,
    positional_param_count,
    spec_tuple_len,
)
from repro.analysis.findings import Finding

RULE_ID = "psum-axis"
DOC = ("collective axis names must be declared by surrounding "
       "shard_map/Mesh/spec constructs; shard_map in_specs arity must "
       "match the function signature")

_COLLECTIVES = {
    "jax.lax.psum", "jax.lax.pmean", "jax.lax.pmax", "jax.lax.pmin",
    "jax.lax.axis_index", "jax.lax.all_gather", "jax.lax.ppermute",
    "jax.lax.psum_scatter", "jax.lax.pshuffle", "jax.lax.all_to_all",
}


def _axis_arg(q: str, node: ast.Call) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg in ("axis_name", "axis"):
            return kw.value
    # positional conventions: axis_index(axis); psum/pmax/...(x, axis);
    # all_gather(x, axis, ...)
    idx = 0 if q.endswith("axis_index") else 1
    return node.args[idx] if len(node.args) > idx else None


def _enclosing_functions(tree: ast.Module) -> dict:
    """node -> chain of enclosing FunctionDefs (outermost first)."""
    chains = {}

    def visit(node, chain):
        for child in ast.iter_child_nodes(node):
            new_chain = chain
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                new_chain = chain + [child]
            chains[child] = new_chain
            visit(child, new_chain)

    chains[tree] = []
    visit(tree, [])
    return chains


def _param_names(fn: ast.FunctionDef) -> Set[str]:
    a = fn.args
    names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def _loop_axis_names(fns: List[ast.FunctionDef]) -> Set[str]:
    """Names bound by ``for ax in <something named *axes*>`` in the
    enclosing function chain (the mesh-derived data-axes idiom)."""
    out: Set[str] = set()
    for fn in fns:
        for node in ast.walk(fn):
            if not isinstance(node, ast.For):
                continue
            src = node.iter
            name = None
            if isinstance(src, ast.Name):
                name = src.id
            elif isinstance(src, ast.Call) and isinstance(src.func, ast.Name):
                name = src.func.id
            elif isinstance(src, ast.Call) and isinstance(
                    src.func, ast.Attribute):
                name = src.func.attr
            if name and ("axes" in name or name == "_data_axes"):
                for tgt in ast.walk(node.target):
                    if isinstance(tgt, ast.Name):
                        out.add(tgt.id)
    return out


def _check_collectives(mod: ModuleInfo) -> Iterable[Finding]:
    declared = mod.declared_axis_names()
    chains = _enclosing_functions(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        q = mod.qualname(node.func)
        if q not in _COLLECTIVES:
            continue
        axis = _axis_arg(q, node)
        if axis is None:
            continue
        fns = chains.get(node, [])
        short = q.rsplit(".", 1)[-1]
        ok = False
        if isinstance(axis, ast.Constant) and isinstance(axis.value, str):
            ok = axis.value in declared
            what = f'literal "{axis.value}"'
        elif isinstance(axis, ast.Name):
            params = set().union(*(_param_names(f) for f in fns)) if fns \
                else set()
            ok = (axis.id in params or axis.id in _loop_axis_names(fns)
                  or axis.id in declared)
            what = f"name {axis.id!r}"
        elif isinstance(axis, (ast.Tuple, ast.List)):
            elems_ok = []
            for e in axis.elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    elems_ok.append(e.value in declared)
                else:
                    elems_ok.append(True)   # dynamic element: trust
            ok = all(elems_ok)
            what = "tuple of axis names"
        else:
            ok = True                        # dynamic expression: trust
            what = "axis expression"
        if not ok:
            yield Finding(
                file=mod.path, line=node.lineno, rule=RULE_ID,
                message=(
                    f"{short} over {what}, which no P(...) spec, Mesh "
                    f"declaration or *_axis parameter default in this file "
                    f"declares — typo'd collectives only fail on the traced "
                    f"path that runs"),
            )


def _check_arity(mod: ModuleInfo) -> Iterable[Finding]:
    for fn, deco in mod.shard_map_decorations():
        if deco.in_specs is None:
            continue
        n_specs = spec_tuple_len(deco.in_specs)
        if n_specs is None:
            continue
        n_params = positional_param_count(fn)
        if n_specs != n_params:
            yield Finding(
                file=mod.path, line=deco.line, rule=RULE_ID,
                message=(
                    f"shard_map in_specs has {n_specs} spec(s) but "
                    f"{fn.name}() takes {n_params} positional parameter(s) "
                    f"— every operand needs exactly one spec"),
            )


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not mod.imports_jax:
            continue
        out.extend(_check_collectives(mod))
        out.extend(_check_arity(mod))
    return out
