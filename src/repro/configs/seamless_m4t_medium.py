"""seamless-m4t-medium [audio] — enc-dec, multimodal [arXiv:2308.11596].

Audio frontend (mel-spectrogram + conformer feature extractor) is a STUB per
the assignment carve-out: input_specs() provides precomputed frame embeddings;
we implement the encoder/decoder transformer backbone (12L per stack).

long_500k is SKIPPED for this arch (DESIGN.md §2.5): a 500k-token decode for
a speech-translation enc-dec is architecturally meaningless and the decoder
is full-attention.
"""
from repro.configs.base import AttentionConfig, EncDecConfig, FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    citation="arXiv:2308.11596 (SeamlessM4T, medium)",
    num_layers=12,               # per stack: 12 encoder + 12 decoder
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        rope_theta=10000.0,
    ),
    encdec=EncDecConfig(enabled=True, encoder_seq_len=4096),
    frontend=FrontendStub(
        kind="audio_frames",
        tokens_per_item=4096,    # frame embeddings per utterance (stub)
        embed_dim=1024,
    ),
    microbatch=4,
    norm="layernorm",
    act="gelu",
    optimizer="adamw",
    long_context_mode="skip",
)
