from repro.core.dglmnet import (  # noqa: F401
    DGLMNETOptions,
    FitResult,
    dglmnet_iteration,
    fit,
    fit_python_loop,
)
from repro.core.distributed import (  # noqa: F401
    DistributedFitResult,
    fit_distributed,
    fit_distributed_sparse,
    make_dglmnet_step,
    make_dglmnet_step_sparse,
)
from repro.core.engine import SolverState, make_solver, make_step  # noqa: F401
from repro.core.linesearch import LineSearchResult, line_search  # noqa: F401
from repro.core.objective import (  # noqa: F401
    lambda_max,
    margins,
    neg_log_likelihood,
    objective,
    soft_threshold,
    working_stats,
)
from repro.core.regpath import (  # noqa: F401
    PathPoint,
    PathResult,
    regularization_path,
    regularization_path_distributed,
)
from repro.core.screening import (  # noqa: F401
    kkt_violations,
    nll_grad_abs_sparse,
    strong_rule_mask,
)
from repro.core.subproblem import (  # noqa: F401
    blocked_cycle_modes,
    cd_cycle_blocked_tile,
    cd_cycle_gram,
    cd_cycle_gram_tile,
    cd_cycle_residual,
    make_tile_solver,
    solve_subproblem,
)
from repro.core.truncated_gradient import TGOptions, truncated_gradient_fit  # noqa: F401
