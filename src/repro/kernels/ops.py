"""Jitted public wrappers + backend dispatch for the kernel layer.

Every solver-facing entry point lives here so the hot paths never care
which implementation serves them:

* on TPU the Pallas kernels compile natively;
* elsewhere the same math runs as the XLA-friendly jnp form (the Pallas
  kernels are still validated on CPU with ``interpret=True`` — by the
  tests, not the solvers, because interpret mode is an emulator, not a
  fast path).

The backend probe is cached once per process (it used to re-query
``jax.default_backend()`` on every wrapper call inside traced loops) and
feeds a single ``interpret`` decision shared by all kernel wrappers.

The slab entry points implement the sparse-native by-feature suite (see
``kernels/sparse_slab.py``): Gram/correlation and SpMV straight from
``(tile, K)`` ``(row_idx, values)`` slabs with sentinel slots contributing
exactly zero. ``prefer_slab_gram`` is the nnz-density heuristic deciding
sparse-native vs the dense-Gram fallback.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.kernels.gram_cd import gram_cd_pallas
from repro.kernels.logistic_stats import logistic_stats_pallas


@lru_cache(maxsize=1)
def _on_tpu() -> bool:
    """One backend query per process — the result cannot change under a
    running JAX runtime, and the probe must never run inside a trace."""
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


@lru_cache(maxsize=1)
def interpret_default() -> bool:
    """The single interpret decision every kernel wrapper threads through:
    compile natively on TPU, interpret (CPU validation) elsewhere."""
    return not _on_tpu()


def gram_cd(G, c, beta, dbeta0, lam, nu=1e-6):
    """One CD cycle on a Gram tile; returns the within-cycle delta d."""
    return gram_cd_pallas(G, c, beta, dbeta0, lam, nu,
                          interpret=interpret_default())


def prefer_blocked_cd(f: int, block: int) -> bool:
    """Tile-size heuristic for `cycle_mode="auto"`: the blocked cycle wins
    when it meaningfully shortens the dependent-step chain — at least two
    blocks per tile and a tile wide enough (F >= 32) that the F-step
    scalar chain, not the Gram matmul, dominates the tile (CPU-measured;
    the `--cycle` bench section tracks the crossover). Below that, or at
    block=1 (== the sequential chain), dispatch stays on ``gram_cd``."""
    return block > 1 and f >= 2 * block and f >= 32


def blocked_cd(G, c, beta, dbeta0, lam, nu=1e-6, *, block: int = 16,
               dom_tol=None):
    """Blocked semi-parallel CD cycle on a Gram tile (F/B dependent steps
    instead of F); same contract as :func:`gram_cd`. The per-block
    Gershgorin safeguard (halve B, then fall back to the sequential chain)
    is resolved outside the kernel from G alone."""
    from repro.core.subproblem import DOM_TOL
    from repro.kernels.blocked_cd import blocked_cd_pallas

    return blocked_cd_pallas(
        G, c, beta, dbeta0, lam, nu, block=block,
        dom_tol=DOM_TOL if dom_tol is None else dom_tol,
        interpret=interpret_default())


def logistic_stats(m, y, *, block: int = 4096):
    """Fused (w, z, nll) from margins — one pass over the examples axis.

    This is the dispatch point the outer iteration uses (core/engine.py).
    The Pallas kernel is engaged only for *concrete* arrays on TPU: inside
    a trace (the engine's jitted while_loop, where ``m``/``y`` may be
    GSPMD-sharded global arrays) ``pallas_call`` has no partitioning rule,
    so traced call sites always get the fused jnp form — XLA fuses it into
    one sweep and partitions it like any elementwise chain. Shard-local
    TPU code that wants the kernel calls ``logistic_stats_pallas``
    directly.
    """
    if _on_tpu() and not isinstance(m, jax.core.Tracer):
        return logistic_stats_pallas(m, y, block=block, interpret=False)
    from repro.kernels.ref import logistic_stats_ref

    return logistic_stats_ref(m, y)


# ---------------------------------------------------------------------------
# sparse slab suite
# ---------------------------------------------------------------------------

def prefer_slab_gram(n_loc: int, k: int) -> bool:
    """nnz-density heuristic: sparse-native Gram when the match join
    (O(T^2 K^2) VPU ops) beats the dense path (O(nnz) scatter +
    O(n_loc T^2) MXU FLOPs). The measured crossover sits near
    K ~ sqrt(n_loc/8) with margin to spare — the paper's truly sparse
    regime (webspam K is single digits) clears it at any realistic
    n_loc, while moderate-density slabs fall back to densify-once."""
    return 8 * k * k <= n_loc


def _sentinel_zeroed(rows, vals, w, r, n_loc: int):
    """Gathered operands with sentinel slots contributing exactly zero.

    Gathers clamp the slab's row indices into range and then mask the
    result on the *original* validity predicate, so padding slots (and any
    adversarial values parked on them) can never pick up a real example's
    weight — in particular not the last row's, which is what a plain
    clamped gather would silently do.
    """
    valid = rows < n_loc
    idx = jnp.where(valid, rows, 0)
    va = jnp.where(valid, vals, 0.0).astype(jnp.float32)
    wv = jnp.where(valid, w.astype(jnp.float32)[idx], 0.0) * va
    cva = va * jnp.where(valid, (w * r).astype(jnp.float32)[idx], 0.0)
    return jnp.minimum(rows, n_loc), va, wv, cva


def slab_gram(rows, vals, w, r):
    """Weighted Gram tile and correlation straight from a feature slab.

    rows/vals: (T, K) by-feature slab, local row indices, sentinel
    ``n_loc`` (= ``w.shape[0]``) marking padding. Returns
    ``(G (T, T), c (T,))`` with G = X_F^T diag(w) X_F and c = X_F^T (w r)
    — no ``(n_loc, T)`` densify anywhere.
    """
    n_loc = w.shape[0]
    safe, va, wv, cva = _sentinel_zeroed(rows, vals, w, r, n_loc)
    if _on_tpu():
        from repro.kernels.sparse_slab import slab_gram_pallas

        return slab_gram_pallas(safe, wv, va, cva, interpret=False)
    # jnp form of the same match join: broadcast compares of the slot rows
    # gate the outer product of the weighted values
    t, k = rows.shape
    rf = safe.reshape(-1)
    wvf = wv.reshape(-1)
    if t * k <= 2048:
        # one-shot (TK, TK) match — fastest at the small K the heuristic
        # admits, and bounded to a ~16 MiB buffer
        match = (rf[:, None] == rf[None, :]).astype(jnp.float32)
        G = (wvf[:, None] * match * va.reshape(-1)[None, :]
             ).reshape(t, k, t, k).sum(axis=(1, 3))
    else:
        # chunk over the right-hand slot axis to bound the match buffer
        def step(Gacc, kp):
            mk = (rf[:, None] == safe[None, :, kp]).astype(jnp.float32)
            contrib = (wvf[:, None] * mk).reshape(t, k, t).sum(axis=1)
            return Gacc + contrib * va[None, :, kp], None

        G, _ = jax.lax.scan(step, jnp.zeros((t, t), jnp.float32),
                            jnp.arange(k))
    return G, jnp.sum(cva, axis=1)


def slab_spmv(rows, vals, d, *, n_loc: int):
    """``X_F @ d`` from a feature slab: (n_loc,) per-example product.

    O(nnz) work — the sparse-native residual/margin update. On TPU the
    Pallas kernel tiles the output rows with a broadcast-compare
    accumulate; elsewhere a 1-D scatter-add over nnz (3x cheaper on CPU
    than densify + matvec, and the scatter target is O(n_loc), never the
    (n_loc, T) tile).
    """
    valid = rows < n_loc
    dv = jnp.where(valid, vals, 0.0).astype(jnp.float32) * d[:, None]
    if _on_tpu():
        from repro.kernels.sparse_slab import slab_spmv_pallas

        return slab_spmv_pallas(jnp.minimum(rows, n_loc), dv, n_loc=n_loc,
                                interpret=False)
    out = jnp.zeros(n_loc + 1, jnp.float32)
    out = out.at[jnp.minimum(rows, n_loc).reshape(-1)].add(dv.reshape(-1))
    return out[:n_loc]


def slab_path_spmv(rows, vals, lam_idx, betas, *, n_loc: int):
    """Per-example-lambda slab SpMV: the serving layer's batched scoring
    primitive (``repro.serve``).

    rows/vals: (T, K) by-feature request slab with *local* example (=
    request row) indices, sentinel ``n_loc``; ``lam_idx`` (n_loc,) int32
    picks each example's operating point in the stacked ``betas`` (L, T)
    coefficient path. Returns the (n_loc,) scores
    ``out[i] = sum_jk vals[j,k] * betas[lam_idx[i], j] [rows[j,k] == i]``.

    The per-entry coefficient gather replaces ``d[:, None]`` in
    :func:`slab_spmv`; everything downstream (sentinel masking, the CPU
    scatter-add, the TPU Pallas row-block accumulate) is shared, so at a
    uniform ``lam_idx == l`` the scores are bit-identical to
    ``slab_spmv(rows, vals, betas[l], n_loc=n_loc)`` — the serve-vs-
    ``decision_function`` equivalence the tests pin down.
    """
    valid = rows < n_loc
    safe = jnp.minimum(rows, n_loc)
    # sentinel rows read lam_idx[0] through the clamp; their dv is zeroed
    # by the validity mask so the read value never matters
    li = jnp.take(lam_idx, jnp.where(valid, rows, 0))            # (T, K)
    feat = jnp.arange(rows.shape[0], dtype=jnp.int32)[:, None]
    bsel = betas.astype(jnp.float32)[li, feat]                   # (T, K)
    dv = jnp.where(valid, vals, 0.0).astype(jnp.float32) * bsel
    if _on_tpu():
        from repro.kernels.sparse_slab import slab_spmv_pallas

        return slab_spmv_pallas(safe, dv, n_loc=n_loc, interpret=False)
    out = jnp.zeros(n_loc + 1, jnp.float32)
    out = out.at[safe.reshape(-1)].add(dv.reshape(-1))
    return out[:n_loc]


def slab_corr(rows, vals, v):
    """Per-feature correlation ``X_F^T v`` from a slab: the gather-reduce
    behind the sparse screen (sentinel slots masked to exact zero)."""
    n = v.shape[0]
    valid = rows < n
    va = jnp.where(valid, vals, 0.0).astype(jnp.float32)
    vg = jnp.where(valid, v.astype(jnp.float32)[jnp.where(valid, rows, 0)],
                   0.0)
    return jnp.sum(va * vg, axis=-1)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Blocked online-softmax attention (forward)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k,
                                  interpret=interpret_default())
