from repro.models.params import (  # noqa: F401
    count_params_analytic,
    forward,
    init_cache,
    init_params,
    is_encdec,
    param_bytes,
)
from repro.models.transformer import init_lm_cache, init_lm_params, lm_forward, segments_of  # noqa: F401
