"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]."""
from repro.configs.base import AttentionConfig, HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    citation="arXiv:2411.15242 (Zamba2)",
    num_layers=81,
    d_model=3584,
    d_ff=14336,                  # shared transformer block MLP
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=32,         # shared block is full MHA
        head_dim=112,            # 3584 / 32
        rope_theta=10000.0,
    ),
    ssm=SSMConfig(
        d_state=64,
        head_dim=64,             # d_inner = 7168 -> 112 SSD heads
        expand=2,
        conv_width=4,
        chunk_size=256,
    ),
    hybrid=HybridConfig(attn_every=6, shared_attn=True),
    norm="rmsnorm",
    tie_embeddings=True,
    microbatch=4,
    optimizer="adamw",
    long_context_mode="native",  # SSM spine; shared-attn blocks go sliding-window
    long_context_window=8192,
)
