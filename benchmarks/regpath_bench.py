"""Regularization-path timing: seed-style host loop vs the device-resident
screened engine. Emits ``BENCH_regpath.json``.

Two drivers over the identical warm-started lambda grid (Algorithm 5):

* **seed-style** — the seed's Python outer loop (`fit_python_loop`): one
  objective sync per outer iteration, full-p subproblems at every lambda.
* **engine** — `regularization_path(screen=True)`: jitted while_loop solves
  (core/engine.py) restricted to the strong-rule/KKT active set
  (core/screening.py), capacity-bucketed so the whole path reuses a
  handful of compilations.

Both sides are run once to compile (cold) and once compiled (warm); the
headline comparison — and the CI gate — is warm wall-clock, which is what
repeated production paths pay.

``--distributed`` adds a third driver — ``regularization_path_distributed``
on a 2x4 fake-device mesh (same screened engine, restricted solves on the
mesh); ``--sparse`` runs it over by-feature (row_idx, values) slabs so the
whole path (screen included) never materializes a dense X. ``--streamed``
adds the HBM-budgeted residency section: the same slab-bucket path with
``device_budget_bytes`` one bucket short of the padded slab total, so the
``BucketResidencyManager`` double-buffers buckets host->device through
every pass — reported against the resident run (warm ratio, prefetch hit
rate) with a bit-identity check. ``--cycle``
adds the blocked-vs-sequential CD cycle section: a per-tile microbench of
the semi-parallel cycle against the F-step chain plus the engine path
rerun with ``cycle_mode="blocked"`` (the CI gate keeps the per-tile
speedup from collapsing — the chain silently re-serializing). ``--serve``
adds the online path-serving section (``repro.serve`` throughput at two
batch sizes; gated catastrophic-only).

    PYTHONPATH=src python -m benchmarks.regpath_bench            # paper-ish shape
    PYTHONPATH=src python -m benchmarks.regpath_bench --tiny     # CI smoke
    PYTHONPATH=src python -m benchmarks.regpath_bench --tiny --distributed --sparse --streamed --kernels --cycle --serve
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

if "--distributed" in sys.argv:
    # the fake-device flag must land before the first jax import; an
    # inherited count below 8 can't be overridden here, so fail loudly
    # instead of letting make_dev_mesh(2, 4) error opaquely later
    _flags = os.environ.get("XLA_FLAGS", "")
    _m = re.search(r"--xla_force_host_platform_device_count=(\d+)", _flags)
    if _m is None:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        )
    elif int(_m.group(1)) < 8:
        sys.exit(
            f"--distributed needs >= 8 fake devices but XLA_FLAGS already "
            f"forces {_m.group(1)}; unset XLA_FLAGS or raise the count"
        )

import jax
import jax.numpy as jnp

from repro.configs.base import GLMConfig
from repro.core import DGLMNETOptions, fit_python_loop, lambda_max, regularization_path
from repro.data.synthetic import make_glm_dataset


def seed_style_path(X, y, path_len: int, opts: DGLMNETOptions):
    """The seed's path driver: warm-started loop of host-driven fits."""
    lmax = float(lambda_max(X, y))
    beta = None
    rows = []
    for i in range(1, path_len + 1):
        lam = lmax * 2.0 ** (-i)
        res = fit_python_loop(X, y, lam, beta0=beta, opts=opts)
        beta = res.beta
        rows.append({"lam": lam, "nnz": res.nnz, "f": res.f,
                     "n_iters": res.n_iters})
    return rows


def frontdoor_path(X, y, path_len: int, opts: DGLMNETOptions):
    """The screened engine path through the ``repro.api`` front door
    (``LogisticL1.path`` — what ``regularization_path`` now shims to)."""
    from repro.api import DenseDesign, LogisticL1

    pts = LogisticL1(opts=opts).path(DenseDesign(X), y, path_len=path_len,
                                     screen=True)
    return [{"lam": p.lam, "nnz": p.nnz, "f": p.f, "n_iters": p.n_iters,
             **{f"screen_{k}": v for k, v in p.screen.items()}} for p in pts]


def distributed_path(data, y, path_len: int, opts: DGLMNETOptions, mesh):
    from repro.core import regularization_path_distributed

    pts = regularization_path_distributed(data, y, mesh, path_len=path_len,
                                          opts=opts)
    return [{"lam": p.lam, "nnz": p.nnz, "f": p.f, "n_iters": p.n_iters,
             **{f"screen_{k}": v for k, v in p.screen.items()}} for p in pts]


def _timed(fn):
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return out, time.perf_counter() - t0


def bench_serve(X, y, path_len: int, opts: DGLMNETOptions,
                batch_sizes=(64, 256), steps: int = 20) -> dict:
    """Online-scoring throughput of the serving layer (``repro.serve``):
    a certified path published into a ``PathStore``, synthetic hashed-
    token traffic through the batcher, one jitted ``slab_path_spmv``
    dispatch per drain. Reported per batch size — scores/sec is the
    serving headline the CI gate floors (catastrophic-only: throughput
    rides host-side packing and flaps more than path wall-clock) plus
    the submit->score latency histogram (p50/p95/p99 seconds) recorded
    through ``repro.obs``."""
    import numpy as np

    from repro.api import DenseDesign, LogisticL1
    from repro.launch.serve_glm import make_traffic, serve_loop
    from repro.obs import observe
    from repro.serve import PathScorer, PathStore, RequestBatcher

    path = LogisticL1(opts=opts).path(DenseDesign(X), y, path_len=path_len)
    p = X.shape[1]
    scorer = PathScorer(PathStore(path))
    rng = np.random.default_rng(0)
    out = {"path_len": len(path), "p": p, "batch": {}}
    for bs in batch_sizes:
        batcher = RequestBatcher(p, max_batch=bs)
        reqs, lams = make_traffic(rng, p, bs * steps, path.lambdas)
        for r, lam in zip(reqs[:bs], lams[:bs]):   # compile warm-up drain
            batcher.submit(r, lam)
        scorer.score(*batcher.drain())
        # each batch size gets its own observe() window so the
        # submit->score latency histogram (fed by mark_scored inside
        # serve_loop) is per-row, not cumulative across sizes
        with observe() as obs:
            total, secs, _ = serve_loop(scorer, batcher, reqs, lams,
                                        steps=steps)
        hist = obs.summary().get("histograms", {}).get("serve.latency_s") \
            or {}
        out["batch"][str(bs)] = {
            "scored": total, "warm_s": secs,
            "scores_per_s": total / max(secs, 1e-12),
            "latency_s": {k: hist.get(k)
                          for k in ("p50", "p95", "p99", "count")},
        }
    return out


def bench_streamed(n: int, p: int, path_len: int, opts: DGLMNETOptions,
                   mesh, dp: int) -> dict:
    """Streamed (HBM-budgeted) vs resident slab-bucket path at matched
    shapes: the same screened driver over the same ``SlabBuckets``, once
    fully device-resident and once with ``device_budget_bytes`` one
    bucket short of the padded total, so the residency manager must
    double-buffer host->device through every pass. Reports the
    streamed/resident warm ratio (the price of not fitting in HBM), the
    prefetch hit rate, and a bit-identity check — streaming changes
    where buckets live, never the math.

    The section rebuilds its own stratified-density X: uniform-density
    columns land in one or two nnz capacity classes, and with fewer than
    three buckets the double buffer already covers the slab (nothing to
    evict, nothing to stream)."""
    import numpy as np

    from repro.api import LogisticL1, as_design
    from repro.data.byfeature import to_by_feature, to_slab_buckets

    rng = np.random.default_rng(0)
    levels = [4, 12, 28, min(60, n // 2)]
    X = np.zeros((n, p), np.float32)
    for j in range(p):
        rows = rng.choice(n, size=levels[j % len(levels)], replace=False)
        X[rows, j] = rng.normal(size=rows.size).astype(np.float32)
    w = rng.normal(size=p) * (rng.random(p) < 0.3)
    prob = 1.0 / (1.0 + np.exp(-(X @ w)))
    y = np.where(rng.random(n) < prob, 1.0, -1.0).astype(np.float32)

    slabs = to_slab_buckets(to_by_feature(X), dp)
    assert len(slabs.buckets) >= 3, slabs.k_classes
    tile = opts.tile
    sizing = as_design(slabs, mesh=mesh, tile=tile)
    budget = sizing.slab_nbytes(tile) - min(sizing.slab_bucket_nbytes(tile))
    last = {}

    def run_path(budget_bytes):
        # a fresh design per call: resident timing pays its one-shot
        # device puts the same way streamed pays per-pass streaming, so
        # the warm ratio compares end-to-end placement + solve
        des = as_design(slabs, mesh=mesh, tile=tile,
                        device_budget_bytes=budget_bytes)
        pts = LogisticL1(opts=opts, mesh=mesh).path(des, y,
                                                    path_len=path_len)
        last["des"] = des
        last["pts"] = pts
        return [pt.beta for pt in pts]

    _, res_cold = _timed(lambda: run_path(None))
    _, res_warm = _timed(lambda: run_path(None))
    res_pts = last["pts"]
    _, str_cold = _timed(lambda: run_path(budget))
    _, str_warm = _timed(lambda: run_path(budget))
    stats = last["des"].residency_stats()[tile]
    assert stats["streamed"] and stats["evictions"] > 0, stats
    bit_identical = all(
        a.lam == b.lam and a.f == b.f and a.nnz == b.nnz
        and bool(jnp.all(a.beta == b.beta))
        for a, b in zip(res_pts, last["pts"]))
    return {
        "n_buckets": stats["n_buckets"],
        "budget_bytes": stats["budget_bytes"],
        "total_bytes": stats["total_bytes"],
        "resident_cold_s": res_cold, "resident_warm_s": res_warm,
        "streamed_cold_s": str_cold, "streamed_warm_s": str_warm,
        "warm_ratio_streamed_vs_resident": str_warm / max(res_warm, 1e-12),
        "prefetch": {k: stats[k] for k in ("hits", "misses", "evictions",
                                           "puts", "bytes_h2d",
                                           "hit_rate")},
        "bit_identical": bit_identical,
    }


def run(*, n: int = 2048, p: int = 4096, path_len: int = 20,
        density: float = 0.2, k_true: int = 64,
        out_path: str = "BENCH_regpath.json",
        distributed: bool = False, sparse: bool = False,
        streamed: bool = False,
        kernels: bool = False, cycle: bool = False, block: int = 16,
        serve: bool = False, tiny: bool = False,
        trace_summary: str = None) -> dict:
    # sparse ground truth (k_true << p): the large-p regime screening is
    # for — most features never activate anywhere on the path
    cfg = GLMConfig(name="regpath-bench", num_examples=int(n / 0.8),
                    num_features=p, density=density)
    ds = make_glm_dataset(cfg, jax.random.key(0), k_true=k_true)
    X, y = ds.X_train, ds.y_train
    opts = DGLMNETOptions(num_blocks=8, tile=128, max_iters=40)
    print(f"# regpath bench: n={X.shape[0]} p={X.shape[1]} "
          f"path_len={path_len} density={density}")

    seed_rows, seed_cold = _timed(lambda: seed_style_path(X, y, path_len, opts))
    _, seed_warm = _timed(lambda: seed_style_path(X, y, path_len, opts))
    eng_rows, eng_cold = _timed(lambda: frontdoor_path(X, y, path_len, opts))
    _, eng_warm = _timed(lambda: frontdoor_path(X, y, path_len, opts))

    if trace_summary:
        # one extra warm front-door leg under repro.obs: the summary's
        # per-phase totals (screen_round / restricted_solve / kkt_check /
        # point_finish) let compare_bench explain a warm-path regression
        # by phase instead of one opaque wall number
        from repro.obs import observe, write_summary

        with observe() as obs:
            _, traced_warm = _timed(
                lambda: frontdoor_path(X, y, path_len, opts))
        summary = obs.summary()
        summary["bench"] = {"section": "frontdoor",
                            "traced_warm_s": traced_warm}
        write_summary(summary, trace_summary)
        print(f"# trace summary: {trace_summary} "
              f"(traced warm {traced_warm:.2f}s; "
              f"python -m repro.obs.report {trace_summary})")

    report = {
        "config": {"n": int(X.shape[0]), "p": int(X.shape[1]),
                   "path_len": path_len, "density": density, "k_true": k_true,
                   "opts": {"num_blocks": opts.num_blocks, "tile": opts.tile,
                            "max_iters": opts.max_iters}},
        "seed_style": {"cold_s": seed_cold, "warm_s": seed_warm,
                       "per_lambda": seed_rows},
        # renamed from "engine" when the path moved behind the repro.api
        # front door; compare_bench accepts either name so the checked-in
        # baselines stay valid
        "frontdoor": {"cold_s": eng_cold, "warm_s": eng_warm,
                      "per_lambda": eng_rows},
        "speedup_warm": seed_warm / max(eng_warm, 1e-12),
        "speedup_cold": seed_cold / max(eng_cold, 1e-12),
        "frontdoor_strictly_faster": eng_warm < seed_warm,
    }
    if distributed:
        from repro.launch.mesh import make_dev_mesh

        mesh = make_dev_mesh(2, 4)
        n_trim = (X.shape[0] // 2) * 2
        Xd, yd = X[:n_trim], y[:n_trim]
        if sparse:
            from repro.data.byfeature import to_by_feature, to_slabs

            row_idx, values, _ = to_slabs(to_by_feature(Xd), 2)
            data = (row_idx, values)
        else:
            data = Xd
        dist_rows, dist_cold = _timed(
            lambda: distributed_path(data, yd, path_len, opts, mesh))
        _, dist_warm = _timed(
            lambda: distributed_path(data, yd, path_len, opts, mesh))
        report["distributed"] = {
            "mesh": dict(mesh.shape), "sparse": sparse,
            "cold_s": dist_cold, "warm_s": dist_warm,
            "per_lambda": dist_rows,
        }
        print(f"# distributed{' (sparse slabs)' if sparse else ''}: "
              f"cold {dist_cold:.2f}s warm {dist_warm:.2f}s")
        if streamed:
            report["streamed"] = bench_streamed(n_trim, X.shape[1],
                                                path_len, opts, mesh, 2)
            st = report["streamed"]
            print(f"# streamed: warm {st['streamed_warm_s']:.2f}s vs "
                  f"resident {st['resident_warm_s']:.2f}s "
                  f"({st['warm_ratio_streamed_vs_resident']:.2f}x) under "
                  f"budget {st['budget_bytes']}/{st['total_bytes']}B over "
                  f"{st['n_buckets']} buckets; prefetch hit rate "
                  f"{st['prefetch']['hit_rate']:.2f}; bit_identical="
                  f"{st['bit_identical']}")
    if cycle:
        import dataclasses

        from benchmarks.kernels_bench import bench_cycle_tile

        # the engine path again, with every within-tile chain swapped for
        # the blocked semi-parallel cycle — same screened driver, so the
        # warm delta is exactly the chain-vs-blocked difference
        blk_opts = dataclasses.replace(opts, cycle_mode="blocked",
                                       block=block)
        blk_rows, blk_cold = _timed(lambda: frontdoor_path(X, y, path_len,
                                                        blk_opts))
        _, blk_warm = _timed(lambda: frontdoor_path(X, y, path_len, blk_opts))
        # acceptance: the blocked path must land on the sequential path's
        # objectives — the safeguard + line search make it an acceleration,
        # not an approximation
        max_gap = max(
            abs(b["f"] - s["f"]) / max(abs(s["f"]), 1e-9)
            for b, s in zip(blk_rows, eng_rows)
        )
        # the microbench is the gate: fixed canonical shapes in CI and
        # locally (like the slab suite — the gate needs the regime where
        # the blocked win is decisive, which tiny path shapes can't
        # provide: a 32-row tile is rank-deficient and the safeguard
        # rightly refuses to parallelize it), and reps stay high (the
        # cycle is ~30us — a flaky floor would be worse than a slow one)
        report["cycle"] = {
            "block": block,
            # bench-shape tile: F=128 from n_loc=2048 density-0.2 rows
            "per_tile": bench_cycle_tile(f=128, n_loc=2048, block=block,
                                         reps=30),
            # production-mesh-depth tile: n_loc = 2048/16 (16x16 mesh data
            # extent). Informational, not gated: at this depth the Gram
            # tile is near rank-deficient and the Gershgorin safeguard
            # demotes most blocks — the entry tracks how the safeguard
            # behaves, not a speedup floor.
            "per_tile_mesh16": bench_cycle_tile(f=128, n_loc=128,
                                               block=block, reps=30),
            "path": {"cold_s": blk_cold, "warm_s": blk_warm,
                     "sequential_warm_s": eng_warm,
                     "speedup_vs_sequential": eng_warm / max(blk_warm, 1e-12),
                     "max_rel_f_gap": max_gap,
                     "per_lambda": blk_rows},
        }
        for key in ("per_tile", "per_tile_mesh16"):
            pt = report["cycle"][key]
            print(f"# cycle {key} (n_loc={pt['n_loc']}): cycle "
                  f"{pt['blocked_us']:.0f}us vs {pt['sequential_us']:.0f}us "
                  f"({pt['speedup']:.2f}x); tile step "
                  f"{pt['step_blocked_us']:.0f}us vs "
                  f"{pt['step_sequential_us']:.0f}us "
                  f"({pt['step_speedup']:.2f}x); modes={pt['modes']}")
        print(f"# cycle path: warm {blk_warm:.2f}s vs {eng_warm:.2f}s "
              f"sequential (max rel f gap {max_gap:.1e})")
    if kernels:
        from benchmarks.kernels_bench import bench_slab_suite

        # same shapes in CI and locally: the gate needs the regime where
        # the sparse-native win is decisive (a densify regression reads as
        # speedup ~1x, which tiny shapes cannot distinguish from noise);
        # fewer reps keep the tiny budget
        report["kernels"] = bench_slab_suite(reps=5 if tiny else 10)
        for name, row in report["kernels"].items():
            if isinstance(row, dict):
                print(f"# kernel {name}: sparse {row['sparse_us']:.0f}us "
                      f"vs densify {row['densify_us']:.0f}us "
                      f"({row['speedup']:.2f}x)")
    if serve:
        report["serve"] = bench_serve(X, y, path_len, opts,
                                      steps=10 if tiny else 30)
        for bs, row in report["serve"]["batch"].items():
            lat = row["latency_s"]
            lat_txt = ""
            if lat.get("count"):
                lat_txt = (f"; latency p50 {lat['p50'] * 1e3:.2f}ms / "
                           f"p95 {lat['p95'] * 1e3:.2f}ms / "
                           f"p99 {lat['p99'] * 1e3:.2f}ms")
            print(f"# serve batch {bs}: {row['scores_per_s']:,.0f} "
                  f"scores/sec ({row['scored']} in {row['warm_s']:.3f}s)"
                  + lat_txt)
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"# seed-style: cold {seed_cold:.2f}s warm {seed_warm:.2f}s")
    print(f"# frontdoor:  cold {eng_cold:.2f}s warm {eng_warm:.2f}s")
    print(f"# warm speedup {report['speedup_warm']:.2f}x "
          f"(strictly faster: {report['frontdoor_strictly_faster']})")
    print(f"# wrote {out_path}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shapes (seconds, not minutes)")
    ap.add_argument("--distributed", action="store_true",
                    help="also time regularization_path_distributed on a "
                         "2x4 fake-device mesh")
    ap.add_argument("--sparse", action="store_true",
                    help="with --distributed: run over by-feature sparse "
                         "slabs (no dense X on the mesh path)")
    ap.add_argument("--streamed", action="store_true",
                    help="with --distributed: add the HBM-budgeted "
                         "streamed-residency section (streamed vs "
                         "resident warm path, prefetch hit rate, "
                         "bit-identity)")
    ap.add_argument("--kernels", action="store_true",
                    help="add the slab kernel microbench section "
                         "(sparse-native vs densify at matched shapes)")
    ap.add_argument("--cycle", action="store_true",
                    help="add the blocked-vs-sequential CD cycle section "
                         "(per-tile microbench + blocked end-to-end warm "
                         "path)")
    ap.add_argument("--block", type=int, default=16,
                    help="B: coordinates per semi-parallel block for "
                         "--cycle (default 16)")
    ap.add_argument("--serve", action="store_true",
                    help="add the online path-serving section (scores/sec "
                         "through repro.serve at two batch sizes)")
    ap.add_argument("--out", default="BENCH_regpath.json")
    ap.add_argument("--trace-summary", default=None, metavar="PATH",
                    help="re-run the warm front-door leg under repro.obs "
                         "and write its per-phase summary JSON to PATH "
                         "(render with python -m repro.obs.report; feed "
                         "to compare_bench --fresh-trace)")
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--p", type=int, default=4096)
    ap.add_argument("--path-len", type=int, default=20)
    ap.add_argument("--density", type=float, default=0.2)
    args = ap.parse_args()
    if args.tiny:
        args.n, args.p, args.path_len = 512, 256, 6
    if args.sparse and not args.distributed:
        ap.error("--sparse requires --distributed")
    if args.streamed and not args.distributed:
        ap.error("--streamed requires --distributed")
    report = run(n=args.n, p=args.p, path_len=args.path_len,
                 density=args.density, out_path=args.out,
                 distributed=args.distributed, sparse=args.sparse,
                 streamed=args.streamed,
                 kernels=args.kernels, cycle=args.cycle, block=args.block,
                 serve=args.serve, tiny=args.tiny,
                 trace_summary=args.trace_summary)
    # Screening pays in proportion to p; tiny CI-smoke shapes sit below the
    # break-even point, so the strictly-faster gate applies to real shapes.
    if not args.tiny and not report["frontdoor_strictly_faster"]:
        raise SystemExit("FAIL: front-door path not strictly faster than seed-style")


if __name__ == "__main__":
    main()
