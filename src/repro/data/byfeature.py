"""The paper's "by feature" data layout (§3, Table 1).

d-GLMNET partitions the dataset by features: machine m stores
X_m = {L_j | j in S_m}, L_j = {(i, x_ij) | x_ij != 0}. The paper produces
this with a Map/Reduce pass; here the layout transformation is an explicit,
tested function pair:

* ``to_by_feature`` — CSC-like padded arrays (row_idx (p, K), values (p, K)),
  K = max nnz per feature, sentinel row = n. JAX-friendly fixed shapes; this
  is what lets webspam-scale (16.6M features, 1.2e9 nnz) fit on the mesh
  where a dense X cannot (DESIGN.md §2.3).
* ``densify_tile`` — scatter a tile of features back to a dense (n, F) block
  for the MXU Gram stage (on-the-fly densification).
* text round-trip of the paper's Table-1 line format for interop:
  ``feature_id (example_id:value) (example_id:value) ...``
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TextIO, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ByFeature:
    row_idx: jnp.ndarray     # (p, K) int32, sentinel = n for padding
    values: jnp.ndarray      # (p, K) float32
    n: int                   # number of examples

    @property
    def p(self) -> int:
        return self.row_idx.shape[0]

    @property
    def nnz(self) -> int:
        return int((self.row_idx < self.n).sum())

    def gather(self, beta, mask, cap: int):
        """Screened working set as a restricted ByFeature (see
        :func:`gather_features`). Returns ``(bf_sub, beta_sub, idx)``."""
        r, v, b, idx = gather_features(
            self.row_idx, self.values, beta, mask, cap, sentinel=self.n
        )
        return ByFeature(r, v, self.n), b, idx


def to_by_feature(X) -> ByFeature:
    """Dense (n, p) -> by-feature padded CSC (the Reduce step of paper §3)."""
    Xn = np.asarray(X)
    n, p = Xn.shape
    cols = [np.nonzero(Xn[:, j])[0] for j in range(p)]
    k = max((len(c) for c in cols), default=1) or 1
    row_idx = np.full((p, k), n, np.int32)
    values = np.zeros((p, k), np.float32)
    for j, c in enumerate(cols):
        row_idx[j, : len(c)] = c
        values[j, : len(c)] = Xn[c, j]
    return ByFeature(jnp.asarray(row_idx), jnp.asarray(values), n)


def densify_tile(bf: ByFeature, start: int, width: int) -> jnp.ndarray:
    """Features [start, start+width) -> dense (n, width) block via scatter."""
    rows = jax.lax.dynamic_slice(bf.row_idx, (start, 0), (width, bf.row_idx.shape[1]))
    vals = jax.lax.dynamic_slice(bf.values, (start, 0), (width, bf.values.shape[1]))
    out = jnp.zeros((bf.n + 1, width), jnp.float32)  # +1 row swallows sentinels
    cols = jnp.broadcast_to(jnp.arange(width)[:, None], rows.shape)
    out = out.at[rows.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))
    return out[: bf.n]


def densify(bf: ByFeature) -> jnp.ndarray:
    return densify_tile(bf, 0, bf.p)


# ---------------------------------------------------------------------------
# Table-1 text format
# ---------------------------------------------------------------------------

def write_table1(bf: ByFeature, fh: TextIO) -> None:
    ri = np.asarray(bf.row_idx)
    vv = np.asarray(bf.values)
    for j in range(bf.p):
        live = ri[j] < bf.n
        cells = " ".join(f"({int(i)}:{float(v):.9g})" for i, v in zip(ri[j][live], vv[j][live]))
        fh.write(f"{j} {cells}\n".rstrip() + "\n")


def read_table1(fh: TextIO, n: int) -> ByFeature:
    """Parse the Table-1 format honoring the leading feature id.

    Lines may arrive in any order (a Map/Reduce shuffle gives no ordering
    guarantee); the feature id — not the line position — decides where a
    feature lands. Ids absent from the file become empty (all-sentinel)
    features; a repeated id keeps the last occurrence.
    """
    feats = {}
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        j = int(parts[0])
        entries = [p.strip("()").split(":") for p in parts[1:]]
        feats[j] = ([int(i) for i, _ in entries], [float(v) for _, v in entries])
    p = max(feats) + 1 if feats else 0
    k = max((len(r) for r, _ in feats.values()), default=1) or 1
    row_idx = np.full((p, k), n, np.int32)
    values = np.zeros((p, k), np.float32)
    for j, (r, v) in feats.items():
        row_idx[j, : len(r)] = r
        values[j, : len(v)] = v
    return ByFeature(jnp.asarray(row_idx), jnp.asarray(values), n)


def partition_features(p: int, num_machines: int) -> Tuple[np.ndarray, ...]:
    """Contiguous feature blocks S_1..S_M (paper's Reduce-side partitioning)."""
    bounds = np.linspace(0, p, num_machines + 1).astype(int)
    return tuple(np.arange(bounds[i], bounds[i + 1]) for i in range(num_machines))


# ---------------------------------------------------------------------------
# Mesh slabs: the (p, DP, K) layout the distributed sparse step consumes
# ---------------------------------------------------------------------------

def to_slabs(bf: ByFeature, dp: int):
    """Re-key a by-feature layout for ``dp`` data shards.

    Examples are split into ``dp`` contiguous shards of n_loc = n/dp rows
    each; every feature's entries are regrouped per shard with *local* row
    indices (sentinel n_loc). Returns ``(row_idx (p, dp, K'), values
    (p, dp, K'), n_loc)`` — exactly the operands of
    ``core.distributed.make_dglmnet_step_sparse`` / ``fit_distributed_sparse``
    under sharding P(model, data, None).
    """
    if bf.n % dp:
        raise ValueError(
            f"data shard count {dp} must divide n={bf.n} (trim or pad upstream)"
        )
    n_loc = bf.n // dp
    ri = np.asarray(bf.row_idx)
    vv = np.asarray(bf.values)
    p = bf.p
    # fully vectorized regroup (p can be webspam-scale): flatten the live
    # entries, key them by (feature, shard), and compute each entry's rank
    # within its group from the stable sort of the keys
    j_idx, k_idx = np.nonzero(ri < bf.n)
    rows = ri[j_idx, k_idx]
    vals = vv[j_idx, k_idx]
    shard = rows // max(n_loc, 1)
    group = j_idx * dp + shard
    counts = np.bincount(group, minlength=p * dp)
    k = max(1, int(counts.max()) if counts.size else 1)
    order = np.argsort(group, kind="stable")
    group_sorted = group[order]
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    rank = np.arange(len(group_sorted)) - starts[group_sorted]
    row_idx = np.full((p, dp, k), n_loc, np.int32)
    values = np.zeros((p, dp, k), np.float32)
    jj, ss = group_sorted // dp, group_sorted % dp
    row_idx[jj, ss, rank] = (rows - shard * n_loc)[order]
    values[jj, ss, rank] = vals[order]
    return jnp.asarray(row_idx), jnp.asarray(values), n_loc


def gather_features(row_idx, values, beta, mask, cap: int, *, sentinel: int):
    """Feature-axis gather of the screened working set into slab form.

    ``row_idx``/``values`` are feature-major — ``(p, K)`` (single ByFeature)
    or ``(p, DP, K)`` (mesh slabs); selection happens on axis 0 only, so the
    restricted problem stays in slab form end-to-end (no densification).
    Returns ``(row_idx_sub, values_sub, beta_sub, idx)`` with ``idx`` of
    shape ``(cap,)`` carrying sentinel ``p`` for padding; padded features are
    all-sentinel/zero slabs, so their coordinates provably stay at zero and
    the restricted solve equals the masked full solve. On a mesh this gather
    *is* the active-set reshard: the working set's slabs land back in a
    capacity-bucketed P(model) layout.
    """
    from repro.core.screening import pack_indices

    idx = pack_indices(mask, cap)
    row_idx_sub = jnp.take(row_idx, idx, axis=0, mode="fill",
                           fill_value=sentinel)
    values_sub = jnp.take(values, idx, axis=0, mode="fill", fill_value=0.0)
    beta_sub = jnp.take(beta, idx, mode="fill", fill_value=0.0)
    return row_idx_sub, values_sub, beta_sub, idx


def scatter_features(beta_sub, idx, p: int):
    """Inverse of :func:`gather_features`: restricted solution -> full beta.
    The coefficient scatter is layout-agnostic, so this is exactly the dense
    column scatter."""
    from repro.core.screening import scatter_columns

    return scatter_columns(beta_sub, idx, p)
