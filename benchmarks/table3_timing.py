"""Table 3: execution times — iterations to convergence, avg time/iteration,
line-search share; truncated-gradient avg time per pass for comparison
(one iteration of both = one full pass over the data, paper §4.4)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import TWINS, Timer, emit, load_twin
from repro.core import DGLMNETOptions, TGOptions, lambda_max
from repro.core.dglmnet import fit
from repro.core.linesearch import line_search
from repro.core.truncated_gradient import truncated_gradient_fit


def run():
    rows = []
    print("# dataset,iters,time_per_iter_us,linesearch_share,tg_time_per_pass_us")
    for name in TWINS:
        ds = load_twin(name)
        X, y = ds.X_train, ds.y_train
        lam = float(lambda_max(X, y)) / 64
        opts = DGLMNETOptions(num_blocks=16, tile=64, max_iters=40)

        # warmup (compile)
        fit(X, y, lam, opts=DGLMNETOptions(num_blocks=16, tile=64, max_iters=2))

        with Timer() as t_fit:
            res = fit(X, y, lam, opts=opts)
            t_fit.block = res.beta
        t_iter = t_fit.dt / max(res.n_iters, 1)

        # line-search share: time the jitted line search alone
        from repro.core.dglmnet import dglmnet_iteration
        from repro.core.objective import margins

        beta0 = res.beta * 0
        m0 = margins(X, beta0)
        dbeta, dm, gd = dglmnet_iteration(X, y, beta0, m0, lam, opts)
        jax.block_until_ready(dm)
        t0 = time.perf_counter()
        for _ in range(5):
            r = line_search(m0, dm, y, beta0, dbeta, lam, gd)
        jax.block_until_ready(r.alpha)
        t_ls = (time.perf_counter() - t0) / 5
        share = min(t_ls / max(t_iter, 1e-9), 1.0)

        truncated_gradient_fit(X, y, lam, opts=TGOptions(num_machines=16, passes=1))
        with Timer() as t_tg:
            t_tg.block = truncated_gradient_fit(
                X, y, lam, opts=TGOptions(num_machines=16, passes=4))
        t_pass = t_tg.dt / 4

        rows.append((name, res.n_iters, t_iter * 1e6, share, t_pass * 1e6))
        print(f"# {name},{res.n_iters},{t_iter*1e6:.0f},{share:.2%},{t_pass*1e6:.0f}")
        emit(f"table3.{name}.dglmnet_iter", t_iter * 1e6,
             f"iters={res.n_iters};ls_share={share:.3f}")
        emit(f"table3.{name}.tg_pass", t_pass * 1e6, "")
    return rows


if __name__ == "__main__":
    run()
