"""Parameter counting and model API dispatch (LM vs enc-dec).

Counts are derived from ``jax.eval_shape`` over the real initializers, so
they are exact for this implementation by construction. MoE active-param
counts weight expert stacks by top_k/num_experts (for 6·N_active·D model
FLOPs in the roofline).
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encdec.enabled


def init_params(key, cfg: ModelConfig):
    if is_encdec(cfg):
        from repro.models.seq2seq import init_seq2seq_params

        return init_seq2seq_params(key, cfg)
    from repro.models.transformer import init_lm_params

    return init_lm_params(key, cfg)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    if is_encdec(cfg):
        from repro.models.seq2seq import init_seq2seq_cache

        return init_seq2seq_cache(cfg, batch, cache_len, dtype)
    from repro.models.transformer import init_lm_cache

    return init_lm_cache(cfg, batch, cache_len, dtype)


def forward(params, inputs, cfg: ModelConfig, **kw):
    if is_encdec(cfg):
        from repro.models.seq2seq import seq2seq_forward

        kw.pop("long_mode", None)
        kw.pop("deterministic", None)
        return seq2seq_forward(params, inputs, cfg, **kw)
    from repro.models.transformer import lm_forward

    return lm_forward(params, inputs, cfg, **kw)


# ---------------------------------------------------------------------------
# counting
# ---------------------------------------------------------------------------

@lru_cache(maxsize=64)
def _shapes(cfg: ModelConfig):
    out = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    return out


def _leaf_sizes(tree) -> Dict[str, int]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    sizes = {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        sizes[name] = int(jnp.prod(jnp.array(leaf.shape))) if leaf.shape else 1
    return sizes


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    sizes = _leaf_sizes(_shapes(cfg))
    total = 0
    frac = (
        cfg.moe.top_k / cfg.moe.num_experts if (cfg.moe.enabled and active_only) else 1.0
    )
    for name, sz in sizes.items():
        is_expert = ("w_gate" in name or "w_up" in name or "w_down" in name) and (
            "moe" in name and "shared" not in name
        )
        total += int(sz * (frac if is_expert else 1.0))
    return total


def param_bytes(cfg: ModelConfig) -> int:
    itemsize = 2 if cfg.param_dtype == "bfloat16" else 4
    return count_params_analytic(cfg) * itemsize
