"""Nestable trace spans with a zero-cost disabled path.

Stdlib-only and JAX-free: a span records two `time.perf_counter` reads
and a dict append — it never touches device values, so enabling a trace
cannot add device->host transfers or XLA compiles. Call sites are placed
at *existing* sync points (the `engine.device_get` counted fetch,
`engine.fetch`, `np.asarray` on served scores); async dispatch between
sync points is attributed to the span that owns the next sync, which is
the honest accounting for an async runtime.

The span tree mirrors the solver and serve loops::

    path > lambda_grid
         > lambda_point > screen_round
                        > restricted_solve > bucket_stream
                        > kkt_check        > bucket_stream
                        > point_finish
    serve > drain
          > encode        (from submit; parents under serve when nested)
          > score
          > swap

Nesting is tracked per-thread: each thread keeps its own span stack, so
a serve thread and a solver thread never corrupt each other's parents.

With no active tracer, `span()` returns a shared `_NULL_SPAN` singleton
whose `__enter__`/`__exit__`/`set` are no-ops.
"""
from __future__ import annotations

import itertools
import threading
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

__all__ = ["Tracer", "event", "get_tracer", "span", "use_tracer"]


class _Span:
    """Context manager recording one timed span on `tracer`."""

    __slots__ = ("_tracer", "name", "args", "sid", "parent", "_t0", "_tid")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, object]):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.sid = next(tracer._sid)
        self.parent: Optional[int] = None
        self._t0 = 0.0
        self._tid = 0

    def set(self, **kw: object) -> "_Span":
        """Attach result metadata (nnz, status, ...) to the open span."""
        self.args.update(kw)
        return self

    def __enter__(self) -> "_Span":
        tracer = self._tracer
        stack = tracer._stack()
        self.parent = stack[-1].sid if stack else None
        self._tid = tracer._tid()
        stack.append(self)
        self._t0 = tracer.clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = self._tracer.clock()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self, self._t0, t1 - self._t0, self._tid)
        return False


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **kw: object) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class Tracer:
    """Collects span records; thread-safe, append-only.

    Records are plain dicts (`name`, `ts`, `dur`, `tid`, `sid`,
    `parent`, `args`) with `ts`/`dur` in seconds relative to the
    tracer's construction — `repro.obs.export` turns them into Chrome
    trace events / JSONL / summaries.
    """

    def __init__(self, clock=time.perf_counter) -> None:
        self.clock = clock
        self.t0 = clock()
        self.spans: List[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._sid = itertools.count(1)
        self._tids: Dict[int, int] = {}

    def _stack(self) -> List[_Span]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = self._tids[ident] = len(self._tids)
            return tid

    def _record(self, sp: _Span, t0: float, dur: float, tid: int) -> None:
        rec = {
            "name": sp.name,
            "ts": t0 - self.t0,
            "dur": dur,
            "tid": tid,
            "sid": sp.sid,
            "parent": sp.parent,
            "args": sp.args,
        }
        with self._lock:
            self.spans.append(rec)

    def span(self, name: str, **args: object) -> _Span:
        return _Span(self, name, args)

    def event(self, name: str, **args: object) -> None:
        """Record an instantaneous (zero-duration) marker."""
        stack = self._stack()
        rec = {
            "name": name,
            "ts": self.clock() - self.t0,
            "dur": 0.0,
            "tid": self._tid(),
            "sid": next(self._sid),
            "parent": stack[-1].sid if stack else None,
            "args": args,
        }
        with self._lock:
            self.spans.append(rec)

    def wall_s(self) -> float:
        """Wall time covered so far: last span end (or now if none)."""
        with self._lock:
            if not self.spans:
                return self.clock() - self.t0
            return max(r["ts"] + r["dur"] for r in self.spans)


_ACTIVE: Optional[Tracer] = None
_ACTIVE_LOCK = threading.Lock()


def get_tracer() -> Optional[Tracer]:
    return _ACTIVE


def span(name: str, **args: object):
    tracer = _ACTIVE
    return _NULL_SPAN if tracer is None else tracer.span(name, **args)


def event(name: str, **args: object) -> None:
    tracer = _ACTIVE
    if tracer is not None:
        tracer.event(name, **args)


@contextmanager
def use_tracer(tracer: Optional[Tracer]) -> Iterator[None]:
    """Activate `tracer` for the enclosed block (re-entrant: the prior
    active tracer is restored on exit). Pass None to force-disable."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, tracer
    try:
        yield
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev
