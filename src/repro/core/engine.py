"""Device-resident solver engine (the outer loop of paper Algorithm 1).

The seed drove d-GLMNET with a Python ``for`` loop that synced the
objective to host every iteration — one blocking device->host transfer
per outer iteration, plus per-call dispatch of the iteration and the line
search. This module replaces that with a single jitted
``jax.lax.while_loop`` program that carries ``(beta, m, f, it, converged)``
on device until termination:

* the convergence test ``(f_k - f_{k+1}) / max(|f_k|, eps) < rel_tol``
  runs on device;
* the objective/alpha histories live in fixed-size on-device buffers
  (``max_iters`` is static), so :class:`FitResult`-style reporting costs
  nothing during the loop;
* the paper's alpha->1 sparsity snap-back runs as a jitted epilogue on the
  stashed final step, exactly mirroring the seed semantics;
* the *only* device->host transfer per solve is one ``device_get`` of the
  final state, performed by the caller via :func:`fetch`.

Both the single-process (``core.dglmnet.fit``) and mesh
(``core.distributed.fit_distributed``) drivers are thin wrappers around
:func:`make_solver` — they differ only in the ``iteration_fn`` they plug
in, so the outer loop is one piece of code reviewed once.

``iteration_fn(data, y, beta, m, lam, w, z) -> (dbeta, dm, grad_dot)`` is
the pluggable subproblem: ``data`` is an arbitrary pytree (dense ``X``,
by-feature sparse slabs, sharded arrays — the engine never inspects it).
``(w, z)`` are the GLMNET working statistics at ``m``: the engine computes
them *once* per outer iteration through the fused ``kernels.logistic_stats``
pass (margins -> (w, z, nll) in one sweep over the examples axis — the
Pallas kernel on TPU, one XLA-fused sweep elsewhere) and hands the NLL to
the line search as its ``f_alpha(0)`` evaluation, so no subproblem or
line-search entry recomputes sigmoid/softplus over ``n``.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.linesearch import (
    MAX_BACKTRACKS,
    LineSearchResult,
    f_alpha,
    line_search,
)
from repro.core.objective import l1_norm, objective
from repro.kernels.ops import logistic_stats

# Indirection point so tests can count the per-solve host transfers.
device_get = jax.device_get

# Typed failure status carried on device (SolverState.status, int32).
# OK is 0 so a zeros-init carry starts healthy; the while-loop body
# writes exactly one non-OK code (then stops), so precedence only matters
# within a single tripped iteration: NONFINITE > STALLED > DIVERGED.
STATUS_OK = 0
STATUS_NONFINITE_OBJECTIVE = 1
STATUS_LINESEARCH_STALLED = 2
STATUS_DIVERGED = 3

STATUS_NAMES = {
    STATUS_OK: "OK",
    STATUS_NONFINITE_OBJECTIVE: "NONFINITE_OBJECTIVE",
    STATUS_LINESEARCH_STALLED: "LINESEARCH_STALLED",
    STATUS_DIVERGED: "DIVERGED",
}

# Objectives here are NLL + lam*||beta||_1 >= 0; a step whose objective
# exceeds this multiple of (f(beta0) + 1) is runaway, not line noise.
_DIVERGE_FACTOR = 1e4


def status_name(code: int) -> str:
    return STATUS_NAMES.get(int(code), f"UNKNOWN({int(code)})")


class SolverState(NamedTuple):
    """While-loop carry. Histories are fixed-size device buffers."""

    beta: jnp.ndarray            # (p,)
    m: jnp.ndarray               # (n,) margin cache X @ beta
    f: jnp.ndarray               # objective at (beta, m)
    it: jnp.ndarray              # int32, iterations executed
    done: jnp.ndarray            # bool
    converged: jnp.ndarray       # bool: rel decrease < tol (vs iter budget)
    # Final step stashed un-applied so the snap-back epilogue can choose
    # between alpha and 1 (seed semantics: snap-back happens pre-update).
    dbeta: jnp.ndarray
    dm: jnp.ndarray
    alpha: jnp.ndarray
    f_new: jnp.ndarray
    f_hist: jnp.ndarray          # (max_iters + 1,), f_hist[0] = f(beta0)
    a_hist: jnp.ndarray          # (max_iters,), line-search alphas (pre-snap)
    unit_steps: jnp.ndarray      # int32, Armijo unit-step short-circuits
    # int32 STATUS_* code; the default keeps pre-status constructors valid
    # (a plain int leaf — no device allocation at import time)
    status: jnp.ndarray = STATUS_OK


_POISON = {"nan": float("nan"), "inf": float("inf")}


def _advance(iteration_fn, data, y, beta, m, lam, *, fire=None, fault=None):
    """One outer step: fused working stats + subproblem + line search.
    Shared by the while-loop body and by :func:`make_step` (the
    single-iteration public API).

    ``fault`` (a ``repro.resilience.EngineFault``-shaped object, static)
    bakes a device-side poisoning into the program; ``fire`` is the traced
    bool selecting the iteration it triggers on. Both default to None —
    the healthy program is byte-identical to pre-fault builds.
    """
    if fault is not None and fault.kind == "margins":
        m = jnp.where(fire, jnp.full_like(m, _POISON[fault.mode]), m)
    w, z, nll0 = logistic_stats(m, y)
    f0 = nll0 + lam * l1_norm(beta)
    if fault is not None and fault.kind == "stats":
        bad = _POISON[fault.mode]
        w = jnp.where(fire, jnp.full_like(w, bad), w)
        z = jnp.where(fire, jnp.full_like(z, bad), z)
    dbeta, dm, grad_dot = iteration_fn(data, y, beta, m, lam, w, z)
    res = line_search(m, dm, y, beta, dbeta, lam, grad_dot, f0=f0)
    if fault is not None and fault.kind == "linesearch":
        # An exhausted, strictly-worse line search: +1.0 dominates any ulp
        # noise between f0 and the carry objective, so the stall guard's
        # strict comparison always sees it.
        res = LineSearchResult(
            alpha=jnp.where(fire, jnp.float32(0.0), res.alpha),
            f_new=jnp.where(fire, f0 + 1.0, res.f_new),
            took_unit_step=jnp.logical_and(jnp.logical_not(fire),
                                           res.took_unit_step),
            backtracks=jnp.where(fire, jnp.int32(MAX_BACKTRACKS),
                                 res.backtracks),
        )
    return dbeta, dm, res


def make_step(iteration_fn) -> Callable:
    """Jitted single outer iteration: ``step(data, y, beta, m, lam) ->
    (beta', m', f', alpha)`` — the building block external drivers (tests,
    ablations) use when they want manual control of the loop."""

    @jax.jit
    def step(data, y, beta, m, lam):
        dbeta, dm, res = _advance(iteration_fn, data, y, beta, m, lam)
        return beta + res.alpha * dbeta, m + res.alpha * dm, res.f_new, res.alpha

    return step


def make_solver(
    iteration_fn,
    *,
    max_iters: int,
    rel_tol: float,
    snap_tol: float,
    fault=None,
) -> Callable:
    """Builds ``solve(data, y, beta0, m0, lam) -> SolverState`` as one
    jitted program (outer loop = a single ``lax.while_loop``; ``lam`` is a
    traced operand so one compilation serves a whole regularization path).

    Numerical guardrails run on the carry every iteration (no host sync):
    a non-finite step objective, an exhausted line search that made the
    objective strictly worse, or a runaway objective trips the matching
    ``STATUS_*`` code, stops the loop, and freezes ``(beta, m, f, it)`` at
    the last good iterate — the tripped step is never applied and never
    enters the histories, so a consumer always gets the last finite beta.

    ``fault`` (static; shaped like ``repro.resilience.EngineFault``) bakes
    a deterministic device-side fault into this build — solver caches must
    not serve fault builds (see the drivers' ``_solver_for``).
    """
    if max_iters < 1:
        raise ValueError(f"max_iters must be >= 1, got {max_iters}")

    def cond(s: SolverState):
        return jnp.logical_not(s.done)

    def solve(data, y, beta0, m0, lam):
        f0 = objective(m0, y, beta0, lam)
        lam = jnp.asarray(lam, jnp.float32)

        def body(s: SolverState) -> SolverState:
            it = s.it + 1
            fire = (jnp.equal(it, jnp.int32(fault.at_iter))
                    if fault is not None else None)
            dbeta, dm, res = _advance(iteration_fn, data, y, s.beta, s.m,
                                      lam, fire=fire, fault=fault)
            # Guardrails on the proposed step, before anything is applied.
            nonfinite = jnp.logical_not(jnp.isfinite(res.f_new))
            stalled = jnp.logical_and(res.backtracks >= MAX_BACKTRACKS,
                                      res.f_new > s.f)
            diverged = res.f_new > _DIVERGE_FACTOR * (s.f_hist[0] + 1.0)
            status = jnp.where(
                nonfinite, STATUS_NONFINITE_OBJECTIVE,
                jnp.where(stalled, STATUS_LINESEARCH_STALLED,
                          jnp.where(diverged, STATUS_DIVERGED, STATUS_OK)),
            ).astype(jnp.int32)
            tripped = status != STATUS_OK

            rel_dec = (s.f - res.f_new) / jnp.maximum(jnp.abs(s.f), 1e-12)
            converged = jnp.logical_and(jnp.logical_not(tripped),
                                        rel_dec < rel_tol)
            done = jnp.logical_or(tripped,
                                  jnp.logical_or(converged, it >= max_iters))
            # Mid-loop iterations apply the step; the stop iteration
            # stashes it for the snap-back epilogue (which overwrites the
            # provisional f_hist entry written here). A tripped iteration
            # applies nothing, counts nothing, and writes nothing: the
            # history scatter index is pushed out of bounds (dropped), so
            # telemetry only ever holds certified iterations.
            keep = jnp.logical_not(done)
            idx_f = jnp.where(tripped, jnp.int32(max_iters + 1), it)
            idx_a = jnp.where(tripped, jnp.int32(max_iters), it - 1)
            return SolverState(
                beta=jnp.where(keep, s.beta + res.alpha * dbeta, s.beta),
                m=jnp.where(keep, s.m + res.alpha * dm, s.m),
                f=jnp.where(keep, res.f_new, s.f),
                it=jnp.where(tripped, s.it, it),
                done=done,
                converged=converged,
                dbeta=dbeta,
                dm=dm,
                alpha=res.alpha,
                f_new=res.f_new,
                f_hist=s.f_hist.at[idx_f].set(res.f_new),
                a_hist=s.a_hist.at[idx_a].set(res.alpha),
                unit_steps=s.unit_steps + jnp.logical_and(
                    res.took_unit_step, jnp.logical_not(tripped)
                ).astype(jnp.int32),
                status=status,
            )

        init = SolverState(
            beta=beta0,
            m=m0,
            f=f0,
            it=jnp.int32(0),
            done=jnp.bool_(False),
            converged=jnp.bool_(False),
            dbeta=jnp.zeros_like(beta0),
            dm=jnp.zeros_like(m0),
            alpha=jnp.float32(0.0),
            f_new=f0,
            f_hist=jnp.full((max_iters + 1,), jnp.nan, jnp.float32).at[0].set(f0),
            a_hist=jnp.full((max_iters,), jnp.nan, jnp.float32),
            unit_steps=jnp.int32(0),
            status=jnp.int32(STATUS_OK),
        )
        s = jax.lax.while_loop(cond, body, init)

        # Sparsity snap-back epilogue (paper §3.3 / seed `fit`): prefer
        # alpha = 1 on the final step if the objective increase is within
        # snap_tol — coordinates the CD solver drove exactly to zero stay
        # zero. Runs on device; the stashed step is applied here. The
        # histories must describe the *applied* step: a_hist's final entry
        # is overwritten with the snapped alpha, and a snap that promotes a
        # fractional alpha to 1 counts as a unit step (the body only
        # counted the line search's own short-circuits).
        #
        # On a tripped status the stashed step is the poisoned one: every
        # output selects the frozen carry via jnp.where (never a
        # multiply-by-zero — 0 * NaN is NaN) and the history overwrite is
        # dropped out of bounds, so the last certified entries survive.
        ok = jnp.equal(s.status, STATUS_OK)
        f_unit = f_alpha(1.0, s.m, s.dm, y, s.beta, s.dbeta, lam)
        snap = jnp.logical_and(ok, f_unit <= s.f_new * (1.0 + snap_tol) + 1e-12)
        alpha = jnp.where(snap, jnp.float32(1.0), s.alpha)
        f_fin = jnp.where(snap, f_unit, s.f_new)
        snapped_up = jnp.logical_and(snap, s.alpha != 1.0)
        idx_f = jnp.where(ok, s.it, jnp.int32(max_iters + 1))
        idx_a = jnp.where(ok, s.it - 1, jnp.int32(max_iters))
        return s._replace(
            beta=jnp.where(ok, s.beta + alpha * s.dbeta, s.beta),
            m=jnp.where(ok, s.m + alpha * s.dm, s.m),
            f=jnp.where(ok, f_fin, s.f),
            alpha=jnp.where(ok, alpha, jnp.float32(0.0)),
            f_hist=s.f_hist.at[idx_f].set(f_fin),
            a_hist=s.a_hist.at[idx_a].set(alpha),
            unit_steps=s.unit_steps + snapped_up.astype(jnp.int32),
        )

    return jax.jit(solve)


def fetch(state: SolverState):
    """The solve's single device->host transfer: one ``device_get`` of the
    whole final state. Returns (host_state, trimmed histories).

    Histories are validated against the device-side ``status``: an OK
    solve with a non-finite history row is a guardrail bug and raises;
    a tripped solve trims any non-finite tail (nothing past the last
    certified iterate is ever reported as a real iteration).
    """
    import math

    host = device_get(state)
    it = int(host.it)
    f_hist = [float(v) for v in host.f_hist[: it + 1]]
    a_hist = [float(v) for v in host.a_hist[:it]]
    status = int(host.status)
    if status == STATUS_OK:
        bad = [k for k, v in enumerate(f_hist) if not math.isfinite(v)]
        if bad:
            raise RuntimeError(
                f"engine invariant violated: status=OK but f_hist has "
                f"non-finite entries at iterations {bad} — the guardrails "
                f"should have tripped")
    else:
        while len(f_hist) > 1 and not math.isfinite(f_hist[-1]):
            f_hist.pop()
        a_hist = a_hist[: max(len(f_hist) - 1, 0)]
    return host, f_hist, a_hist
