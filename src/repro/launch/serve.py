"""Serving launcher: batched prefill + decode against the sharded cache.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
        --mesh 2x4 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import MODEL_CONFIGS
from repro.launch.train import parse_mesh
from repro.models import init_cache, init_params
from repro.sharding.ctx import mesh_context
from repro.sharding.rules import cache_pspecs, param_pspecs
from repro.train import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(MODEL_CONFIGS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="prod")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = MODEL_CONFIGS[args.arch]
    if args.smoke:
        cfg = cfg.smoke()
    mesh = parse_mesh(args.mesh)
    cache_len = args.prompt_len + args.tokens
    rng = np.random.default_rng(0)

    with mesh_context(mesh):
        params = init_params(jax.random.key(0), cfg)
        named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(
            params, named(param_pspecs(cfg, jax.eval_shape(lambda: params), mesh)))
        cache = init_cache(cfg, args.batch, cache_len)
        cache = jax.device_put(
            cache, named(cache_pspecs(cfg, jax.eval_shape(lambda: cache), mesh)))

        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32)
        batch = {"tokens": prompts}
        if cfg.encdec.enabled:
            batch["frame_embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, 16, cfg.frontend.embed_dim)),
                jnp.float32)

        prefill = jax.jit(make_prefill_step(cfg))
        serve = jax.jit(make_serve_step(cfg), donate_argnums=1)

        logits, pre_cache = prefill(params, batch)
        # splice prefill into the full cache
        def per_leaf(f, p):
            if f.shape == p.shape:
                return p.astype(f.dtype)
            axis = next(i for i, (a, b) in enumerate(zip(f.shape, p.shape)) if a != b)
            idx = [slice(None)] * f.ndim
            idx[axis] = slice(0, p.shape[axis])
            return f.at[tuple(idx)].set(p.astype(f.dtype))

        cache = jax.tree.map(per_leaf, cache, pre_cache)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]

        outs = [tok]
        t0 = time.time()
        for i in range(args.tokens - 1):
            idx = jnp.asarray(args.prompt_len + i, jnp.int32)
            _, nxt, cache = serve(params, cache, idx, tok)
            tok = nxt[:, None]
            outs.append(tok)
        dt = (time.time() - t0) / max(args.tokens - 1, 1)
        gen = jnp.concatenate(outs, axis=1)
        print(f"arch={cfg.name} mesh={dict(mesh.shape)} "
              f"generated {gen.shape} ({dt*1e3:.1f} ms/token)")
        print("sample:", np.asarray(gen[0][:12]))


if __name__ == "__main__":
    main()
