"""Table 2: dataset summary (synthetic twins; paper-scale dims are in
repro/configs/glm.py and exercised via the dry-run)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import TWINS, Timer, emit, load_twin
from repro.configs.glm import GLM_CONFIGS


def run():
    rows = []
    print("# dataset,examples(train/test),features,nnz,avg_nnz/example")
    for name in TWINS:
        with Timer() as t:
            ds = load_twin(name)
            X = np.asarray(ds.X_train)
            nnz = int((X != 0).sum()) + int((np.asarray(ds.X_test) != 0).sum())
            avg = nnz / (ds.X_train.shape[0] + ds.X_test.shape[0])
        rows.append((name, f"{ds.X_train.shape[0]}/{ds.X_test.shape[0]}",
                     X.shape[1], nnz, f"{avg:.1f}"))
        print("# " + ",".join(str(c) for c in rows[-1]))
        emit(f"table2.{name}.gen", t.dt * 1e6, f"nnz={nnz}")
    print("# paper-scale (dry-run) configs:")
    for c in GLM_CONFIGS.values():
        print(f"# {c.name}: n={c.num_examples} p={c.num_features} "
              f"avg_nnz={c.avg_nnz_per_example}")
    return rows


if __name__ == "__main__":
    run()
