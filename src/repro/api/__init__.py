"""repro.api — the one front door to every d-GLMNET solve.

``Design`` abstracts the data layout (dense / slab / bucketed / mesh-
sharded); ``LogisticL1`` is the estimator (fit / path / predict) whose
strategy resolver picks kernels, cycle mode, capacities and local-vs-mesh
execution in one place. The legacy entry points (``repro.core.fit``,
``fit_distributed``, ``fit_distributed_sparse``, ``regularization_path``,
``regularization_path_distributed``) are thin shims over this package.
"""
from repro.api.design import (  # noqa: F401
    BucketedSlabDesign,
    DenseDesign,
    Design,
    ShardedDesign,
    SlabDesign,
    as_design,
)
from repro.api.estimator import (  # noqa: F401
    LogisticL1,
    PathPoint,
    PathResult,
    lambda_max_design,
    make_design_eval,
)
from repro.api.strategy import Strategy, mesh_programs, resolve  # noqa: F401
