"""dead-code: inventory of modules unreachable from the GLM entry points.

The seed shipped an LM-model zoo (``models/``, LM ``configs/``,
``launch/train.py``, ...) that the d-GLMNET reproduction does not ride.
Rather than deleting it (the probe examples and model-zoo tests still
exercise it), this rule computes import-reachability from the GLM
surface and reports everything outside it — and the findings live in the
checked-in ``analysis-allowlist.toml``, each with a reason, so every
future PR sees the boundary explicitly instead of rediscovering it.

Roots are the *GLM* surface only: the public API (``repro.api``), the
solver core (``repro.core``, minus the LM activation probe that lives
there), serving (``repro.serve`` + ``launch.serve_glm``),
checkpointing, the analyzer itself, and the GLM drivers of record
(``benchmarks``, ``scripts.sanity_dglmnet``, ``scripts.hillclimb_glm``).
The LM launchers (``launch.train``/``serve``/``dryrun``) and
``scripts.sanity_models`` are deliberately NOT roots — they are the
bridges that keep the seed zoo importable, which is exactly the boundary
this rule exists to draw. Test modules are NOT roots either: "only a
test imports it" is a finding, not reachability.

Two edge subtleties:

* only *import-time* imports (module/class level) are edges.
  Function-local imports — including PEP 562 ``__getattr__`` lazy
  re-exports, see ``repro/train/__init__.py`` and
  ``repro/configs/__init__.py`` — are declared lazy boundaries: they say
  "this dependency is not part of my import-time surface", which is the
  surface this inventory draws;
* importing ``pkg.sub`` executes ``pkg/__init__`` first, so every
  submodule edge also adds its parent packages (matching Python).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "dead-code"
DOC = ("src modules unreachable from the GLM entry points — inventoried "
       "in analysis-allowlist.toml, not deleted")

ROOTS = (
    "repro.api",
    "repro.core",
    "repro.serve",
    "repro.launch.serve_glm",
    "repro.launch.chaos_glm",
    "repro.checkpoint",
    "repro.compat",
    "repro.analysis",
    "benchmarks",
    "scripts.sanity_dglmnet",
    "scripts.hillclimb_glm",
)

#: exact modules excluded from root prefixes — scaffolding that happens
#: to live inside a root package
NONROOTS = frozenset({"repro.core.probe"})


def _module_name(path: str) -> str:
    """posix repo-relative path -> dotted module name."""
    p = path
    if p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith("/__init__.py"):
        p = p[: -len("/__init__.py")]
    elif p.endswith(".py"):
        p = p[:-3]
    return p.replace("/", ".")


def _import_time_nodes(tree: ast.Module) -> Iterable[ast.AST]:
    """Nodes executed when the module is imported: module and class
    bodies, but NOT function bodies — a function-local import (including
    a PEP 562 ``__getattr__``) is a declared lazy boundary, not part of
    the import-time surface this rule draws."""
    stack: List[ast.AST] = [tree]
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _edges(mod: ModuleInfo, known: Set[str]) -> Set[str]:
    """Outgoing import-time edges, restricted to in-project module names.
    ``from pkg import name`` adds both ``pkg`` and ``pkg.name`` when the
    latter is itself a module."""
    name = _module_name(mod.path)
    pkg_parts = name.split(".")
    out: Set[str] = set()

    def add(target: str) -> None:
        while target:
            if target in known:
                out.add(target)
                # a package import pulls in its __init__, which is the
                # package node itself; submodule edges come from the
                # __init__'s own imports
                return
            if "." not in target:
                return
            target = target.rsplit(".", 1)[0]

    for node in _import_time_nodes(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base = pkg_parts[: len(pkg_parts) - node.level + (
                    1 if mod.path.endswith("__init__.py") else 0)]
                prefix = ".".join(base)
                module = (f"{prefix}.{node.module}" if node.module
                          else prefix)
            else:
                module = node.module or ""
            if module:
                add(module)
                for a in node.names:
                    if f"{module}.{a.name}" in known:
                        out.add(f"{module}.{a.name}")
    return out


def check(project: Project) -> Iterable[Finding]:
    names: Dict[str, ModuleInfo] = {
        _module_name(m.path): m for m in project.modules
    }
    known = set(names)
    graph = {n: _edges(m, known) for n, m in names.items()}
    # package nodes implicitly import nothing extra, but importing any
    # repro.x.y reaches repro.x (__init__ runs); add parent edges
    for n in list(graph):
        if "." in n:
            graph[n].add(n.rsplit(".", 1)[0])

    reached: Set[str] = set()
    stack = [n for n in known
             if n not in NONROOTS
             and any(n == r or n.startswith(r + ".") for r in ROOTS)]
    while stack:
        n = stack.pop()
        if n in reached:
            continue
        reached.add(n)
        stack.extend(graph.get(n, ()))

    out: List[Finding] = []
    for n in sorted(known - reached):
        mod = names[n]
        if not mod.path.startswith("src/"):
            continue          # only src modules are inventory candidates
        out.append(Finding(
            file=mod.path, line=1, rule=RULE_ID,
            message=(
                f"module {n} is unreachable from the GLM entry points "
                f"({', '.join(ROOTS[:5])}, ...) — seed scaffolding? "
                f"inventory it in analysis-allowlist.toml with a reason, "
                f"or delete it"),
        ))
    return out
