"""Bounded exponential-backoff retry for the serve loop's fragile edges.

``PathStore.swap`` and checkpoint loads are the two places the serving
stack crosses a boundary that can fail transiently (device OOM during a
build-then-publish, a checkpoint directory mid-rotation). Wrapping them
in :func:`retry_call` keeps the failure typed and bounded instead of
letting one transient kill the serve loop.

Stdlib only (the ``repro.obs`` registry it reports retries to is itself
stdlib-only); the sleep is injectable so tests run at full speed. When a
metrics registry is active, each retried failure bumps the process-wide
``retry.retries`` counter and each give-up bumps ``retry.exhausted`` —
``on_retry`` remains the per-call-site hook for legacy counters.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Tuple, Type, TypeVar

from repro.obs import registry as _metrics

T = TypeVar("T")


class RetriesExhausted(RuntimeError):
    """All attempts failed; ``__cause__`` is the last underlying error."""

    def __init__(self, attempts: int, last: BaseException):
        super().__init__(
            f"gave up after {attempts} attempts: "
            f"{type(last).__name__}: {last}")
        self.attempts = attempts
        self.last = last


def retry_call(
    fn: Callable[[], T],
    *,
    attempts: int = 3,
    base_delay_s: float = 0.05,
    max_delay_s: float = 1.0,
    retry_on: Tuple[Type[BaseException], ...] = (RuntimeError, OSError),
    sleep: Optional[Callable[[float], None]] = None,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
) -> T:
    """Call ``fn()`` with up to ``attempts`` tries and exponential backoff.

    Delays run ``base_delay_s * 2**k`` capped at ``max_delay_s``. Only
    exceptions in ``retry_on`` are retried; anything else propagates
    immediately (a typed rejection like ``Overloaded`` must not be
    retried into a success). ``on_retry(attempt_index, error)`` fires
    before each backoff sleep so callers can count retries in telemetry.
    Raises :class:`RetriesExhausted` (chaining the last error) when every
    attempt fails.
    """
    if attempts < 1:
        raise ValueError(f"attempts must be >= 1, got {attempts}")
    do_sleep = time.sleep if sleep is None else sleep
    last: Optional[BaseException] = None
    for k in range(attempts):
        try:
            return fn()
        except retry_on as err:
            last = err
            if k + 1 >= attempts:
                break
            if on_retry is not None:
                on_retry(k, err)
            _metrics.counter("retry.retries").inc()
            do_sleep(min(base_delay_s * (2.0 ** k), max_delay_s))
    assert last is not None
    _metrics.counter("retry.exhausted").inc()
    raise RetriesExhausted(attempts, last) from last
