"""Quickstart: L1-regularized logistic regression with d-GLMNET.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import GLMConfig
from repro.core import DGLMNETOptions, fit, lambda_max, regularization_path
from repro.data.synthetic import make_glm_dataset
from repro.train.metrics import glm_eval_fn


def main():
    cfg = GLMConfig(name="quickstart", num_examples=8192, num_features=256,
                    density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    X, y = ds.X_train, ds.y_train
    lmax = float(lambda_max(X, y))
    print(f"n={X.shape[0]}  p={X.shape[1]}  lambda_max={lmax:.2f}")

    # single solve, simulating 8 machines (feature blocks)
    res = fit(X, y, lmax / 64,
              opts=DGLMNETOptions(num_blocks=8, method="gram", tile=32),
              verbose=True)
    print(f"\nfit: f={res.f:.4f}  nnz={res.nnz}/{X.shape[1]}  "
          f"iters={res.n_iters}  unit-step={res.unit_step_frac:.0%}")

    # regularization path (paper Algorithm 5) with test metrics
    print("\nregularization path:")
    pts = regularization_path(
        X, y, path_len=8, opts=DGLMNETOptions(num_blocks=8, tile=32),
        eval_fn=glm_eval_fn(ds.X_test, ds.y_test), verbose=True)
    best = max(pts, key=lambda p: p.metrics["auprc"])
    print(f"\nbest: lambda={best.lam:.3f} nnz={best.nnz} "
          f"AUPRC={best.metrics['auprc']:.4f}")


if __name__ == "__main__":
    main()
