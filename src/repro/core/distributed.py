"""Distributed d-GLMNET on a JAX mesh (paper Algorithm 4 -> shard_map).

Mapping (DESIGN.md §2.2):
  * feature blocks S_m  <->  `model` mesh axis (paper-faithful dimension)
  * example shards      <->  `data` (+ `pod`) mesh axes (beyond-paper 2-D)

Layout: X P(data, model); y, m P(data); beta P(model).

The quadratic subproblem needs *sequential* CD semantics, so it runs inside
``shard_map``: per feature tile, the Gram block and correlation vector are
``psum``-ed over `data` (exact row-global statistics), the tile's CD cycle
runs replicated on every data shard, and the local residual advances with a
dense matmul. ``dm = X @ dbeta`` is ``psum``-ed over `model` inside the map —
this is the paper's MPI_AllReduce of (dbeta, dbeta^T x_i), with the same
O(n + p) payload per device.

The outer loop is the shared device-resident engine (core/engine.py): the
shard_map subproblem is plugged into the same jitted while_loop program the
single-process ``fit`` uses, so ``fit_distributed`` performs no per-iteration
host synchronization either — sharded state stays on the mesh until the one
``device_get`` at the end of the solve.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pcast_varying, shard_map
from repro.core import engine
from repro.core.dglmnet import DGLMNETOptions
from repro.core.subproblem import make_tile_solver


def _data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def local_subproblem(X_loc, w_loc, r, beta_loc, lam, *, tile: int, nu: float,
                     data_axes: Tuple[str, ...], use_kernel: bool = False,
                     cycle_mode: str = "sequential", block: int = 16):
    """Per-(data, model)-shard subproblem body. Runs under shard_map.

    X_loc: (n_loc, p_loc); w_loc/r: (n_loc,); beta_loc: (p_loc,).
    Returns (dbeta_loc, r_final). ``cycle_mode``/``block`` pick the
    within-tile CD cycle (sequential chain vs the blocked semi-parallel
    cycle) via the shared ``make_tile_solver`` resolution.
    """
    n_loc, p_loc = X_loc.shape
    assert p_loc % tile == 0, (p_loc, tile)
    nt = p_loc // tile
    if not use_kernel:
        # r becomes varying over the model axis once tile updates land; mark
        # it so the scan carry type is stable (shard_map vma tracking). The
        # Pallas-kernel path runs with check_vma=False (interpret-mode scan
        # internals mix varying axes), where pcast is unavailable.
        r = pcast_varying(r, "model")
    tile_solver = make_tile_solver(cycle_mode=cycle_mode, tile=tile,
                                   block=block, use_kernel=use_kernel)

    def tile_step(carry, idx):
        r, dbeta = carry
        Xf = jax.lax.dynamic_slice(X_loc, (0, idx * tile), (n_loc, tile))
        wXf = w_loc[:, None] * Xf
        G = Xf.T @ wXf                                   # (F, F) local rows
        c = wXf.T @ r                                    # (F,)  local rows
        for ax in data_axes:                             # exact row-global stats
            G = jax.lax.psum(G, ax)
            c = jax.lax.psum(c, ax)
        b_f = jax.lax.dynamic_slice(beta_loc, (idx * tile,), (tile,))
        db_f = jax.lax.dynamic_slice(dbeta, (idx * tile,), (tile,))
        d = tile_solver(G, c, b_f, db_f, lam, nu)
        r = r - Xf @ d                                   # local-row residual
        dbeta = jax.lax.dynamic_update_slice(dbeta, db_f + d, (idx * tile,))
        return (r, dbeta), None

    from repro.sharding.ctx import unroll_enabled

    if unroll_enabled():
        # dry-run cost pass: make every tile visible to HloCostAnalysis
        carry = (r, jnp.zeros_like(beta_loc))
        for i in range(nt):
            carry, _ = tile_step(carry, jnp.int32(i))
        r, dbeta = carry
    else:
        (r, dbeta), _ = jax.lax.scan(
            tile_step, (r, jnp.zeros_like(beta_loc)), jnp.arange(nt)
        )
    return dbeta, r


def local_subproblem_sparse(row_idx, values, w_loc, r, beta_loc, lam, *,
                            tile: int, nu: float, data_axes: Tuple[str, ...],
                            cycle_mode: str = "sequential", block: int = 16):
    """Sparse by-feature variant (paper Table 1 layout at webspam scale).

    row_idx/values: (p_loc, K) — per local feature, its local-example rows
    (sentinel n_loc) and values. Each feature tile's weighted Gram block
    and correlation come straight from the slab via the sparse-native
    kernel layer (``kernels.slab_gram``: a match-and-accumulate join over
    nnz slots) and the residual advances with the O(nnz) slab SpMV — no
    ``(n_loc, tile)`` densify scatter anywhere. Sentinel slots contribute
    exactly zero for every slab capacity, including all-padding
    (empty-feature) slabs. Callers in the dense-density regime should
    densify once per solve instead (``fit_distributed_sparse`` does, per
    ``kernels.prefer_slab_gram``) — this body is the K << n_loc path.
    """
    from repro.kernels import ops as kops

    n_loc = r.shape[0]
    p_loc, k = row_idx.shape
    assert p_loc % tile == 0, (p_loc, tile)
    nt = p_loc // tile
    r = pcast_varying(r, "model")
    tile_solver = make_tile_solver(cycle_mode=cycle_mode, tile=tile,
                                   block=block)

    def tile_step(carry, idx):
        r, dbeta = carry
        rows = jax.lax.dynamic_slice(row_idx, (idx * tile, 0), (tile, k))
        vals = jax.lax.dynamic_slice(values, (idx * tile, 0), (tile, k))
        G, c = kops.slab_gram(rows, vals, w_loc, r)
        for ax in data_axes:
            G = jax.lax.psum(G, ax)
            c = jax.lax.psum(c, ax)
        b_f = jax.lax.dynamic_slice(beta_loc, (idx * tile,), (tile,))
        db_f = jax.lax.dynamic_slice(dbeta, (idx * tile,), (tile,))
        d = tile_solver(G, c, b_f, db_f, lam, nu)
        r = r - kops.slab_spmv(rows, vals, d, n_loc=n_loc)
        dbeta = jax.lax.dynamic_update_slice(dbeta, db_f + d, (idx * tile,))
        return (r, dbeta), None

    (r, dbeta), _ = jax.lax.scan(
        tile_step, (r, jnp.zeros_like(beta_loc)), jnp.arange(nt)
    )
    return dbeta, r


def _data_extent(mesh: Mesh) -> int:
    ddim = 1
    for ax in _data_axes(mesh):
        ddim *= mesh.shape[ax]
    return ddim


def check_slab_shapes(row_idx, values, mesh: Mesh, n: int) -> int:
    """Validate (p, DP, K) by-feature slabs against the mesh and example
    count. Returns n_loc (= local examples per data shard)."""
    if row_idx.shape != values.shape or row_idx.ndim != 3:
        raise ValueError(
            f"slab shapes must match and be (p, DP, K); got row_idx "
            f"{row_idx.shape} vs values {values.shape}"
        )
    ddim = _data_extent(mesh)
    if row_idx.shape[1] != ddim:
        raise ValueError(
            f"slab data dimension {row_idx.shape[1]} must equal the mesh "
            f"data extent {ddim}"
        )
    if n % ddim:
        raise ValueError(
            f"data extent {ddim} must divide n={n} (trim or pad upstream)"
        )
    n_loc = n // ddim
    # local row indices beyond the sentinel would be silently dropped by
    # the scatter-adds downstream — catch a slab/y example-count mismatch
    # here instead of converging to a wrong solution
    max_row = int(row_idx.max()) if row_idx.size else 0
    if max_row > n_loc:
        raise ValueError(
            f"slab row index {max_row} exceeds the local example count "
            f"{n_loc} implied by n={n} on data extent {ddim} — were the "
            f"slabs built for a different n?"
        )
    return n_loc


def make_distributed_iteration_sparse(mesh: Mesh, opts: DGLMNETOptions, *,
                                      model_axis: str = "model"):
    """The by-feature sparse mesh subproblem in the engine's
    ``iteration_fn`` signature, with ``data = (row_idx, values)``.

    row_idx/values are (p, DP, K): feature-major, one slab per data shard
    (local example indices, sentinel = n_loc); sharded P(model, data, -).
    This is what makes webspam (p = 16.6M, dense X = 10.5 TB) fit the mesh.
    """
    daxes = _data_axes(mesh)
    dspec = P(daxes) if daxes else P()

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(model_axis, daxes, None), P(model_axis, daxes, None),
                  P(model_axis), dspec, dspec, P()),
        out_specs=(P(model_axis), dspec),
    )
    def subproblem_sharded(row_idx, values, beta, w, z, lam):
        dbeta, r = local_subproblem_sparse(
            row_idx[:, 0, :], values[:, 0, :], w, z, beta, lam[0],
            tile=opts.tile, nu=opts.nu, data_axes=daxes,
            cycle_mode=opts.cycle_mode, block=opts.block,
        )
        dm = jax.lax.psum(z - r, model_axis)
        return dbeta, dm

    def iteration(data, y, beta, m, lam, w, z):
        row_idx, values = data
        lam_arr = jnp.asarray(lam, jnp.float32)[None]
        dbeta, dm = subproblem_sharded(row_idx, values, beta, w, z, lam_arr)
        grad_dot = jnp.dot(jax.nn.sigmoid(m) - (y + 1.0) * 0.5, dm)
        return dbeta, dm, grad_dot

    return iteration


def make_dglmnet_step_sparse(mesh: Mesh, opts: DGLMNETOptions, *,
                             model_axis: str = "model"):
    """Jitted distributed d-GLMNET outer iteration over by-feature slabs:
    ``step(row_idx, values, y, beta, m, lam) -> (beta', m', f', alpha)``."""
    step_core = engine.make_step(
        make_distributed_iteration_sparse(mesh, opts, model_axis=model_axis)
    )

    @jax.jit
    def step(row_idx, values, y, beta, m, lam):
        return step_core((row_idx, values), y, beta, m, lam)

    return step


@lru_cache(maxsize=64)
def make_slab_margins(mesh: Mesh, n_loc: int, model_axis: str = "model"):
    """Sharded sparse matvec ``margins(row_idx, values, beta) -> m`` over
    (p, DP, K) slabs: each (model, data) shard runs the slab SpMV kernel
    over its features (``kernels.slab_spmv`` — O(nnz), sentinel slots
    exact zero), then a psum over ``model`` assembles X @ beta exactly —
    no dense X, no densify."""
    from repro.kernels import ops as kops

    daxes = _data_axes(mesh)
    dspec = P(daxes) if daxes else P()

    @jax.jit
    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(model_axis, daxes, None), P(model_axis, daxes, None),
                  P(model_axis)),
        out_specs=dspec,
    )
    def slab_margins(row_idx, values, beta):
        rows, vals = row_idx[:, 0, :], values[:, 0, :]
        m_loc = kops.slab_spmv(rows, vals, beta, n_loc=n_loc)
        return jax.lax.psum(m_loc, model_axis)

    return slab_margins


@lru_cache(maxsize=64)
def make_slab_densifier(mesh: Mesh, n_loc: int, model_axis: str = "model"):
    """One-shot on-mesh densify ``(row_idx, values) -> X`` (P(data, model))
    — the dense-Gram fallback setup for slabs above the sparse-win density
    (``kernels.prefer_slab_gram``). The scatter runs once per solve at
    O(nnz) and the solve then rides the dense MXU subproblem, instead of
    paying a per-tile densify on every outer iteration; a dense (n, p_sub)
    block only ever exists sharded on the mesh, never on host."""
    daxes = _data_axes(mesh)

    @jax.jit
    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(model_axis, daxes, None), P(model_axis, daxes, None)),
        out_specs=P(daxes, model_axis),
    )
    def densify(row_idx, values):
        rows, vals = row_idx[:, 0, :], values[:, 0, :]
        p_loc = rows.shape[0]
        va = jnp.where(rows < n_loc, vals, 0.0).astype(jnp.float32)
        out = jnp.zeros((p_loc, n_loc + 1), jnp.float32)
        out = out.at[jnp.arange(p_loc)[:, None],
                     jnp.minimum(rows, n_loc)].add(va)
        return out[:, :n_loc].T

    return densify


def make_distributed_iteration(mesh: Mesh, opts: DGLMNETOptions, *,
                               model_axis: str = "model"):
    """The mesh subproblem in the engine's ``iteration_fn`` signature:
    ``iteration(X, y, beta, m, lam) -> (dbeta, dm, grad_dot)``."""
    daxes = _data_axes(mesh)
    dspec = P(daxes) if daxes else P()

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(daxes, model_axis), P(model_axis), dspec, dspec, P()),
        out_specs=(P(model_axis), dspec),
        check_vma=not opts.use_kernel,
    )
    def subproblem_sharded(X, beta, w, z, lam):
        dbeta, r = local_subproblem(
            X, w, z, beta, lam[0], tile=opts.tile, nu=opts.nu,
            data_axes=daxes, use_kernel=opts.use_kernel,
            cycle_mode=opts.cycle_mode, block=opts.block,
        )
        # paper Alg. 4 step 3: AllReduce of per-block margin deltas over blocks
        dm = z - r                                       # X_loc @ dbeta_loc
        dm = jax.lax.psum(dm, model_axis)
        return dbeta, dm

    def iteration(X, y, beta, m, lam, w, z):
        lam_arr = jnp.asarray(lam, jnp.float32)[None]
        dbeta, dm = subproblem_sharded(X, beta, w, z, lam_arr)
        # grad(L)^T dbeta from margins (global sharded arrays; XLA reduces)
        grad_dot = jnp.dot(jax.nn.sigmoid(m) - (y + 1.0) * 0.5, dm)
        return dbeta, dm, grad_dot

    return iteration


def make_dglmnet_step(mesh: Mesh, opts: DGLMNETOptions, *, model_axis: str = "model"):
    """Builds a jitted distributed d-GLMNET outer iteration.

    step(X, y, beta, m, lam) -> (beta', m', f', alpha)
    """
    return engine.make_step(
        make_distributed_iteration(mesh, opts, model_axis=model_axis)
    )


def _build_solver(mesh: Mesh, opts: DGLMNETOptions, model_axis: str,
                  *, sparse: bool, fault=None):
    make_iter = (make_distributed_iteration_sparse if sparse
                 else make_distributed_iteration)
    return engine.make_solver(
        make_iter(mesh, opts, model_axis=model_axis),
        max_iters=opts.max_iters,
        rel_tol=opts.rel_tol,
        snap_tol=opts.snap_tol,
        fault=fault,
    )


@lru_cache(maxsize=64)
def _cached_solver(mesh: Mesh, opts: DGLMNETOptions, model_axis: str,
                   sparse: bool):
    return _build_solver(mesh, opts, model_axis, sparse=sparse)


def _solver_for(mesh: Mesh, opts: DGLMNETOptions, model_axis: str):
    """Cached mesh solver; an armed ``repro.resilience`` engine fault gets
    an uncached poisoned build instead (fault programs never enter — or
    evict from — the healthy cache)."""
    from repro.resilience import arm_engine_fault

    fault = arm_engine_fault()
    if fault is not None:
        return _build_solver(mesh, opts, model_axis, sparse=False,
                             fault=fault)
    return _cached_solver(mesh, opts, model_axis, False)


def _solver_sparse_for(mesh: Mesh, opts: DGLMNETOptions, model_axis: str):
    """Sparse-slab twin of :func:`_solver_for` (same fault-bypass rule)."""
    from repro.resilience import arm_engine_fault

    fault = arm_engine_fault()
    if fault is not None:
        return _build_solver(mesh, opts, model_axis, sparse=True,
                             fault=fault)
    return _cached_solver(mesh, opts, model_axis, True)


@dataclass
class DistributedFitResult:
    """Mirror of ``FitResult`` for mesh solves — same epilogue telemetry
    (the engine state carries it on device either way), plus the final
    sharded margin cache ``m`` (P(data)), which the distributed path driver
    reuses for its KKT pass instead of re-deriving X @ beta."""
    beta: jnp.ndarray
    f: float
    n_iters: int
    objective_history: list
    alpha_history: list = field(default_factory=list)
    unit_step_frac: float = 0.0
    converged: bool = False
    m: Optional[jnp.ndarray] = None
    # engine.STATUS_* code; non-OK means the solve tripped a guardrail and
    # beta/f are the last certified iterate, not the final proposed step
    status: int = 0

    @property
    def nnz(self) -> int:
        return int(jnp.sum(jnp.abs(self.beta) > 0))

    @property
    def status_name(self) -> str:
        return engine.status_name(self.status)

    @property
    def ok(self) -> bool:
        return self.status == engine.STATUS_OK


def fit_distributed(
    X,
    y,
    lam: float,
    mesh: Mesh,
    *,
    beta0: Optional[jnp.ndarray] = None,
    opts: DGLMNETOptions = DGLMNETOptions(),
    verbose: bool = False,
) -> DistributedFitResult:
    """Device-resident outer loop over the sharded subproblem (CPU-testable
    with fake devices; same code lowers on the production mesh). The whole
    solve is one jitted while_loop on the mesh — identical driver code to
    the single-process ``fit`` (core/engine.py).

    Legacy shim: delegates to the ``repro.api`` front door
    (``LogisticL1`` over ``ShardedDesign(DenseDesign(X), mesh)``), which
    owns the solve body; results are bit-identical to the pre-API driver."""
    from repro.api import DenseDesign, LogisticL1, ShardedDesign

    design = ShardedDesign(DenseDesign(X), mesh, tile=opts.tile)
    return LogisticL1(opts=opts).fit(design, y, lam, beta0=beta0,
                                     verbose=verbose)


def _finish(state, p: int, pad: int, verbose: bool,
            tag: str) -> DistributedFitResult:
    """Shared solve epilogue: the one d2h transfer + result assembly."""
    host, hist, alphas = engine.fetch(state)
    it = int(host.it)
    if verbose:
        for k in range(1, it + 1):
            print(f"  [{tag}] iter {k} f={hist[k]:.6f}")
    beta_out = state.beta[:p] if pad else state.beta
    return DistributedFitResult(
        beta=beta_out, f=hist[-1], n_iters=it, objective_history=hist,
        alpha_history=alphas,
        unit_step_frac=int(host.unit_steps) / max(it, 1),
        converged=bool(host.converged),
        m=state.m,
        status=int(host.status),
    )


def fit_distributed_sparse(
    row_idx,
    values,
    y,
    lam: float,
    mesh: Mesh,
    *,
    beta0: Optional[jnp.ndarray] = None,
    opts: DGLMNETOptions = DGLMNETOptions(),
    verbose: bool = False,
    densify: Optional[bool] = None,
) -> DistributedFitResult:
    """``fit_distributed`` over by-feature sparse slabs (p, DP, K) — the
    webspam-scale layout where a dense X can never exist on any machine.
    Same device-resident engine loop. The subproblem implementation is
    picked by the nnz-density heuristic (``kernels.prefer_slab_gram``,
    overridable via ``densify``):

    * sparse-native (K << n_loc): every Gram tile and residual update
      comes straight from the slabs via the ``kernels.slab_gram`` /
      ``slab_spmv`` suite — no densify anywhere, O(nnz)-dominated work;
    * dense fallback (denser slabs): one O(nnz) on-mesh densify *per
      solve* builds the sharded (n, p) block and the solve rides the
      dense MXU subproblem — instead of the old per-tile, per-iteration
      densify scatter that dominated the hot loop.

    Legacy shim: delegates to the ``repro.api`` front door
    (``LogisticL1`` over ``ShardedDesign(SlabDesign(...), mesh)``), which
    owns the solve body; results are bit-identical to the pre-API driver.
    """
    from repro.api import LogisticL1, ShardedDesign, SlabDesign

    design = ShardedDesign(
        SlabDesign(row_idx, values, int(y.shape[0])), mesh, tile=opts.tile)
    return LogisticL1(opts=opts).fit(design, y, lam, beta0=beta0,
                                     verbose=verbose, densify=densify)
