"""Mamba2 / SSD (state-space duality) block, arXiv:2405.21060.

TPU adaptation: the SSD *chunked* formulation — intra-chunk work is dense
masked matmuls (MXU-friendly), inter-chunk state passing is a short
``lax.scan`` over S/chunk steps. Decode is an O(1) state update, which is
what makes the long_500k shape native for SSM/hybrid archs.

Layout: x (B, S, H, P) heads x head_dim; state (B, H, P, N).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, gated_rmsnorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: SSMConfig, d_model: int, dtype):
    d_inner = cfg.d_inner(d_model)
    nheads = cfg.num_heads(d_model)
    g, n = cfg.ngroups, cfg.d_state
    conv_dim = d_inner + 2 * g * n
    # in_proj -> [z (d_inner), x (d_inner), B (g*n), C (g*n), dt (nheads)]
    d_in_proj = 2 * d_inner + 2 * g * n + nheads
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d_model, d_in_proj, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.conv_width, conv_dim), jnp.float32)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nheads,), 0.01, jnp.float32))),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype),
    }


def init_ssm_cache(cfg: SSMConfig, d_model: int, batch: int, dtype):
    d_inner = cfg.d_inner(d_model)
    nheads = cfg.num_heads(d_model)
    g, n = cfg.ngroups, cfg.d_state
    conv_dim = d_inner + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, nheads, cfg.head_dim, n), jnp.float32),
    }


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------

def _split_proj(zxbcdt, cfg: SSMConfig, d_model: int):
    d_inner = cfg.d_inner(d_model)
    g, n = cfg.ngroups, cfg.d_state
    nheads = cfg.num_heads(d_model)
    z, x, bc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + 2 * g * n], axis=-1
    )
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)
    return z, x, b_mat, c_mat, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv over axis 1. xbc: (B,S,Cd); conv_w: (W,Cd)."""
    w = conv_w.shape[0]
    if conv_state is not None:
        xbc_pad = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
    else:
        xbc_pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    s = xbc.shape[1]
    for i in range(w):  # width is 4: unrolled shifts, depthwise
        out = out + xbc_pad[:, i : i + s, :].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    out = out + conv_b.astype(jnp.float32)
    new_state = xbc_pad[:, xbc_pad.shape[1] - (w - 1) :, :]
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def ssd_chunked(x, dt, A, b_mat, c_mat, *, chunk: int, init_state=None):
    """SSD chunked scan.

    x: (B,S,H,P) f32; dt: (B,S,H) f32 (already softplus'ed);
    A: (H,) f32 negative; b_mat/c_mat: (B,S,G,N) f32.
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, g, n)
    cc = c_mat.reshape(bsz, nc, chunk, g, n)

    da = dtc * A  # (B,nc,L,H): log-decay per step
    cum = jnp.cumsum(da, axis=2)                       # (B,nc,L,H)
    # intra-chunk attention-like term: M[i,j] = exp(cum_i - cum_j) * dt_j, i>=j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,L,L,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)

    # weighted input: u = dt * x  (B,nc,L,H,P)
    u = xc * dtc[..., None]
    # scores: S[i,j] = (C_i . B_j) within chunk, grouped heads
    cb = jnp.einsum("bnigz,bnjgz->bnijg", cc, bc)       # (B,nc,L,L,G)
    cb = jnp.repeat(cb, rep, axis=-1)                   # (B,nc,L,L,H)
    y_intra = jnp.einsum("bnijh,bnijh,bnjhp->bnihp", cb, lmat, u)

    # chunk-final states: state_c = sum_j exp(cum_L - cum_j) * B_j u_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # (B,nc,L,H)
    b_heads = jnp.repeat(bc, rep, axis=3)               # (B,nc,L,H,N) grouped->per-head
    state_chunks = jnp.einsum("bnlh,bnlhz,bnlhp->bnhpz", decay_to_end, b_heads, u)

    chunk_decay = jnp.exp(jnp.sum(da, axis=2))          # (B,nc,H) total decay per chunk

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(carry, inp):
        st = carry                                      # (B,H,P,N)
        s_chunk, dec = inp                              # (B,H,P,N), (B,H)
        out_prev = st                                   # state entering this chunk
        new = st * dec[..., None, None] + s_chunk
        return new, out_prev

    # scan over chunks
    states_seq = jnp.moveaxis(state_chunks, 1, 0)       # (nc,B,H,P,N)
    decay_seq = jnp.moveaxis(chunk_decay, 1, 0)         # (nc,B,H)
    final_state, prev_states = jax.lax.scan(step, init_state, (states_seq, decay_seq))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # inter-chunk contribution: y_j += C_j . (decay_from_start_j * prev_state)
    decay_from_start = jnp.exp(cum)                     # (B,nc,L,H)
    cgrp = jnp.repeat(cc, rep, axis=3).reshape(bsz, nc, chunk, h, n)
    y_inter = jnp.einsum("bnlhz,bnhpz,bnlh->bnlhp", cgrp, prev_states, decay_from_start)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final_state


def ssd_decode_step(x, dt, A, b_mat, c_mat, state):
    """One-token SSD update. x: (B,1,H,P); dt: (B,1,H); b/c: (B,1,G,N);
    state: (B,H,P,N). Returns y (B,1,H,P), new state."""
    bsz, _, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    da = jnp.exp(dt[:, 0] * A)                          # (B,H)
    bh = jnp.repeat(b_mat[:, 0], rep, axis=1)           # (B,H,N)
    ch = jnp.repeat(c_mat[:, 0], rep, axis=1)           # (B,H,N)
    u = x[:, 0] * dt[:, 0, :, None]                     # (B,H,P)
    new_state = state * da[..., None, None] + jnp.einsum("bhp,bhz->bhpz", u, bh)
    y = jnp.einsum("bhpz,bhz->bhp", new_state, ch)
    return y[:, None], new_state


# ---------------------------------------------------------------------------
# full block forward
# ---------------------------------------------------------------------------

def mamba2_forward(
    p,
    x_in: jnp.ndarray,                   # (B,S,D) post-norm input
    *,
    cfg: SSMConfig,
    d_model: int,
    mode: str = "train",
    cache: Optional[dict] = None,
):
    bsz, s, _ = x_in.shape
    d_inner = cfg.d_inner(d_model)
    nheads = cfg.num_heads(d_model)
    g, n, pdim = cfg.ngroups, cfg.d_state, cfg.head_dim

    zxbcdt = x_in @ p["in_proj"]
    z, xr, b_mat, c_mat, dt = _split_proj(zxbcdt, cfg, d_model)

    xbc = jnp.concatenate([xr, b_mat, c_mat], axis=-1)
    conv_state = cache["conv"] if (cache is not None and mode == "decode") else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xr, b_mat, c_mat = jnp.split(xbc, [d_inner, d_inner + g * n], axis=-1)

    xh = xr.reshape(bsz, s, nheads, pdim).astype(jnp.float32)
    bg = b_mat.reshape(bsz, s, g, n).astype(jnp.float32)
    cg = c_mat.reshape(bsz, s, g, n).astype(jnp.float32)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_neg = -jnp.exp(p["A_log"])                        # (H,)

    if mode == "decode":
        assert cache is not None
        y, new_ssd = ssd_decode_step(xh, dtp, a_neg, bg, cg, cache["ssd"])
    else:
        pad = (-s) % cfg.chunk_size
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            bg = jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cg = jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dtp, ((0, 0), (0, pad), (0, 0)))
        y, new_ssd = ssd_chunked(xh, dtp, a_neg, bg, cg, chunk=cfg.chunk_size)
        if pad:
            y = y[:, :s]

    y = y[:, :s] + xh[:, :s] * p["D"][None, None, :, None]   # skip-connection D term
    y = y.reshape(bsz, s, d_inner).astype(x_in.dtype)
    y = gated_rmsnorm(p["norm_scale"], y, z)
    out = y @ p["out_proj"]

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"conv": new_conv.astype(x_in.dtype), "ssd": new_ssd}
    return out, new_cache
