"""Feature screening for the regularization path.

Sequential strong rule (Tibshirani et al., JRSS-B 2012, §5) adapted to the
paper's conventions (y in {-1, +1}, margins-cached gradient):

    keep j  iff  |g_j(beta_hat(lam_prev))| >= 2*lam - lam_prev

where g = nabla L(beta) = X^T (sigmoid(m) - (y+1)/2) is the
negative-log-likelihood gradient at the warm-start point. The rule is a
heuristic (it assumes the gradient is 1-Lipschitz along the path), so every
screened solve is followed by a KKT post-check over the *discarded* set;
violations re-enter the working set and the solve repeats. For lasso-type
problems the check passes almost always, making the expected cost of a path
point proportional to the active-set size instead of p.

All predicates run on device; only the active-set *size* crosses to host
(the path driver needs it to pick a gather capacity bucket).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import engine
from repro.core.objective import grad_nll_from_margins


def nll_grad_abs(X, y, m) -> jnp.ndarray:
    """|g_j| = |x_j^T (sigmoid(m) - (y+1)/2)| for all p features."""
    return jnp.abs(grad_nll_from_margins(m, y, X))


def _nll_residual(m, y):
    """v = sigmoid(m) - (y+1)/2, the per-example NLL gradient factor."""
    return jax.nn.sigmoid(m) - (y + 1.0) * 0.5


@jax.jit
def nll_grad_abs_sparse(row_idx, values, y, m) -> jnp.ndarray:
    """Sparse-native |g_j| over a by-feature layout (paper Table 1).

    ``row_idx``/``values`` are (p, K) with sentinel row index n; the pass
    is the kernel layer's slab correlation ``X^T v`` (a pure gather-reduce
    over the slabs, sentinel slots exact zero) — a dense (n, p) X is never
    materialized. Memory is O(nnz), the size of the slabs themselves.
    """
    from repro.kernels.ops import slab_corr

    return jnp.abs(slab_corr(row_idx, values, _nll_residual(m, y)))


@jax.jit
def strong_rule_mask(g_abs, lam, lam_prev, beta) -> jnp.ndarray:
    """Sequential-strong-rule working set at ``lam`` given the previous
    solution (gradient magnitudes ``g_abs`` and coefficients ``beta`` at
    ``lam_prev``). Ever-active features are always kept: warm starts must
    be representable in the restricted problem.

    The admission threshold is ``max(2*lam - lam_prev, lam)``: the strong
    rule alone degenerates on coarse grids (on the paper's halving grid
    ``2*lam - lam_prev = 0``, admitting everything), so it is intersected
    with the warm-start KKT activation test ``|g_j| > lam`` — features at
    their lam_prev-optimum value cannot activate at lam unless their
    gradient already exceeds lam (GLMNET's ever-active + violators
    strategy). Both halves are heuristic bounds on the gradient's path
    drift; the KKT post-check makes either safe."""
    lam = jnp.float32(lam)
    lam_prev = jnp.maximum(jnp.float32(lam_prev), lam)
    thresh = jnp.maximum(2.0 * lam - lam_prev, lam)
    return jnp.logical_or(g_abs >= thresh, beta != 0.0)


@jax.jit
def kkt_violations(g_abs, lam, mask, *, tol: float = 1e-3) -> jnp.ndarray:
    """KKT post-check on the discarded set.

    At an optimum of the full problem, every j with beta_j = 0 must satisfy
    |g_j| <= lam. Features outside ``mask`` were *forced* to zero by the
    screen, so |g_j| > lam(1+tol) there means the screen was wrong and j
    must re-enter. Returns the boolean violation mask (all-False == screen
    certified).
    """
    slack = lam * (1.0 + tol) + 1e-7
    return jnp.logical_and(jnp.logical_not(mask), g_abs > slack)


def budgeted_admission(viol, g_abs, budget: int):
    """Blitz-style violator admission: keep only the ``budget`` most-violating
    features (largest ``g_abs``) of ``viol``; the rest wait for a later
    round. Admitting every violator at once blows the capacity bucket up a
    power-of-two step (and a solver retrace) for features that frequently
    solve straight back to zero; the budget grows the working set
    incrementally instead. Ties at the cutoff are all admitted (the budget
    is a growth *rate*, not an exact count). Returns the admitted mask."""
    n_viol = int(engine.device_get(viol.sum()))
    if n_viol <= budget:
        return viol
    scores = jnp.where(viol, g_abs, -jnp.inf)
    cutoff = jax.lax.top_k(scores, budget)[0][-1]
    return jnp.logical_and(viol, scores >= cutoff)


def capacity_bucket(count: int, p: int, *, tile: int) -> int:
    """Round an active-set size up to a power-of-two multiple of ``tile``
    (min ``tile``, max ``p``). Bounds the number of distinct restricted
    shapes — and hence solver retraces — to O(log(p / tile)) per path."""
    cap = max(tile, 1)
    while cap < count:
        cap *= 2
    return min(cap, p)


def pack_indices(mask, cap: int) -> jnp.ndarray:
    """Stable front-pack of the selected indices into shape ``(cap,)``,
    sentinel ``p`` (== mask size) marking padding. The shared primitive
    behind the dense column gather here and the slab gather in
    ``data/byfeature.py``."""
    p = mask.shape[0]
    order = jnp.argsort(jnp.where(mask, jnp.arange(p), p))
    return jnp.where(jnp.arange(p) < jnp.sum(mask), order, p)[:cap]


def gather_columns(X, beta, mask, cap: int):
    """Device-side gather of the working set into a (n, cap) problem.

    Returns (X_sub, beta_sub, idx) where idx has shape (cap,) with sentinel
    ``p`` marking padding; padded columns are all-zero, so their
    coordinates provably stay at zero (soft-threshold of a zero gradient)
    and the restricted solve is exactly the masked full solve.
    """
    idx = pack_indices(mask, cap)
    X_sub = jnp.take(X, idx, axis=1, mode="fill", fill_value=0.0)
    beta_sub = jnp.take(beta, idx, mode="fill", fill_value=0.0)
    return X_sub, beta_sub, idx


def scatter_columns(beta_sub, idx, p: int):
    """Inverse of :func:`gather_columns`: restricted solution -> full
    beta (padding rows dropped via out-of-bounds scatter)."""
    return jnp.zeros(p, beta_sub.dtype).at[idx].set(beta_sub, mode="drop")


@lru_cache(maxsize=64)
def _sparse_corr_program(mesh: Mesh, n_loc: int, tile: int,
                         model_axis: str = "model"):
    """The shard_map slab-stream behind both the sparse screen and
    ``Design.correlation``: ``corr(row_idx, values, v) -> X^T v`` (signed),
    feature-sharded P(model). Un-jitted so callers can fuse it into their
    own programs; see :func:`make_sparse_corr` for the jitted form."""
    from repro.compat import shard_map
    from repro.core.distributed import _data_axes

    daxes = _data_axes(mesh)
    dspec = P(daxes) if daxes else P()

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(model_axis, daxes, None), P(model_axis, daxes, None),
                  dspec),
        out_specs=P(model_axis),
    )
    def corr(row_idx, values, v):
        from repro.kernels.ops import slab_corr

        rows, vals = row_idx[:, 0, :], values[:, 0, :]
        p_loc, k = rows.shape
        assert p_loc % tile == 0, (
            f"per-shard feature count {p_loc} must be a multiple of "
            f"tile={tile} (pad the slabs upstream)"
        )

        def tile_pass(_, i):
            rt = jax.lax.dynamic_slice(rows, (i * tile, 0), (tile, k))
            vt = jax.lax.dynamic_slice(vals, (i * tile, 0), (tile, k))
            return None, slab_corr(rt, vt, v)

        _, g = jax.lax.scan(tile_pass, None, jnp.arange(p_loc // tile))
        g = g.reshape(p_loc)
        for ax in daxes:
            g = jax.lax.psum(g, ax)
        return g

    return corr


@lru_cache(maxsize=64)
def make_sparse_corr(mesh: Mesh, n_loc: int, tile: int,
                     model_axis: str = "model"):
    """Jitted distributed slab correlation ``corr(row_idx, values, v) ->
    X^T v`` over (p, DP, K) mesh slabs (sharded P(model, data, None), local
    row indices with sentinel ``n_loc``); ``v`` is example-sharded P(data).
    Per-tile memory is (tile, K) — never a dense (n, p) block. This is the
    one gradient-pass primitive: the strong-rule screen is ``|corr(...)|``
    at the NLL residual and lambda_max is ``max |corr(0.5 y)|``
    (``repro.api.lambda_max_design``)."""
    return jax.jit(_sparse_corr_program(mesh, n_loc, tile, model_axis))


@lru_cache(maxsize=64)
def make_sparse_screen(mesh: Mesh, n_loc: int, tile: int,
                       model_axis: str = "model"):
    """Distributed strong-rule gradient pass over by-feature sparse slabs.

    Builds a jitted ``screen(row_idx, values, y, m) -> g_abs``: the
    :func:`make_sparse_corr` slab stream evaluated at the per-example NLL
    residual, absolute value taken. The result feeds
    :func:`strong_rule_mask` and :func:`kkt_violations` unchanged (both are
    elementwise in g_abs), making the whole screen sparse-native.
    """
    corr = _sparse_corr_program(mesh, n_loc, tile, model_axis)

    @jax.jit
    def screen(row_idx, values, y, m):
        return jnp.abs(corr(row_idx, values, _nll_residual(m, y)))

    return screen
