"""Serving demo: prefill a batch of prompts, then greedy-decode with the KV
cache (or SSM state) — exercises the same serve_step the decode dry-run
shapes lower.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import MODEL_CONFIGS
from repro.models import init_cache, init_params
from repro.train import make_prefill_step, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list(MODEL_CONFIGS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = MODEL_CONFIGS[args.arch].smoke()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)

    cache_len = args.prompt_len + args.tokens
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)

    batch = {"tokens": prompts}
    if cfg.encdec.enabled:
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, 16, cfg.frontend.embed_dim)),
            jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    logits, cache = prefill(params, batch)
    # splice the prefill cache into a full-length cache
    full_cache = init_cache(cfg, args.batch, cache_len)
    full_cache = _splice(full_cache, cache, args.prompt_len)

    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        idx = jnp.asarray(args.prompt_len + i, jnp.int32)
        _, next_tok, full_cache = serve(params, full_cache, idx, tok)
        tok = next_tok[:, None]
        out.append(tok)
    dt = (time.time() - t0) / max(args.tokens - 1, 1)
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name}  generated {gen.shape} tokens  {dt*1e3:.1f} ms/token")
    print("sample:", np.asarray(gen[0][:16]))


def _splice(full, prefill_cache, prompt_len):
    """Copy prefill results into the front of the full-length cache."""
    import jax

    def per_leaf(f, p):
        if f.shape == p.shape:
            return p
        # seq axis differs; write p at offset 0 along that axis
        axis = next(i for i, (a, b) in enumerate(zip(f.shape, p.shape)) if a != b)
        idx = [slice(None)] * f.ndim
        idx[axis] = slice(0, p.shape[axis])
        return f.at[tuple(idx)].set(p.astype(f.dtype))

    return jax.tree.map(per_leaf, full, prefill_cache)


if __name__ == "__main__":
    main()
