from repro.kernels.ops import (  # noqa: F401
    flash_attention,
    gram_cd,
    logistic_stats,
    prefer_slab_gram,
    slab_corr,
    slab_gram,
    slab_spmv,
)
