"""Train / prefill / decode steps for every architecture.

``make_train_step(cfg)`` returns a pure function
    step(state, batch) -> (state', metrics)
suitable for jit with in/out shardings from repro.sharding.rules.

Loss: masked token cross-entropy (labels == IGNORE are excluded — used for
multimodal prefix positions and padding) + MoE auxiliary losses + the
DeepSeek-style MTP auxiliary CE when enabled.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import forward
from repro.optim import make_optimizer
from repro.optim.optimizers import apply_updates, clip_by_global_norm

IGNORE = -100


def cross_entropy(logits, labels, ignore=IGNORE):
    """Masked CE; logits (B,S,V), labels (B,S) int32 (may contain IGNORE).

    The gold-logit read uses a one-hot contraction rather than
    take_along_axis: with the vocab dim sharded over `model`, the gather
    would force an all-gather of the logits; the contraction partitions
    cleanly (partial sums + psum). Keeps f32 only inside the reduction.
    """
    mask = labels != ignore
    safe = jnp.where(mask, labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    return nll.sum() / denom, denom


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch, deterministic=True):
        logits, _, aux = forward(
            params, batch, cfg, mode="train", deterministic=deterministic
        )
        labels = batch["labels"]
        # logits cover (prefix + text); labels are provided full-length
        ce, ntok = cross_entropy(logits[:, -labels.shape[1]:, :], labels)
        loss = ce
        metrics = {"ce": ce, "ntok": ntok}
        for k in ("moe_lb_loss", "moe_z_loss"):
            if k in aux:
                loss = loss + aux[k]
                metrics[k] = aux[k]
        if "moe_drop_frac" in aux:
            metrics["moe_drop_frac"] = aux["moe_drop_frac"]
        if "mtp_logits" in aux:
            # MTP predicts token t+2: shift labels by one extra position
            mtp_labels = jnp.concatenate(
                [labels[:, 1:], jnp.full_like(labels[:, :1], IGNORE)], axis=1
            )
            mtp_ce, _ = cross_entropy(aux["mtp_logits"], mtp_labels)
            loss = loss + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, lr_schedule=None, clip_norm: float = 1.0):
    """cfg.microbatch > 1 enables gradient accumulation: the global batch is
    split on the leading axis and scanned, bounding activation memory to one
    microbatch (how the big configs fit 16 GB/chip — see EXPERIMENTS)."""
    opt = make_optimizer(cfg.optimizer)
    loss_fn = make_loss_fn(cfg)
    lr_schedule = lr_schedule or (lambda s: jnp.float32(3e-4))
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        n = cfg.microbatch
        b = batch["tokens"].shape[0]
        if n <= 1 or b % n:
            return grad_fn(params, batch)
        micro = jax.tree.map(lambda a: a.reshape((n, b // n) + a.shape[1:]), batch)

        def constrain_grads(grads):
            """Pin per-microbatch grads to the PARAM sharding: XLA then
            reduce-scatters each microbatch's contribution to its FSDP shard
            instead of all-reducing the full gradient every microbatch
            (deepseek train_4k: 2.9 TB -> ~0.2 TB, see EXPERIMENTS §Perf)."""
            from repro.sharding.ctx import current_mesh
            from jax.sharding import NamedSharding

            mesh = current_mesh()
            if mesh is None:
                return grads
            from repro.sharding.rules import param_pspecs

            specs = param_pspecs(cfg, grads, mesh)
            return jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(mesh, s)),
                grads, specs)

        def body(carry, mb):
            (loss_a, metrics_a, grads_a) = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads = constrain_grads(grads)
            grads = jax.tree.map(lambda x, y: x + y / n, grads_a, grads)
            metrics = jax.tree.map(lambda x, y: x + y / n, metrics_a, metrics)
            return (loss_a + loss / n, metrics, grads), None

        # accumulate in the gradient dtype (= param dtype): f32 accumulators
        # double the carry and XLA's while-loop phi copies triple it — at
        # 671B/256 chips that is the difference between fitting and not.
        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        mb0 = jax.tree.map(lambda a: a[0], micro)
        (_, m0_shape), _ = jax.eval_shape(grad_fn, params, mb0)
        zero_m = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m0_shape)
        (loss, metrics, grads), _ = jax.lax.scan(
            body, (jnp.float32(0.0), zero_m, zero_g), micro
        )
        return (loss, metrics), grads

    def step(state, batch):
        (loss, metrics), grads = accumulate(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(state["step"])
        updates, new_opt = opt.update(grads, state["opt"], state["params"], lr)
        new_params = apply_updates(state["params"], updates)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return (
            {"params": new_params, "opt": new_opt, "step": state["step"] + 1},
            metrics,
        )

    return step


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, batch):
        logits, cache, _ = forward(params, batch, cfg, mode="prefill")
        return logits[:, -1:, :], cache

    return prefill


def make_serve_step(cfg: ModelConfig, *, long_mode: bool = False):
    """ONE new token against a cache of cache_len entries (decode shapes)."""

    def serve(params, cache, cache_index, tokens):
        kw = {} if cfg.encdec.enabled else {"long_mode": long_mode}
        logits, new_cache, _ = forward(
            params, {"tokens": tokens}, cfg, mode="decode",
            cache=cache, cache_index=cache_index, **kw,
        )
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return logits, next_tok, new_cache

    return serve
