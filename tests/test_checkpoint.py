"""Checkpointer round-trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.configs import MODEL_CONFIGS
from repro.train import make_train_state


def test_round_trip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
        "list": [jnp.zeros(3), jnp.ones(2)],
    }
    save_pytree(tree, str(tmp_path / "ck"), step=42)
    out = load_pytree(str(tmp_path / "ck"), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_train_state_round_trip(tmp_path):
    cfg = MODEL_CONFIGS["tinyllama-1.1b"].smoke()
    state = make_train_state(jax.random.key(0), cfg)
    save_pytree(state, str(tmp_path / "state"))
    restored = load_pytree(str(tmp_path / "state"), state)
    a = jax.tree.leaves(state["params"])[0]
    b = jax.tree.leaves(restored["params"])[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((2, 2))}
    save_pytree(tree, str(tmp_path / "ck"))
    bad = {"a": jnp.zeros((3, 3))}
    try:
        load_pytree(str(tmp_path / "ck"), bad)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass
