"""pallas-conventions: every kernel entry point keeps its CPU oracle.

The repo's Pallas kernels are validated on CPU with ``interpret=True``
against pure-jnp oracles in ``kernels/ref.py`` (the tests' allclose
targets); native-TPU compilation is the production path. That parity
only holds while two conventions hold:

1. every public kernel entry point threads an ``interpret`` parameter
   (so tests can force the emulator and TPU code can force native);
2. every public ``*_pallas`` entry has a ``*_ref`` oracle counterpart in
   the sibling ``ref.py``.

The ROADMAP's native-TPU kernel campaign multiplies kernel entry points;
this rule is what keeps each new one honest without a hand audit.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "pallas-conventions"
DOC = ("public pallas_call entry points must thread `interpret` and have "
       "a *_ref oracle in the sibling ref.py")


def _imports_pallas(mod: ModuleInfo) -> bool:
    return any(m.startswith("jax.experimental.pallas")
               for m in mod.imported_modules)


def _calls_pallas_call(mod: ModuleInfo, fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            q = mod.qualname(node.func)
            if q is not None and q.endswith("pallas_call"):
                return True
    return False


def _has_interpret_param(fn: ast.FunctionDef) -> bool:
    a = fn.args
    return any(p.arg == "interpret"
               for p in a.posonlyargs + a.args + a.kwonlyargs)


def _ref_names(project: Project, mod: ModuleInfo) -> Optional[Set[str]]:
    """Top-level def names in the sibling ref.py, or None if there is no
    oracle module next to this kernel module."""
    pkg_dir = mod.path.rsplit("/", 1)[0] if "/" in mod.path else ""
    ref = project.by_path(f"{pkg_dir}/ref.py" if pkg_dir else "ref.py")
    if ref is None or ref.path == mod.path:
        return None
    return {n.name for n in ref.tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not _imports_pallas(mod):
            continue
        ref_names = _ref_names(project, mod)
        for fn in mod.tree.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name.startswith("_") or not _calls_pallas_call(mod, fn):
                continue
            if not _has_interpret_param(fn):
                out.append(Finding(
                    file=mod.path, line=fn.lineno, rule=RULE_ID,
                    message=(
                        f"pallas entry point {fn.name}() does not thread an "
                        f"`interpret` parameter — CPU oracle validation and "
                        f"native-TPU compilation need the caller to choose"),
                ))
            base = fn.name[:-7] if fn.name.endswith("_pallas") else fn.name
            if ref_names is not None and f"{base}_ref" not in ref_names:
                out.append(Finding(
                    file=mod.path, line=fn.lineno, rule=RULE_ID,
                    message=(
                        f"pallas entry point {fn.name}() has no "
                        f"{base}_ref oracle in the sibling ref.py — every "
                        f"kernel keeps an interpret-parity target the "
                        f"tests can allclose against"),
                ))
    return out
