"""Production training launcher.

On a real TPU slice this runs under `jax.distributed` with one process per
host; on CPU it runs the same code on fake devices for rehearsal:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --mesh 2x4 --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import save_pytree
from repro.configs import MODEL_CONFIGS
from repro.data.lm_data import batches, zipf_corpus
from repro.launch.mesh import parse_mesh
from repro.optim import warmup_cosine
from repro.sharding.ctx import mesh_context
from repro.sharding.rules import input_pspecs, opt_state_pspecs, param_pspecs
from repro.train import make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(MODEL_CONFIGS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="prod")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = MODEL_CONFIGS[args.arch]
    if args.smoke:
        cfg = cfg.smoke()
    mesh = parse_mesh(args.mesh)
    print(f"arch={cfg.name} mesh={dict(mesh.shape)} devices={len(jax.devices())}")

    with mesh_context(mesh):
        state = make_train_state(jax.random.key(0), cfg)
        pspec = param_pspecs(cfg, jax.eval_shape(lambda: state)["params"], mesh)
        ospec = opt_state_pspecs(cfg, jax.eval_shape(lambda: state)["opt"], pspec, mesh)
        named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                       is_leaf=lambda x: isinstance(x, P))
        st_sh = {"params": named(pspec), "opt": named(ospec),
                 "step": NamedSharding(mesh, P())}
        state = jax.device_put(state, st_sh)

        sched = warmup_cosine(args.lr, max(args.steps // 10, 1), args.steps)
        rng = np.random.default_rng(0)
        corpus = zipf_corpus(rng, cfg.vocab_size, 1_000_000)
        it = batches(corpus, args.batch, args.seq, cfg=cfg, rng=rng)
        b0 = next(it)
        b_sh = named(input_pspecs(cfg, jax.eval_shape(lambda: b0), mesh))

        step_fn = jax.jit(make_train_step(cfg, lr_schedule=sched),
                          in_shardings=(st_sh, b_sh), donate_argnums=0)

        t0 = time.time()
        for i in range(args.steps):
            batch = jax.device_put(next(it) if i else b0, b_sh)
            state, metrics = step_fn(state, batch)
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss={float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)")
        if args.ckpt:
            save_pytree(state, args.ckpt, step=args.steps)
            print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
