"""Activation sharding-constraint context.

Model code is mesh-agnostic; the launcher wraps lowering in
``mesh_context(mesh)`` and the model calls ``constrain(x, "batch", None,
"model")`` at propagation-critical points (embeddings, segment boundaries,
logits). Outside a context (unit tests, single device) it is a no-op.

Symbolic axes: "batch" -> ("pod","data") ∩ mesh axes; "model" -> "model";
None -> unsharded. Every constraint is divisibility-guarded so batch=1
decode shapes and odd head counts degrade to replication instead of erroring.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None
)
_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_unroll", default=False
)


@contextlib.contextmanager
def unroll_context(enabled: bool = True):
    """Unroll inner loops (attention query chunks) so HloCostAnalysis sees
    every FLOP — used by the dry-run's cost pass, not for real training."""
    tok = _UNROLL.set(enabled)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def unroll_enabled() -> bool:
    return _UNROLL.get()


_FLASH_DECODE: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_flash_decode", default=False
)


@contextlib.contextmanager
def flash_decode_context(enabled: bool = True):
    """Enable sequence-parallel flash-decode attention (partial-softmax
    psum combine over the seq-sharded KV cache) — see EXPERIMENTS §Perf."""
    tok = _FLASH_DECODE.set(enabled)
    try:
        yield
    finally:
        _FLASH_DECODE.reset(tok)


def flash_decode_enabled() -> bool:
    return _FLASH_DECODE.get()


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    tok = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def _resolve(mesh: Mesh, sym):
    """Returns a preference-ordered list of axis groups for a symbol."""
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = ("model",) if "model" in mesh.axis_names else ()
    if sym == "batch":
        return [batch if batch else None, None]
    if sym == "model":
        return [model if model else None, None]
    if sym == "expert":
        # experts prefer the full mesh (1 expert/device at deepseek scale),
        # fall back to model-only (llama4's 16 experts), else replicate
        return [model + batch if (model and batch) else None,
                model if model else None, None]
    return [sym, None]


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, *spec):
    """with_sharding_constraint under the ambient mesh (no-op without one)."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    if len(spec) != x.ndim:
        raise ValueError(f"spec rank {len(spec)} != array rank {x.ndim}")
    resolved = []
    for dim, sym in zip(x.shape, spec):
        ax = None
        for cand in _resolve(mesh, sym):
            if cand is None or dim % _axis_size(mesh, cand) == 0:
                ax = cand
                break
        resolved.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*resolved))
    )
