"""Regularization path (paper Algorithm 5).

Find lambda_max for which beta = 0, then solve with
lambda = lambda_max * 2^{-i}, i = 1..path_len, warm-starting each solve from
the previous beta.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core.dglmnet import DGLMNETOptions, FitResult, fit
from repro.core.objective import lambda_max


@dataclass
class PathPoint:
    lam: float
    nnz: int
    f: float
    n_iters: int
    beta: jnp.ndarray
    metrics: dict = field(default_factory=dict)


def regularization_path(
    X,
    y,
    *,
    path_len: int = 20,
    opts: DGLMNETOptions = DGLMNETOptions(),
    eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
    extra_lams: Optional[List[float]] = None,
    verbose: bool = False,
) -> List[PathPoint]:
    """Returns one PathPoint per lambda (decreasing). ``eval_fn(beta)``
    computes test metrics (e.g. AUPRC) per point — the paper's Figure 1."""
    lmax = float(lambda_max(X, y))
    lams = [lmax * 2.0 ** (-i) for i in range(1, path_len + 1)]
    if extra_lams:
        lams = sorted(set(lams) | set(extra_lams), reverse=True)

    beta = jnp.zeros(X.shape[1], jnp.float32)
    points: List[PathPoint] = []
    for lam in lams:
        res: FitResult = fit(X, y, lam, beta0=beta, opts=opts)
        beta = res.beta
        metrics = eval_fn(beta) if eval_fn else {}
        points.append(
            PathPoint(lam=lam, nnz=res.nnz, f=res.f, n_iters=res.n_iters,
                      beta=beta, metrics=metrics)
        )
        if verbose:
            print(
                f"lambda={lam:10.4f} nnz={res.nnz:6d} f={res.f:12.4f} "
                f"iters={res.n_iters:3d} {metrics}"
            )
    return points
