"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (plus commented context lines).

    PYTHONPATH=src python -m benchmarks.run \
        [--only fig1|table2|table3|kernels|ablation|regpath]
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["fig1", "table2", "table3", "kernels", "ablation",
                             "regpath"])
    args = ap.parse_args()

    from benchmarks import fig1_quality_sparsity, kernels_bench, table2_datasets, table3_timing

    print("name,us_per_call,derived")
    if args.only in (None, "table2"):
        table2_datasets.run()
    if args.only in (None, "table3"):
        table3_timing.run()
    if args.only in (None, "fig1"):
        fig1_quality_sparsity.run()
    if args.only in (None, "kernels"):
        kernels_bench.run()
    if args.only == "ablation":   # opt-in: ~8 min
        from benchmarks import ablation_parallel_cd

        ablation_parallel_cd.run()
    if args.only == "regpath":    # opt-in: emits BENCH_regpath.json
        from benchmarks import regpath_bench

        regpath_bench.run()


if __name__ == "__main__":
    main()
