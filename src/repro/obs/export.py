"""Trace/metrics exporters: Chrome trace-event JSON, JSONL, summary.

The Chrome trace file loads directly in Perfetto (https://ui.perfetto.dev)
or chrome://tracing — spans become "X" (complete) events with
microsecond timestamps relative to the tracer's start. The summary JSON
is the machine-readable side file consumed by `repro.obs.report`,
`benchmarks/compare_bench.py --fresh-trace` and the chaos launcher's
fault-counter assertions.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer

__all__ = [
    "chrome_trace",
    "summarize",
    "write_chrome_trace",
    "write_jsonl",
    "write_summary",
]


def _records(tracer: Tracer) -> List[dict]:
    with tracer._lock:
        return list(tracer.spans)


def chrome_trace(tracer: Tracer) -> dict:
    """Trace-event-format dict (the JSON object form, Perfetto-loadable)."""
    events = []
    for r in sorted(_records(tracer), key=lambda r: r["ts"]):
        events.append({
            "name": r["name"],
            "ph": "X",
            "ts": r["ts"] * 1e6,
            "dur": r["dur"] * 1e6,
            "pid": 0,
            "tid": r["tid"],
            "args": dict(r["args"]),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(tracer: Tracer, path: str) -> None:
    with open(path, "w") as fh:
        json.dump(chrome_trace(tracer), fh, default=str)


def write_jsonl(tracer: Tracer, path: str) -> None:
    """One span record per line, in completion order."""
    with open(path, "w") as fh:
        for r in _records(tracer):
            fh.write(json.dumps(r, default=str) + "\n")


def summarize(tracer: Optional[Tracer] = None,
              registry: Optional[MetricsRegistry] = None) -> dict:
    """Aggregate a tracer + registry into one JSON-safe summary dict.

    Keys (all optional depending on what was recorded):

    * ``wall_s`` — last span end relative to tracer start.
    * ``spans`` — per-name totals: ``{name: {count, total_s, mean_s, max_s}}``.
    * ``roots`` — top-level spans in order: ``[{name, dur_s, args}]``.
    * ``phases`` — per-root-name totals of *direct* children grouped by
      name: ``{"path": {"lambda_grid": s, "lambda_point": s}}``. For a
      single traced path solve the phase totals sum to the root span's
      duration minus inter-span gaps (strategy resolution, checkpoint
      bookkeeping) — within 5% of warm wall time.
    * ``per_lambda`` — one row per ``lambda_point`` span: its args
      (index, lam, nnz, status, ...), ``dur_s``, and direct-child phase
      totals (screen_round / restricted_solve / kkt_check / ...).
    * ``counters`` / ``gauges`` / ``histograms`` / ``callbacks`` — the
      registry's `collect()` snapshot, flattened in.
    """
    out: dict = {}
    if tracer is not None:
        records = _records(tracer)
        children: Dict[int, List[dict]] = {}
        per_name: Dict[str, dict] = {}
        roots: List[dict] = []
        for r in records:
            agg = per_name.setdefault(
                r["name"], {"count": 0, "total_s": 0.0, "max_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += r["dur"]
            agg["max_s"] = max(agg["max_s"], r["dur"])
            if r["parent"] is None:
                roots.append(r)
            else:
                children.setdefault(r["parent"], []).append(r)
        for agg in per_name.values():
            agg["mean_s"] = agg["total_s"] / max(agg["count"], 1)

        def child_totals(rec: dict) -> Dict[str, float]:
            totals: Dict[str, float] = {}
            for c in children.get(rec["sid"], ()):
                totals[c["name"]] = totals.get(c["name"], 0.0) + c["dur"]
            return totals

        phases: Dict[str, Dict[str, float]] = {}
        for r in roots:
            fam = phases.setdefault(r["name"], {})
            for name, total in child_totals(r).items():
                fam[name] = fam.get(name, 0.0) + total

        per_lambda = [
            {**dict(r["args"]), "dur_s": r["dur"], "phases": child_totals(r)}
            for r in sorted(records, key=lambda r: r["ts"])
            if r["name"] == "lambda_point"
        ]

        out["wall_s"] = tracer.wall_s()
        out["spans"] = {k: per_name[k] for k in sorted(per_name)}
        out["roots"] = [{"name": r["name"], "dur_s": r["dur"],
                         "args": dict(r["args"])}
                        for r in sorted(roots, key=lambda r: r["ts"])]
        out["phases"] = phases
        out["per_lambda"] = per_lambda
    if registry is not None:
        out.update(registry.collect())
    return out


def write_summary(summary: dict, path: str) -> None:
    with open(path, "w") as fh:
        json.dump(summary, fh, indent=2, default=str)
        fh.write("\n")
