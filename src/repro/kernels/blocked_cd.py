"""Pallas TPU kernel: blocked semi-parallel CD cycle on a Gram tile.

``gram_cd`` runs d-GLMNET's within-tile cycle as F dependent scalar
soft-threshold steps — correct, but the VPU/MXU idle between steps. This
kernel breaks the chain into F/B dependent steps: each B-wide block is
updated proximal-Jacobi style from the shared gradient snapshot
``g = c - s`` (one lane-masked vector soft-threshold), then applied with a
single ``(1, F) @ (F, F)`` MXU matvec ``s += d_blk @ G`` before the next
block. The paper's Theorem-1 convergence only needs the block-separable
model plus the global line search, so the within-tile cycle is free to be
semi-parallel (Shotgun, arXiv:1105.5379; inexact block solves with a
line-search safeguard, arXiv:1405.4544).

The per-block safeguard decision is *precomputed outside the kernel* from
G alone (``core.subproblem.blocked_cycle_modes`` — a Gershgorin dominance
check, iterate-independent) and passed in as a scalar-memory mode vector:

* mode 0 — full-B Jacobi step;
* mode 1 — two sequential B/2-wide Jacobi sub-steps (halved block);
* mode 2 — the sequential scalar chain over the block (pathological
  correlation; identical math to ``gram_cd`` restricted to the block).

VMEM budget matches ``gram_cd`` (G F^2 + 6 vectors); F stays 128-aligned
in the hot paths. Validated on CPU with ``interpret=True`` against
``ref.blocked_cd_ref`` (= the core solver's own blocked cycle, which is
bit-identical to the sequential chain at B=1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import out_shape_struct
from repro.core.subproblem import DOM_TOL, blocked_cycle_modes


def _make_blocked_cd_kernel(block: int):
    """Kernel body closure over the static block width B."""

    def kernel(scal_ref, modes_ref, G_ref, h_ref, c_ref, beta_ref,
               dbeta0_ref, d_ref, s_ref):
        """Refs: scal (1,1)=[lam] SMEM; modes (1, F/B) int32 SMEM;
        G (F,F), h (1,F)=diag+nu, c/beta/dbeta0 (1,F) VMEM; out d (1,F);
        scratch s (1,F) = G @ d maintained incrementally."""
        f = G_ref.shape[0]
        nb = f // block
        lam = scal_ref[0, 0]

        d_ref[...] = jnp.zeros_like(d_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

        lane = jax.lax.broadcasted_iota(jnp.int32, (1, f), 1)

        def jacobi_step(start, width):
            # proximal-Jacobi on [start, start+width): full-lane vector
            # soft-threshold, update masked to the block
            mask = jnp.logical_and(lane >= start,
                                   lane < start + width).astype(jnp.float32)
            h = h_ref[...]
            b_old = beta_ref[...] + dbeta0_ref[...] + d_ref[...]
            u = (c_ref[...] - s_ref[...]) + b_old * h
            b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - lam, 0.0) / h
            delta = (b_new - b_old) * mask                     # (1, F)
            # s += G @ d_blk as one MXU matvec (G symmetric)
            s_ref[...] = s_ref[...] + jnp.dot(
                delta, G_ref[...], preferred_element_type=jnp.float32)
            d_ref[...] = d_ref[...] + delta

        def seq_step(j):
            # one scalar chain step (== gram_cd's body at coordinate j)
            onehot = (lane == j).astype(jnp.float32)
            g = jnp.sum((c_ref[...] - s_ref[...]) * onehot)
            h = jnp.sum(h_ref[...] * onehot)
            b_old = jnp.sum(
                (beta_ref[...] + dbeta0_ref[...] + d_ref[...]) * onehot)
            u = g + b_old * h
            b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - lam, 0.0) / h
            delta = b_new - b_old
            g_row = pl.load(G_ref, (pl.ds(j, 1), slice(None)))  # (1, F)
            s_ref[...] = s_ref[...] + delta * g_row
            d_ref[...] = d_ref[...] + delta * onehot

        def body(b, _):
            start = b * block
            mode = modes_ref[0, b]

            @pl.when(mode == 0)
            def _():
                jacobi_step(start, block)

            if block >= 2:       # a 1-wide block is always mode 0
                @pl.when(mode == 1)
                def _():
                    jacobi_step(start, block // 2)
                    jacobi_step(start + block // 2, block // 2)

                @pl.when(mode == 2)
                def _():
                    def chain(j, carry):
                        seq_step(j)
                        return carry

                    jax.lax.fori_loop(start, start + block, chain, 0)
            return 0

        jax.lax.fori_loop(0, nb, body, 0)

    return kernel


@partial(jax.jit, static_argnames=("block", "interpret"))
def blocked_cd_pallas(G, c, beta, dbeta0, lam, nu, *, block: int = 16,
                      dom_tol: float = DOM_TOL, interpret: bool = True):
    """Returns d such that dbeta <- dbeta0 + d (one blocked CD cycle)."""
    f = G.shape[0]
    assert G.shape == (f, f) and c.shape == (f,)
    if f % block:
        raise ValueError(f"block={block} must divide the tile width F={f}")
    nb = f // block
    G = G.astype(jnp.float32)
    # safeguard decision + curvature precomputed outside the kernel: both
    # depend only on G, and the mode vector lives in scalar memory
    modes = blocked_cycle_modes(G, block, nu=nu, dom_tol=dom_tol)[None]
    h = (jnp.diagonal(G) + jnp.asarray(nu, jnp.float32))[None]
    scal = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    out_shape = out_shape_struct((1, f), jnp.float32,
                                 operands=(c, beta, dbeta0, G))
    out = pl.pallas_call(
        _make_blocked_cd_kernel(block),
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # lam
            pl.BlockSpec(memory_space=pltpu.SMEM),            # modes
            pl.BlockSpec((f, f), lambda: (0, 0)),             # G in VMEM
            pl.BlockSpec((1, f), lambda: (0, 0)),             # h = diag + nu
            pl.BlockSpec((1, f), lambda: (0, 0)),
            pl.BlockSpec((1, f), lambda: (0, 0)),
            pl.BlockSpec((1, f), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, f), lambda: (0, 0)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, f), jnp.float32)],
        interpret=interpret,
    )(scal, modes.astype(jnp.int32), G, h, c.astype(jnp.float32)[None],
      beta.astype(jnp.float32)[None], dbeta0.astype(jnp.float32)[None])
    return out[0]
