"""Human-readable report over an obs summary JSON.

    python -m repro.obs.report run1.summary.json

Prints the per-lambda phase table (where each point of the path spent
its wall time), serve p50/p95/p99 latency when a serve histogram was
recorded, and the residency hit-rate when a residency manager was
registered. `render_summary` is the library entry point the quickstart
example uses to print the same report inline.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

__all__ = ["main", "render_summary"]

# lambda_point children, in pipeline order, with compact column labels
_PHASE_COLS = (
    ("screen_round", "screen"),
    ("restricted_solve", "solve"),
    ("kkt_check", "kkt"),
    ("point_finish", "finish"),
)


def _fmt_s(v: Optional[float]) -> str:
    return "-" if v is None else f"{v:.4f}"


def _fmt_ms(v: Optional[float]) -> str:
    return "-" if v is None else f"{v * 1e3:.2f}ms"


def _per_lambda_table(rows: List[dict]) -> List[str]:
    head = (f"{'idx':>4} {'lambda':>12} {'dur_s':>9} "
            + " ".join(f"{label:>9}" for _, label in _PHASE_COLS)
            + f" {'other':>9} {'nnz':>7}  status")
    lines = ["per-lambda phases (seconds):", head, "-" * len(head)]
    for row in rows:
        phases = row.get("phases", {})
        known = sum(phases.get(name, 0.0) for name, _ in _PHASE_COLS)
        other = max(row.get("dur_s", 0.0) - known, 0.0)
        lam = row.get("lam")
        lines.append(
            f"{row.get('index', '-'):>4} "
            f"{lam if lam is None else format(lam, '12.6g'):>12} "
            f"{row.get('dur_s', 0.0):>9.4f} "
            + " ".join(f"{phases.get(name, 0.0):>9.4f}"
                       for name, _ in _PHASE_COLS)
            + f" {other:>9.4f} {str(row.get('nnz', '-')):>7}"
            + f"  {row.get('status', '')}")
    return lines


def render_summary(summary: dict) -> str:
    """Render an obs summary dict (see `repro.obs.export.summarize`)."""
    lines: List[str] = []
    wall = summary.get("wall_s")
    if wall is not None:
        lines.append(f"traced wall time: {wall:.3f}s")
    root_agg: dict = {}
    for root in summary.get("roots", []):
        agg = root_agg.setdefault(root["name"], [0, 0.0])
        agg[0] += 1
        agg[1] += root["dur_s"]
    for name, (count, total) in sorted(root_agg.items(),
                                       key=lambda kv: -kv[1][1]):
        mult = f" x{count}" if count > 1 else ""
        lines.append(f"  root span {name}{mult}: {total:.3f}s")
    phases = summary.get("phases", {})
    for root_name in sorted(phases):
        fam = phases[root_name]
        if not fam:           # leaf roots (stray encodes etc.): no table
            continue
        total = sum(fam.values())
        lines.append(f"phase totals under '{root_name}' "
                     f"(sum {total:.3f}s):")
        for name in sorted(fam, key=fam.get, reverse=True):
            lines.append(f"  {name:<18} {fam[name]:>9.4f}s")
    per_lambda = summary.get("per_lambda", [])
    if per_lambda:
        lines.append("")
        lines.extend(_per_lambda_table(per_lambda))

    hist = summary.get("histograms", {}).get("serve.latency_s")
    if hist and hist.get("count"):
        lines.append("")
        lines.append(
            f"serve submit->score latency ({hist['count']} requests): "
            f"p50 {_fmt_ms(hist['p50'])} / p95 {_fmt_ms(hist['p95'])} / "
            f"p99 {_fmt_ms(hist['p99'])} "
            f"(min {_fmt_ms(hist['min'])}, max {_fmt_ms(hist['max'])})")

    callbacks = summary.get("callbacks", {})
    for name in sorted(callbacks):
        stats = callbacks[name]
        if name.startswith("residency"):
            hits, misses = stats.get("hits", 0), stats.get("misses", 0)
            total = hits + misses
            if total:
                lines.append(
                    f"{name}: hit rate {hits / total:.2f} "
                    f"({hits} hits / {misses} misses, "
                    f"{stats.get('evictions', 0)} evictions, "
                    f"{stats.get('bytes_h2d', 0)} bytes h2d)")
        elif name == "serve.batcher":
            lines.append(f"{name}: " + ", ".join(
                f"{k}={v}" for k, v in sorted(stats.items())))

    counters = summary.get("counters", {})
    interesting = {k: v for k, v in counters.items()
                   if k.startswith(("faults.", "retry.", "serve."))}
    if interesting:
        lines.append("counters: " + ", ".join(
            f"{k}={v}" for k, v in sorted(interesting.items())))
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render an obs summary JSON (written by "
                    "ObsSession.export / regpath_bench --trace-summary / "
                    "the launchers' --trace flag) as a phase report.")
    ap.add_argument("summary", help="path to a *.summary.json file")
    args = ap.parse_args(argv)
    with open(args.summary) as fh:
        summary = json.load(fh)
    print(render_summary(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
