"""Golden fixture: trips sharded-concat and nothing else.

A direct ``jnp.concatenate`` in a mesh-aware module (the ``Mesh`` import
marks it) must route through ``sharding.collect.concat_replicated``.
"""
import jax.numpy as jnp
from jax.sharding import Mesh  # noqa: F401  (marks the module mesh-aware)


def gather_pieces(xs):
    return jnp.concatenate(xs)
