"""Data pipeline: by-feature layout (paper Table 1), synthetic twins, LM
batches."""
import io

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLMConfig
from repro.configs.glm import GLM_EPSILON, GLM_WEBSPAM, twin
from repro.data.byfeature import (
    densify,
    densify_tile,
    partition_features,
    read_table1,
    to_by_feature,
    write_table1,
)
from repro.data.lm_data import batches, zipf_corpus
from repro.data.synthetic import make_glm_dataset


def _rand_sparse(n=64, p=24, density=0.3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p)) * (rng.random((n, p)) < density)
    return jnp.asarray(X, jnp.float32)


def test_by_feature_round_trip():
    X = _rand_sparse()
    bf = to_by_feature(X)
    np.testing.assert_allclose(densify(bf), X, atol=0)
    assert bf.nnz == int((np.asarray(X) != 0).sum())


def test_densify_tile_matches_slice():
    X = _rand_sparse(n=50, p=32)
    bf = to_by_feature(X)
    np.testing.assert_allclose(densify_tile(bf, 8, 16), X[:, 8:24], atol=0)


def test_table1_text_round_trip():
    X = _rand_sparse(n=20, p=10)
    bf = to_by_feature(X)
    buf = io.StringIO()
    write_table1(bf, buf)
    buf.seek(0)
    bf2 = read_table1(buf, bf.n)
    np.testing.assert_allclose(densify(bf2), densify(bf), atol=0)


def test_table1_out_of_order_round_trip():
    """A Map/Reduce shuffle gives no line ordering: the leading feature id,
    not the line position, must decide where a feature lands."""
    X = _rand_sparse(n=20, p=10, seed=3)
    bf = to_by_feature(X)
    buf = io.StringIO()
    write_table1(bf, buf)
    lines = buf.getvalue().splitlines(keepends=True)
    rng = np.random.default_rng(0)
    shuffled = [lines[i] for i in rng.permutation(len(lines))]
    bf2 = read_table1(io.StringIO("".join(shuffled)), bf.n)
    np.testing.assert_allclose(densify(bf2), densify(bf), atol=0)


def test_table1_gap_features_stay_empty():
    """Ids absent from the file become empty features at their position."""
    bf = read_table1(io.StringIO("3 (1:2.5)\n0 (0:1.0) (4:-1.0)\n"), n=6)
    assert bf.p == 4
    dense = np.asarray(densify(bf))
    np.testing.assert_allclose(dense[:, 0], [1.0, 0, 0, 0, -1.0, 0])
    assert not dense[:, 1].any() and not dense[:, 2].any()
    np.testing.assert_allclose(dense[:, 3], [0, 2.5, 0, 0, 0, 0])


def test_to_slabs_local_reindexing():
    """to_slabs regroups each feature's entries per data shard with local
    row indices; re-assembling the shards recovers the dense matrix."""
    from repro.data.byfeature import to_slabs

    X = _rand_sparse(n=24, p=7, seed=4)
    bf = to_by_feature(X)
    row_idx, values, n_loc = to_slabs(bf, 4)
    assert n_loc == 6 and row_idx.shape[:2] == (7, 4)
    dense = np.zeros((24, 7), np.float32)
    ri, vv = np.asarray(row_idx), np.asarray(values)
    for j in range(7):
        for s in range(4):
            live = ri[j, s] < n_loc
            dense[s * n_loc + ri[j, s][live], j] = vv[j, s][live]
    np.testing.assert_allclose(dense, np.asarray(X), atol=0)


def test_gather_scatter_features_roundtrip():
    """Slab gather/scatter mirrors the dense column gather: selected slabs
    match, padding is all-sentinel, and scatter restores the masked beta."""
    import jax.numpy as jnp

    from repro.data.byfeature import gather_features, scatter_features

    X = _rand_sparse(n=16, p=12, seed=5)
    bf = to_by_feature(X)
    beta = jnp.arange(12, dtype=jnp.float32)
    mask = jnp.arange(12) % 3 == 0
    rows_sub, vals_sub, beta_sub, idx = gather_features(
        bf.row_idx, bf.values, beta, mask, cap=8, sentinel=bf.n)
    sel = np.flatnonzero(np.asarray(mask))
    np.testing.assert_array_equal(np.asarray(rows_sub[: len(sel)]),
                                  np.asarray(bf.row_idx)[sel])
    np.testing.assert_allclose(np.asarray(vals_sub[: len(sel)]),
                               np.asarray(bf.values)[sel])
    assert np.all(np.asarray(rows_sub[len(sel):]) == bf.n)
    assert np.all(np.asarray(vals_sub[len(sel):]) == 0)
    back = scatter_features(beta_sub, idx, 12)
    np.testing.assert_allclose(np.asarray(back),
                               np.asarray(jnp.where(mask, beta, 0.0)))


def test_to_slab_buckets_partitions_and_reassembles():
    """Bucketed slabs: every feature lands in exactly one capacity class,
    classes are power-of-two (capped at the global max), and reassembling
    all buckets recovers the dense matrix."""
    from repro.data.byfeature import to_slab_buckets

    rng = np.random.default_rng(9)
    # power-law-ish nnz: a few heavy features, many light ones
    n, p = 48, 15
    X = np.zeros((n, p), np.float32)
    for j in range(p):
        k = 40 if j < 2 else int(rng.integers(1, 5))
        rows = rng.choice(n, size=min(k, n), replace=False)
        X[rows, j] = rng.standard_normal(len(rows))
    bf = to_by_feature(X)
    slabs = to_slab_buckets(bf, 4, k_min=2)
    assert slabs.n_loc == 12 and slabs.p == p
    all_feats = np.sort(slabs.feat_order)
    np.testing.assert_array_equal(all_feats, np.arange(p))
    ks = slabs.k_classes
    assert list(ks) == sorted(ks)
    k_global = max(int((np.asarray(bf.row_idx[j]) < n).sum()) for j in range(p))
    for r_b, v_b, fid in slabs.buckets:
        kb = r_b.shape[2]
        assert kb <= 12 and (kb & (kb - 1) == 0 or kb == ks[-1])
    # storage actually shrinks vs the single global capacity
    single_cells = p * 4 * max(ks)
    bucket_cells = sum(b[0].shape[0] * 4 * b[0].shape[2] for b in slabs.buckets)
    assert bucket_cells < single_cells
    dense = np.zeros((n, p), np.float32)
    for r_b, v_b, fid in slabs.buckets:
        ri, vv = np.asarray(r_b), np.asarray(v_b)
        for bj, j in enumerate(np.asarray(fid)):
            for s in range(4):
                live = ri[bj, s] < slabs.n_loc
                dense[s * slabs.n_loc + ri[bj, s][live], j] = vv[bj, s][live]
    np.testing.assert_allclose(dense, X, atol=0)


def test_k_class_ladder():
    from repro.data.byfeature import k_class

    assert k_class(0, 100) == 8
    assert k_class(8, 100) == 8
    assert k_class(9, 100) == 16
    assert k_class(17, 100) == 32
    assert k_class(90, 100) == 100      # capped at the global max
    assert k_class(3, 5, k_min=2) == 4
    assert k_class(2, 5, k_min=2) == 2


def test_gather_features_k_cap_trim():
    """k_cap trimming relies on front-packed entries: the trimmed gather
    must equal the full gather whenever k_cap covers the active features'
    nnz, and pad with sentinels when k_cap exceeds the stored K."""
    import jax.numpy as jnp

    from repro.data.byfeature import gather_features

    X = _rand_sparse(n=16, p=12, seed=6)
    bf = to_by_feature(X)
    k = bf.row_idx.shape[1]
    beta = jnp.zeros(12)
    mask = jnp.asarray([True] + [False] * 11)
    nnz0 = int((np.asarray(bf.row_idx[0]) < 16).sum())
    full = gather_features(bf.row_idx, bf.values, beta, mask, cap=4,
                           sentinel=bf.n)
    trim = gather_features(bf.row_idx, bf.values, beta, mask, cap=4,
                           sentinel=bf.n, k_cap=nnz0)
    assert trim[0].shape == (4, nnz0)
    np.testing.assert_array_equal(np.asarray(trim[0]),
                                  np.asarray(full[0][:, :nnz0]))
    np.testing.assert_allclose(np.asarray(trim[1]),
                               np.asarray(full[1][:, :nnz0]))
    grow = gather_features(bf.row_idx, bf.values, beta, mask, cap=4,
                           sentinel=bf.n, k_cap=k + 3)
    assert grow[0].shape == (4, k + 3)
    assert np.all(np.asarray(grow[0][:, k:]) == bf.n)
    assert np.all(np.asarray(grow[1][:, k:]) == 0.0)


def test_gather_features_buckets_matches_flat_gather():
    """The per-bucket gather-and-combine equals gathering from the
    equivalent single-capacity slab layout."""
    import jax.numpy as jnp

    from repro.data.byfeature import (
        SlabBuckets, gather_features, gather_features_buckets,
        to_slab_buckets, to_slabs,
    )

    X = _rand_sparse(n=24, p=10, seed=7)
    bf = to_by_feature(X)
    slabs = to_slab_buckets(bf, 2, k_min=2)
    row_idx, values, n_loc = to_slabs(bf, 2)
    k = row_idx.shape[2]
    # flat layout permuted into bucket order = the buckets' view
    perm = slabs.feat_order
    rows_flat = jnp.asarray(np.asarray(row_idx)[perm])
    vals_flat = jnp.asarray(np.asarray(values)[perm])
    rng = np.random.default_rng(0)
    mask = jnp.asarray(rng.random(10) < 0.5)
    beta = jnp.asarray(rng.standard_normal(10), dtype=jnp.float32)
    rb, vb, bb, idxb = gather_features_buckets(slabs, beta, mask, cap=8,
                                               k_cap=k)
    rf, vf, bf_sub, idxf = gather_features(rows_flat, vals_flat, beta, mask,
                                           cap=8, sentinel=n_loc, k_cap=k)
    np.testing.assert_array_equal(np.asarray(idxb), np.asarray(idxf))
    np.testing.assert_array_equal(np.asarray(rb), np.asarray(rf))
    np.testing.assert_allclose(np.asarray(vb), np.asarray(vf))
    np.testing.assert_allclose(np.asarray(bb), np.asarray(bf_sub))


def test_partition_features_covers_all():
    parts = partition_features(103, 16)
    allidx = np.concatenate(parts)
    assert len(allidx) == 103
    assert len(np.unique(allidx)) == 103


def test_synthetic_twin_density():
    ds = make_glm_dataset(twin(GLM_WEBSPAM, scale=0.002), jax.random.key(0))
    X = np.asarray(ds.X_train)
    density = (X != 0).mean()
    assert density < 0.01  # webspam twin is very sparse
    assert set(np.unique(np.asarray(ds.y_train))) <= {-1.0, 1.0}


def test_synthetic_learnable():
    """Bayes-ish: the true beta scores the test set well above chance."""
    cfg = GLMConfig(name="t", num_examples=2048, num_features=64, density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(1))
    from repro.train.metrics import auprc

    ap = auprc(ds.X_test @ ds.beta_true, ds.y_test)
    base = float((np.asarray(ds.y_test) > 0).mean())
    assert ap > base + 0.2


def test_zipf_corpus_and_batches():
    rng = np.random.default_rng(0)
    corpus = zipf_corpus(rng, 1000, 10_000)
    assert corpus.min() >= 0 and corpus.max() < 1000
    it = batches(corpus, 4, 16, rng=rng)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))
