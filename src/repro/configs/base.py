"""Model / run configuration dataclasses.

Every assigned architecture gets one ``<arch>.py`` file in this package that
instantiates :class:`ModelConfig` with the exact dimensions from the
assignment table (source citation in ``citation``). Reduced smoke variants
(for CPU tests) are derived mechanically via :meth:`ModelConfig.smoke`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture type tags (mirror the assignment table)
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"
GLM = "glm"  # the paper's own workload: L1-regularized logistic regression

ARCH_TYPES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO, GLM)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (GShard/Mixtral-style capacity routing)."""

    num_experts: int = 0
    top_k: int = 1
    num_shared_experts: int = 0      # DeepSeek-style always-on shared expert(s)
    expert_d_ff: int = 0             # per-expert hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01    # load-balance loss
    router_z_loss_weight: float = 1e-3

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD sub-config (arXiv:2405.21060)."""

    d_state: int = 128
    head_dim: int = 64               # SSD "P"
    expand: int = 2                  # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256            # SSD chunked scan length
    ngroups: int = 1                 # B/C groups (GVA-style)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False           # Qwen-style
    rope_theta: float = 10000.0
    use_mrope: bool = False          # Qwen2-VL M-RoPE (3 rotary sections)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    sliding_window: int = 0          # 0 -> full attention
    # MLA (DeepSeek-V3, arXiv:2412.19437)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    def resolved_head_dim(self, d_model: int) -> int:
        if self.head_dim:
            return self.head_dim
        return d_model // max(self.num_heads, 1)


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: mostly-Mamba2 stack with a *shared* attention
    block applied at a fixed period (arXiv:2411.15242)."""

    attn_every: int = 6              # apply shared attention block each k layers
    shared_attn: bool = True         # one set of attention weights, reused


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (seamless-m4t, arXiv:2308.11596). ``num_layers`` in the
    parent config is the per-stack depth (12 -> 12 enc + 12 dec)."""

    enabled: bool = False
    encoder_seq_len: int = 4096      # frame-embedding memory length (stubbed frontend)


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend carve-out: input_specs() provides precomputed
    patch/frame embeddings of this shape instead of raw pixels/waveform."""

    kind: str = "none"               # none | vision_patches | audio_frames
    tokens_per_item: int = 0         # e.g. ViT patches per image / frames per utterance
    embed_dim: int = 0               # frontend output dim (projector maps -> d_model)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "unnamed"
    arch_type: str = DENSE
    citation: str = ""

    num_layers: int = 0
    d_model: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    max_seq_len: int = 532_480

    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: Optional[HybridConfig] = None
    encdec: EncDecConfig = field(default_factory=EncDecConfig)
    frontend: FrontendStub = field(default_factory=FrontendStub)

    first_dense_layers: int = 0      # MoE archs: leading layers with dense MLP
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    norm_eps: float = 1e-5
    act: str = "silu"                # silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    mtp_depth: int = 0               # DeepSeek-V3 multi-token prediction heads

    # numerics / memory policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    optimizer: str = "adamw"         # adamw | adafactor | sgd
    microbatch: int = 1              # gradient-accumulation steps (train)

    # long-context policy (see DESIGN.md §2.5)
    long_context_mode: str = "sliding_window"   # native | sliding_window | skip
    long_context_window: int = 8192

    # sharding fallbacks resolved by repro.sharding.rules
    vocab_pad_to: int = 256

    # ----- derived -----------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def is_encdec(self) -> bool:
        return self.encdec.enabled

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn' | 'moe' | 'ssm' | 'hybrid_attn'."""
        if self.arch_type == SSM:
            return tuple("ssm" for _ in range(self.num_layers))
        if self.arch_type == HYBRID and self.hybrid is not None:
            k = self.hybrid.attn_every
            return tuple(
                "hybrid_attn" if (i % k) == (k - 1) else "ssm"
                for i in range(self.num_layers)
            )
        if self.moe.enabled:
            nd = self.first_dense_layers
            return tuple(
                "attn" if i < nd else "moe" for i in range(self.num_layers)
            )
        return tuple("attn" for _ in range(self.num_layers))

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs and memory
        sanity checks; exact for our implementation, including biases)."""
        from repro.models.params import count_params_analytic

        return count_params_analytic(self)

    def num_active_params(self) -> int:
        from repro.models.params import count_params_analytic

        return count_params_analytic(self, active_only=True)

    # ----- reduced variants ---------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts.

        Used by per-arch CPU smoke tests; preserves every structural feature
        (GQA ratio, MLA, MoE routing, SSD, hybrid pattern, enc-dec, biases).
        """
        d_model = min(self.d_model, 256)
        attn = self.attention
        if attn.num_heads:
            heads = min(attn.num_heads, 4)
            ratio = max(1, attn.num_heads // max(attn.num_kv_heads, 1))
            kv = max(1, heads // ratio)
            smoke_dh = 64 if attn.head_dim else 0
            half = (smoke_dh or (d_model // heads)) // 2
            sections = (half // 4, (3 * half) // 8, half - half // 4 - (3 * half) // 8)
            attn = replace(
                attn,
                num_heads=heads,
                num_kv_heads=kv,
                head_dim=smoke_dh,
                mrope_sections=sections if attn.use_mrope else attn.mrope_sections,
                q_lora_rank=min(attn.q_lora_rank, 64) if attn.q_lora_rank else 0,
                kv_lora_rank=min(attn.kv_lora_rank, 32) if attn.kv_lora_rank else 0,
                qk_rope_head_dim=min(attn.qk_rope_head_dim, 16) if attn.use_mla else attn.qk_rope_head_dim,
                qk_nope_head_dim=min(attn.qk_nope_head_dim, 32) if attn.use_mla else attn.qk_nope_head_dim,
                v_head_dim=min(attn.v_head_dim, 32) if attn.use_mla else attn.v_head_dim,
                sliding_window=min(attn.sliding_window, 64) if attn.sliding_window else 0,
            )
        moe = self.moe
        if moe.enabled:
            moe = replace(
                moe,
                num_experts=min(moe.num_experts, 4),
                top_k=min(moe.top_k, 2),
                num_shared_experts=min(moe.num_shared_experts, 1),
                expert_d_ff=min(moe.expert_d_ff or 128, 128),
            )
        ssm = replace(self.ssm, d_state=min(self.ssm.d_state, 16),
                      head_dim=min(self.ssm.head_dim, 32), chunk_size=32)
        hybrid = self.hybrid
        nl = min(self.num_layers, 2)
        if hybrid is not None:
            hybrid = replace(hybrid, attn_every=2)
        frontend = self.frontend
        if frontend.kind != "none":
            frontend = replace(frontend, tokens_per_item=min(frontend.tokens_per_item, 16),
                               embed_dim=min(frontend.embed_dim or 128, 128))
        return replace(
            self,
            name=self.name + "-smoke",
            num_layers=nl,
            d_model=d_model,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            attention=attn,
            moe=moe,
            ssm=ssm,
            hybrid=hybrid,
            frontend=frontend,
            first_dense_layers=min(self.first_dense_layers, nl - 1),
            max_seq_len=4096,
            long_context_window=64,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            mtp_depth=min(self.mtp_depth, 1),
        )


# ---------------------------------------------------------------------------
# GLM (paper workload) config
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GLMConfig:
    """The paper's own problem: L1-regularized logistic regression.

    A synthetic twin of each Table-2 dataset; dims match the paper where a
    CPU-scale twin makes sense, and the dry-run uses the full dims.
    """

    name: str = "glm"
    arch_type: str = GLM
    citation: str = "Trofimov & Genkin 2014, Table 2"
    num_examples: int = 0
    num_features: int = 0
    avg_nnz_per_example: int = 0     # density hint for synthetic twin
    density: float = 1.0             # fraction of nonzero entries
    lam_path_len: int = 20           # Algorithm 5: lambda_max * 2^{-i}

    # tiling for the Gram-CD solver
    feature_tile: int = 256

    def smoke(self) -> "GLMConfig":
        return replace(self, name=self.name + "-smoke",
                       num_examples=min(self.num_examples, 2048),
                       num_features=min(self.num_features, 128),
                       lam_path_len=4, feature_tile=32)


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
