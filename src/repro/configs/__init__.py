"""Config registry: ``get_config("<arch-id>")`` for every assigned arch.

Arch ids match the assignment table verbatim (dashes/dots); module names are
the pythonized versions.
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ARCH_TYPES,
    AttentionConfig,
    EncDecConfig,
    FrontendStub,
    GLMConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.configs.shapes import SHAPES, InputShape, get_shape  # noqa: F401

from repro.configs.qwen2_5_3b import CONFIG as _qwen2_5_3b
from repro.configs.mamba2_2p7b import CONFIG as _mamba2_2p7b
from repro.configs.zamba2_7b import CONFIG as _zamba2_7b
from repro.configs.qwen1_5_4b import CONFIG as _qwen1_5_4b
from repro.configs.internlm2_1p8b import CONFIG as _internlm2_1p8b
from repro.configs.tinyllama_1p1b import CONFIG as _tinyllama_1p1b
from repro.configs.deepseek_v3_671b import CONFIG as _deepseek_v3_671b
from repro.configs.qwen2_vl_72b import CONFIG as _qwen2_vl_72b
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4_scout
from repro.configs.seamless_m4t_medium import CONFIG as _seamless_m4t
from repro.configs.glm import GLM_CONFIGS

MODEL_CONFIGS = {
    c.name: c
    for c in (
        _qwen2_5_3b,
        _mamba2_2p7b,
        _zamba2_7b,
        _qwen1_5_4b,
        _internlm2_1p8b,
        _tinyllama_1p1b,
        _deepseek_v3_671b,
        _qwen2_vl_72b,
        _llama4_scout,
        _seamless_m4t,
    )
}

ALL_CONFIGS = {**MODEL_CONFIGS, **GLM_CONFIGS}

ARCH_IDS = tuple(MODEL_CONFIGS)
GLM_IDS = tuple(GLM_CONFIGS)


def get_config(name: str):
    """Look up any registered config (model arch or GLM workload)."""
    try:
        return ALL_CONFIGS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; have {sorted(ALL_CONFIGS)}"
        ) from None
