"""Synthetic sparse classification data — twins of the paper's Table 2
datasets (epsilon / webspam / dna) at configurable scale.

Generation: a sparse ground-truth beta* with ``k_true`` informative
features; X with the target density (dense Gaussian for epsilon-like,
Bernoulli-masked for sparse sets); labels sampled from the logistic model
with controllable noise. Returns train/test splits like the paper's
protocol (AUPRC is evaluated on the held-out split).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GLMConfig


@dataclass
class GLMDataset:
    X_train: jnp.ndarray
    y_train: jnp.ndarray
    X_test: jnp.ndarray
    y_test: jnp.ndarray
    beta_true: jnp.ndarray
    name: str = "synthetic"

    @property
    def nnz(self) -> int:
        return int(jnp.sum(self.X_train != 0) + jnp.sum(self.X_test != 0))


def make_glm_dataset(
    cfg: GLMConfig,
    key,
    *,
    test_frac: float = 0.2,
    k_true: int = 0,
    label_noise: float = 0.05,
    snr: float = 3.0,
) -> GLMDataset:
    n, p = cfg.num_examples, cfg.num_features
    k_true = k_true or max(4, p // 20)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)

    X = jax.random.normal(k1, (n, p), jnp.float32)
    if cfg.density < 1.0:
        mask = jax.random.bernoulli(k2, cfg.density, (n, p))
        X = jnp.where(mask, X, 0.0)

    beta_true = jnp.zeros(p, jnp.float32)
    idx = jax.random.choice(k3, p, (k_true,), replace=False)
    vals = jax.random.normal(k4, (k_true,)) * snr / jnp.sqrt(k_true * max(cfg.density, 1e-6))
    beta_true = beta_true.at[idx].set(vals)

    logits = X @ beta_true
    prob = jax.nn.sigmoid(logits)
    u = jax.random.uniform(k5, (n,))
    y = jnp.where(u < prob, 1.0, -1.0)
    if label_noise:
        flip = jax.random.bernoulli(jax.random.fold_in(k5, 1), label_noise, (n,))
        y = jnp.where(flip, -y, y)

    n_test = int(n * test_frac)
    return GLMDataset(
        X_train=X[n_test:], y_train=y[n_test:],
        X_test=X[:n_test], y_test=y[:n_test],
        beta_true=beta_true, name=cfg.name,
    )
