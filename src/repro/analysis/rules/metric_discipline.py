"""metric-discipline: ad-hoc timing/counters in src/repro outside repro.obs.

``repro.obs`` is the single observability surface: spans own wall-time
attribution (closed at existing sync points), the registry owns counters,
and legacy stat dicts are mirrored onto it through ``register_metrics``
adapters. A raw ``time.perf_counter()`` pair or a hand-rolled counter
dict added anywhere else in the library starts a parallel telemetry
channel the trace summaries, the report CLI and the chaos assertions
never see — exactly the drift this subsystem was built to end.

Two findings, both scoped to ``src/repro/`` outside ``src/repro/obs/``
(benchmarks and launchers time things for a living; launcher offenders
that predate the subsystem are carried in ``analysis-allowlist.toml``):

* a call to a wall clock (``time.perf_counter`` / ``time.monotonic`` /
  ``time.time``) — wrap the region in an ``obs.trace.span`` instead, or
  justify with ``allow[metric-discipline]: why`` (e.g. the value is a
  deadline fed to a clock-injectable API, not a measurement);
* an ``x += ...`` onto a stats/counter-named target — route through
  ``obs.registry.counter(...)`` instead. Increments lexically inside a
  class that defines ``register_metrics`` are exempt: that's the
  sanctioned legacy-adapter shape (the dict stays the bit-for-bit source
  of truth and the registry mirrors it read-only).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "metric-discipline"
DOC = ("raw wall-clock call or ad-hoc counter increment outside repro.obs "
       "— use obs.trace spans / obs.registry counters (or a "
       "register_metrics adapter for legacy stat dicts)")

#: the observability home; everything under it is the implementation
_HOME = "src/repro/obs/"

_CLOCKS = ("time.perf_counter", "perf_counter", "time.monotonic",
           "monotonic", "time.time")

#: substrings (of the full dotted target) that mark a counter-ish store;
#: deliberately NOT bare "count" — loop counters are not telemetry
_COUNTERISH = ("stats", "counter", "metric", "telemetry")


def _target_chain(node: ast.AST) -> Optional[str]:
    """Dotted identifier chain of an AugAssign target: ``self._stats``
    for ``self._stats["drained"] += n``; None for non-name targets."""
    if isinstance(node, ast.Subscript):
        return _target_chain(node.value)
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    parts.append(cur.id)
    return ".".join(reversed(parts))


def _adapter_exempt_nodes(mod: ModuleInfo) -> Set[ast.AST]:
    """AST nodes inside classes that define ``register_metrics`` — the
    legacy-counter adapter shape this rule sanctions."""
    exempt: Set[ast.AST] = set()
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        has_adapter = any(
            isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
            and fn.name == "register_metrics"
            for fn in node.body)
        if has_adapter:
            exempt.update(ast.walk(node))
    return exempt


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not mod.path.startswith("src/repro/"):
            continue
        if mod.path.startswith(_HOME):
            continue
        exempt = _adapter_exempt_nodes(mod)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and mod.qualname(node.func) \
                    in _CLOCKS:
                out.append(Finding(
                    file=mod.path, line=node.lineno, rule=RULE_ID,
                    message=(
                        f"raw {mod.qualname(node.func)}() call outside "
                        f"repro.obs — wrap the region in an obs.trace "
                        f"span so the time lands in the trace summaries "
                        f"(or allow[{RULE_ID}] with why this is not a "
                        f"measurement)"),
                ))
            elif isinstance(node, ast.AugAssign) and node not in exempt:
                chain = _target_chain(node.target)
                if chain and any(w in chain.lower() for w in _COUNTERISH):
                    out.append(Finding(
                        file=mod.path, line=node.lineno, rule=RULE_ID,
                        message=(
                            f"ad-hoc counter increment on {chain} outside "
                            f"repro.obs — use obs.registry.counter(...) "
                            f"or mirror the legacy dict through a "
                            f"register_metrics adapter (or "
                            f"allow[{RULE_ID}] stating why)"),
                    ))
    return out
