"""Tier-1 wiring of the runtime sanitizers (``repro.analysis.sanitize``).

Three contracts get teeth here:

* the engine: one solve is ONE sanctioned host transfer
  (``repro.core.engine.device_get``) — anything else that materializes a
  device value raises;
* the screened path: every device->host crossing in the driver (active
  and violation counts, per-point telemetry) goes through the same
  audited door, so a whole ``LogisticL1.path`` runs under the sanitizer;
* warm code never recompiles: ``compile_sanitizer(0)`` certifies the
  zero-retrace property of the warm-started path (>= 10 lambdas) and of
  the serve scorer's repeat dispatch;
* observability is free: running the same warm path under
  ``repro.obs.observe()`` changes neither the counted-fetch total nor
  the compile count — spans timestamp at existing sync points, they
  never add a device->host transfer or an XLA compile.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.sanitize import (
    CompileBudgetExceeded,
    FetchBudgetExceeded,
    HostTransferError,
    compile_sanitizer,
    transfer_sanitizer,
)
from repro.api import DenseDesign, LogisticL1
from repro.core import engine
from repro.core.dglmnet import DGLMNETOptions

_OPTS = dict(num_blocks=4, tile=8, max_iters=10)
_PATH_LEN = 12            # acceptance: zero retraces across >= 10 lambdas


@pytest.fixture(scope="module")
def problem():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(120, 40)), jnp.float32)
    beta = np.zeros(40)
    beta[:6] = rng.normal(size=6) * 2.0
    probs = 1.0 / (1.0 + np.exp(-(np.asarray(X) @ beta)))
    y = jnp.asarray((rng.random(120) < probs).astype(np.float32))
    return X, y


@pytest.fixture(scope="module")
def warm_path(problem):
    """Cold leg: pays every compile once so the certificate tests below
    measure only the warm behavior."""
    X, y = problem
    est = LogisticL1(opts=DGLMNETOptions(**_OPTS))
    return est.path(DenseDesign(X), y, path_len=_PATH_LEN)


# ---------------------------------------------------------------------------
# transfer sanitizer
# ---------------------------------------------------------------------------

def test_fit_is_one_sanctioned_fetch(problem):
    X, y = problem
    est = LogisticL1(opts=DGLMNETOptions(**_OPTS))
    with transfer_sanitizer(max_fetches=1) as ts:
        res = est.fit(DenseDesign(X), y, lam=0.05)
    assert ts.fetches == 1
    assert res.beta.shape == (40,) and res.n_iters >= 1


def test_screened_path_is_fully_audited(problem, warm_path):
    # the whole driver (screen counts, KKT rounds, per-point telemetry)
    # crosses to host only through the engine door, each crossing counted
    X, y = problem
    est = LogisticL1(opts=DGLMNETOptions(**_OPTS))
    with transfer_sanitizer(max_fetches=400) as ts:
        path = est.path(DenseDesign(X), y, path_len=_PATH_LEN)
    assert len(path) == _PATH_LEN
    assert _PATH_LEN <= ts.fetches <= 400


def test_unsanctioned_materialization_trips(problem):
    x = jnp.ones(4)
    with pytest.raises(HostTransferError):
        with transfer_sanitizer():
            jnp.sum(x).item()
    with pytest.raises(HostTransferError):
        with transfer_sanitizer():
            float(jnp.sum(x))


def test_fetch_budget_exceeded():
    a, b = jnp.ones(3), jnp.ones(3)
    with pytest.raises(FetchBudgetExceeded):
        with transfer_sanitizer(max_fetches=1):
            # allow[nonfinite-guard]: counts the transfers themselves; operands are literal ones, not served output
            engine.device_get(a)
            engine.device_get(b)


def test_transfer_sanitizer_restores_patches():
    x = jnp.ones(())
    with transfer_sanitizer():
        pass
    assert float(x) == 1.0 and x.item() == 1.0


# ---------------------------------------------------------------------------
# compile sanitizer
# ---------------------------------------------------------------------------

def test_zero_retrace_certificate_across_warm_path(problem, warm_path):
    X, y = problem
    est = LogisticL1(opts=DGLMNETOptions(**_OPTS))
    with compile_sanitizer(0) as cs:
        path = est.path(DenseDesign(X), y, path_len=_PATH_LEN)
    assert cs.count == 0, cs.compiles
    assert len(path) >= 10
    assert np.allclose(np.asarray(path.betas), np.asarray(warm_path.betas))


def test_compile_budget_trips_on_shape_change():
    @jax.jit
    def g(v):
        return v * 2.0

    a, b = jnp.ones(8), jnp.ones(9)   # made BEFORE arming the counter
    g(a)                              # warm the first shape
    with compile_sanitizer(0):
        g(a)                          # warm call: no compile
    with pytest.raises(CompileBudgetExceeded, match=r"jit\(g\)"):
        with compile_sanitizer(0):
            g(b)                      # new shape: retrace + recompile


def test_traced_path_same_counted_fetches_as_untraced(problem, warm_path):
    # the obs acceptance contract: tracing wraps EXISTING sync points, so
    # the audited device->host crossing count is identical with and
    # without an active tracer — and so are the coefficients
    from repro.obs import observe

    X, y = problem
    est = LogisticL1(opts=DGLMNETOptions(**_OPTS))
    with transfer_sanitizer(max_fetches=400) as ts_off:
        path_off = est.path(DenseDesign(X), y, path_len=_PATH_LEN)
    with observe() as obs:
        with transfer_sanitizer(max_fetches=400) as ts_on:
            path_on = est.path(DenseDesign(X), y, path_len=_PATH_LEN)
    assert ts_on.fetches == ts_off.fetches
    assert np.array_equal(np.asarray(path_on.betas),
                          np.asarray(path_off.betas))
    # and the trace actually recorded the path (it is not a null tracer)
    assert any(r["name"] == "lambda_point" for r in obs.tracer.spans)


def test_traced_path_adds_zero_compiles(problem, warm_path):
    from repro.obs import observe

    X, y = problem
    est = LogisticL1(opts=DGLMNETOptions(**_OPTS))
    with observe():
        with compile_sanitizer(0) as cs:
            path = est.path(DenseDesign(X), y, path_len=_PATH_LEN)
    assert cs.count == 0, cs.compiles
    assert np.allclose(np.asarray(path.betas), np.asarray(warm_path.betas))


def test_serve_scorer_warm_dispatch_never_recompiles(warm_path):
    from repro.serve import PathScorer, PathStore, RequestBatcher

    store = PathStore(warm_path)
    scorer = PathScorer(store)
    batcher = RequestBatcher(store.snapshot.p, max_batch=16,
                             pad_p_to=store.pad_p_to)
    rng = np.random.default_rng(1)
    for i in range(16):
        req = {f"tok{int(t)}": float(v) for t, v in zip(
            rng.integers(0, 160, size=4), rng.normal(size=4))}
        batcher.submit(req, float(warm_path.lambdas[i % len(warm_path)]))
    batch, lams = batcher.drain()
    scorer.score(batch, lams)         # warm the scoring program
    with compile_sanitizer(0) as cs:
        s1, v1 = scorer.score(batch, lams)
        s2, v2 = scorer.score(batch, lams)
    assert cs.count == 0, cs.compiles
    assert v1 == v2 and np.array_equal(s1, s2) and len(s1) == batch.n_live
