"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    citation="arXiv:2403.17297 (InternLM2)",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92544,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000.0,
    ),
    norm="rmsnorm",
    act="silu",
    optimizer="adamw",
    long_context_mode="sliding_window",
)
