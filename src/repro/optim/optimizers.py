"""Hand-rolled optimizers (no optax in this environment — substrate built
from scratch per the assignment).

API (optax-like):
    opt = adamw(...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)
    params = apply_updates(params, updates)

Adafactor exists because AdamW state for the ≥70B configs does not fit
16 GB/chip v5e HBM even fully sharded (see EXPERIMENTS §Dry-run): factored
second moments cost O(rows+cols) instead of O(rows*cols).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def sgd(momentum: float = 0.9, weight_decay: float = 0.0, state_dtype=jnp.float32):
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params)}

    def update(grads, state, params, lr):
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(state_dtype), state["mu"], grads
        )
        upd = jax.tree.map(
            lambda m, p: -lr * (m + weight_decay * p.astype(state_dtype)), mu, params
        )
        return upd, {"mu": mu}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    state_dtype=jnp.float32,
):
    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        c = state["count"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(state_dtype), state["m"], grads
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(state_dtype)),
            state["v"],
            grads,
        )
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def u(m_, v_, p):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(state_dtype))

        upd = jax.tree.map(u, m, v, params)
        return upd, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------

def adafactor(
    decay: float = 0.99,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
):
    """Shazeer & Stern (2018), simplified: factored for >=2D leaves over the
    last two dims; full accumulator for 0/1-D leaves."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),       # row accum
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "acc": jax.tree.map(per_leaf, params, is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        def per_leaf(g, acc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(g.shape):
                vr = decay * acc["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * acc["vc"] + (1 - decay) * g2.mean(axis=-2)
                denom = (
                    vr[..., :, None]
                    * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1)[..., None, None], eps)
                )
                upd = g * jax.lax.rsqrt(denom + eps)
                new_acc = {"vr": vr, "vc": vc}
            else:
                v = decay * acc["v"] + (1 - decay) * g2
                upd = g * jax.lax.rsqrt(v + eps)
                new_acc = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            upd = -lr * (upd + weight_decay * p.astype(jnp.float32))
            return upd, new_acc

        flat_u, flat_acc = [], []
        g_leaves, treedef = jax.tree.flatten(grads)
        acc_leaves = treedef.flatten_up_to(state["acc"])
        p_leaves = jax.tree.leaves(params)
        for g, a, p in zip(g_leaves, acc_leaves, p_leaves):
            u_, a_ = per_leaf(g, a, p)
            flat_u.append(u_)
            flat_acc.append(a_)
        return (
            jax.tree.unflatten(treedef, flat_u),
            {"acc": jax.tree.unflatten(treedef, flat_acc), "count": state["count"] + 1},
        )

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(**kw)
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(f"unknown optimizer {name!r}")
