"""Thread-safe labeled metrics: Counter / Gauge / Histogram + registry.

Stdlib-only by design — the registry is imported by `repro.resilience`
(which must stay importable without JAX) and by the analysis lint's
golden fixtures, so it must never pull in the numeric stack.

Two access modes:

* **Injectable instance**: construct a `MetricsRegistry` and pass it
  around (or activate it with `use_registry`). This is what `observe()`
  does.
* **Process-global helpers**: `counter(name)`, `gauge(name)`,
  `histogram(name)` resolve against the currently active registry. When
  none is active they return shared *null* instruments whose methods are
  no-ops — instrumented hot paths pay two attribute loads and a
  comparison, nothing else.

Legacy counter dicts (`batcher.stats`, `ResidencyCounters`) are mirrored
through `register_callback(name, fn)`: the callback is invoked lazily at
`collect()` time, so the legacy dict remains the single source of truth
and its values stay bit-identical to pre-obs behavior.
"""
from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from typing import Callable, Dict, Iterator, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "counter",
    "gauge",
    "get_registry",
    "histogram",
    "use_registry",
]


class Counter:
    """Monotonic counter. `inc` is atomic under the instrument lock."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins scalar (queue depth, resident bytes, ...)."""

    __slots__ = ("name", "labels", "_lock", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


# Log-spaced bucket edges: 1 microsecond .. ~67 seconds, factor 2 per
# bucket. Sub-microsecond observations land in the underflow bucket,
# >67s in the overflow bucket; min/max are tracked exactly so the
# percentile interpolation clamps to the true range.
_EDGES: Tuple[float, ...] = tuple(1e-6 * (2.0 ** i) for i in range(27))


class Histogram:
    """Log-bucketed histogram with interpolated percentiles.

    Tuned for latency-style values in seconds; arbitrary non-negative
    floats work (negative observations clamp into the underflow bucket).
    """

    __slots__ = ("name", "labels", "_lock", "_counts", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._lock = threading.Lock()
        # counts[i] counts observations in [_EDGES[i-1], _EDGES[i]);
        # counts[0] is the underflow bucket, counts[-1] the overflow one.
        self._counts = [0] * (len(_EDGES) + 1)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            self._counts[self._bucket(v)] += 1

    @staticmethod
    def _bucket(v: float) -> int:
        if v < _EDGES[0]:
            return 0
        if v >= _EDGES[-1]:
            return len(_EDGES)
        # log2 search beats bisect for a fixed geometric grid
        i = int(math.log2(v / _EDGES[0])) + 1
        # float fuzz at bucket boundaries: nudge into the right bin
        while i > 0 and v < _EDGES[i - 1]:
            i -= 1
        while i < len(_EDGES) and v >= _EDGES[i]:
            i += 1
        return i

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> Optional[float]:
        """Interpolated q-th percentile (q in [0, 100]); None when empty."""
        with self._lock:
            if self._count == 0:
                return None
            target = (q / 100.0) * self._count
            seen = 0
            for i, c in enumerate(self._counts):
                if c == 0:
                    continue
                if seen + c >= target:
                    lo = self._min if i == 0 else _EDGES[i - 1]
                    hi = self._max if i == len(_EDGES) else _EDGES[i]
                    lo = max(lo, self._min)
                    hi = min(hi, self._max)
                    if hi <= lo:
                        return lo
                    frac = (target - seen) / c
                    return lo + frac * (hi - lo)
                seen += c
            return self._max

    def snapshot(self) -> dict:
        """JSON-safe summary (None percentiles when empty, never NaN)."""
        with self._lock:
            count, total = self._count, self._sum
            vmin = self._min if count else None
            vmax = self._max if count else None
        return {
            "count": count,
            "sum": total,
            "min": vmin,
            "max": vmax,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


class _NullCounter:
    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    @property
    def value(self) -> int:
        return 0


class _NullGauge:
    __slots__ = ()

    def set(self, v: float) -> None:
        pass

    @property
    def value(self) -> float:
        return 0.0


class _NullHistogram:
    __slots__ = ()

    def observe(self, v: float) -> None:
        pass

    @property
    def count(self) -> int:
        return 0

    @property
    def sum(self) -> float:
        return 0.0

    def percentile(self, q: float) -> Optional[float]:
        return None

    def snapshot(self) -> dict:
        return {"count": 0, "sum": 0.0, "min": None, "max": None,
                "p50": None, "p95": None, "p99": None}


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()


def _key(name: str, labels: Dict[str, object]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create instrument store keyed by name + sorted labels."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._callbacks: Dict[str, Callable[[], dict]] = {}

    def counter(self, name: str, **labels: object) -> Counter:
        key = _key(name, labels)
        with self._lock:
            inst = self._counters.get(key)
            if inst is None:
                inst = Counter(name, tuple(sorted(
                    (k, str(v)) for k, v in labels.items())))
                self._counters[key] = inst
            return inst

    def gauge(self, name: str, **labels: object) -> Gauge:
        key = _key(name, labels)
        with self._lock:
            inst = self._gauges.get(key)
            if inst is None:
                inst = Gauge(name, tuple(sorted(
                    (k, str(v)) for k, v in labels.items())))
                self._gauges[key] = inst
            return inst

    def histogram(self, name: str, **labels: object) -> Histogram:
        key = _key(name, labels)
        with self._lock:
            inst = self._histograms.get(key)
            if inst is None:
                inst = Histogram(name, tuple(sorted(
                    (k, str(v)) for k, v in labels.items())))
                self._histograms[key] = inst
            return inst

    def register_callback(self, name: str, fn: Callable[[], dict]) -> None:
        """Mirror an external counter surface (a legacy stats dict) onto
        the registry. `fn` is called lazily at `collect()` — the legacy
        structure stays the source of truth, bit-for-bit."""
        with self._lock:
            self._callbacks[name] = fn

    def value(self, name: str, **labels: object) -> Optional[int]:
        """Current value of a counter, or None if it was never created
        (useful for assertions that a code path did NOT fire)."""
        key = _key(name, labels)
        with self._lock:
            inst = self._counters.get(key)
        return None if inst is None else inst.value

    def collect(self) -> dict:
        """One JSON-safe snapshot of every instrument + callback."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
            callbacks = dict(self._callbacks)
        out = {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(histograms.items())},
            "callbacks": {},
        }
        for name, fn in sorted(callbacks.items()):
            try:
                out["callbacks"][name] = dict(fn())
            except Exception as err:  # a dead callback must not kill collect
                out["callbacks"][name] = {"error": repr(err)}
        return out


_ACTIVE: Optional[MetricsRegistry] = None
_ACTIVE_LOCK = threading.Lock()


def get_registry() -> Optional[MetricsRegistry]:
    return _ACTIVE


def counter(name: str, **labels: object):
    reg = _ACTIVE
    return _NULL_COUNTER if reg is None else reg.counter(name, **labels)


def gauge(name: str, **labels: object):
    reg = _ACTIVE
    return _NULL_GAUGE if reg is None else reg.gauge(name, **labels)


def histogram(name: str, **labels: object):
    reg = _ACTIVE
    return _NULL_HISTOGRAM if reg is None else reg.histogram(name, **labels)


@contextmanager
def use_registry(registry: Optional[MetricsRegistry]) -> Iterator[None]:
    """Activate `registry` for the enclosed block (re-entrant: the prior
    active registry is restored on exit). Pass None to force-disable."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        prev, _ACTIVE = _ACTIVE, registry
    try:
        yield
    finally:
        with _ACTIVE_LOCK:
            _ACTIVE = prev
