"""Kernel micro-benchmarks (interpret-mode on CPU: correctness-surface
timing only; TPU wall-times come from the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.subproblem import cd_cycle_gram_tile
from repro.kernels.ref import logistic_stats_ref


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run():
    key = jax.random.key(0)
    for f in (128, 256, 512):
        A = jax.random.normal(key, (2 * f, f))
        G = A.T @ A / f
        c = jax.random.normal(key, (f,))
        beta = jnp.zeros(f)
        jitted = jax.jit(lambda G, c, b: cd_cycle_gram_tile(G, c, b, b * 0, 0.1, 1e-6))
        dt = _time(jitted, G, c, beta)
        emit(f"kernel.gram_cd_oracle.F{f}", dt * 1e6, f"flops~{2*f*f}")
    for n in (65536, 262144):
        m = jax.random.normal(key, (n,))
        y = jnp.sign(jax.random.normal(key, (n,)))
        jitted = jax.jit(lambda m, y: logistic_stats_ref(m, y))
        dt = _time(jitted, m, y)
        emit(f"kernel.logistic_stats_ref.n{n}", dt * 1e6, f"bytes~{n*16}")


if __name__ == "__main__":
    run()
