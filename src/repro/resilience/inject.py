"""Deterministic, seeded fault injection.

One module owns every fault the stack can be asked to survive, so a chaos
run is a single :class:`FaultPlan` armed around the code under test:

    with inject_faults(FaultPlan(engine=EngineFault("margins", at_iter=3))):
        res = est.fit(X, y, lam)
    assert res.status == engine.STATUS_NONFINITE_OBJECTIVE

Hook protocol — the production layers *consult* this module, they never
depend on it being armed:

* ``arm_engine_fault()`` — the solver factories (``core.dglmnet`` /
  ``core.distributed`` ``_solver_for``) call this once per solver
  acquisition; a non-None :class:`EngineFault` is baked into an uncached
  solver build whose while-loop body poisons margins/working stats (or
  forces a line-search stall) at ``at_iter``, on device. With no plan
  armed the call is a cheap None and the bounded solver caches serve the
  hot path byte-identically.
* ``maybe_kill(points_done)`` — the path driver calls this after each
  emitted point (post-checkpoint); raises :class:`InjectedKill` when the
  plan says so, simulating a mid-path process death.
* ``serve_delay()`` / ``take_swap_failure()`` / ``take_load_failure()``
  — the serve layer's latency and transient-failure knobs (the latter
  two are consumable counters, so retry-with-backoff paths can be
  exercised deterministically).
* ``take_prefetch_failure()`` — the streamed bucket-residency manager's
  lost-bucket knob (``repro.data.residency``): each consult either burns
  one of ``fail_prefetches_after`` healthy host->device puts or consumes
  one of ``fail_prefetches`` failures, so a drill can place the failure
  window mid-path deterministically (transient -> absorbed by retry;
  >= the retry budget -> the path dies and must resume via
  ``PathProgress``).
* :func:`corrupt_checkpoint` — host-side, deterministic corruption of a
  ``repro.checkpoint`` directory (bit flip / truncation / meta drop).

Everything here is stdlib-only: the harness must import (and the hooks
answer None/no-op) even where JAX cannot. Every fault that actually
*fires* bumps a ``faults.*`` counter on the active ``repro.obs`` metrics
registry (a no-op when none is armed), so chaos drills can assert the
expected faults really happened through the same telemetry surface
production reads.
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional

from repro.obs import registry as _metrics


class InjectedFault(RuntimeError):
    """A failure raised (not computed) by the injection harness."""


class InjectedKill(InjectedFault):
    """Simulated process death (``FaultPlan.kill_after_points``)."""


#: EngineFault kinds: what gets poisoned, at outer iteration ``at_iter``
ENGINE_FAULT_KINDS = ("margins", "stats", "linesearch")


@dataclass(frozen=True)
class EngineFault:
    """A device-side fault baked into one solver build.

    ``kind``: ``"margins"`` poisons the margin cache entering the fused
    working-stats pass; ``"stats"`` poisons (w, z) entering the
    subproblem; ``"linesearch"`` forces a no-progress, backtrack-exhausted
    line-search result. ``mode`` picks the poison value (``"nan"`` or
    ``"inf"``). ``at_iter`` is the 1-based outer iteration that fires.
    """

    kind: str
    at_iter: int = 1
    mode: str = "nan"

    def __post_init__(self):
        if self.kind not in ENGINE_FAULT_KINDS:
            raise ValueError(
                f"unknown EngineFault kind {self.kind!r}: expected one of "
                f"{ENGINE_FAULT_KINDS}")
        if self.mode not in ("nan", "inf"):
            raise ValueError(f"mode must be 'nan' or 'inf', got {self.mode!r}")
        if self.at_iter < 1:
            raise ValueError(f"at_iter must be >= 1, got {self.at_iter}")


@dataclass(frozen=True)
class FaultPlan:
    """The full, deterministic description of one chaos scenario.

    ``engine_fires`` bounds how many solver acquisitions arm ``engine``
    (None = every one while the plan is active) — ``engine_fires=1``
    poisons exactly the next solve, so recovery paths (the path driver's
    degradation ladder) see a *transient* fault. ``fail_swaps`` /
    ``fail_loads`` are consumable counters making the next N
    ``PathStore.swap`` / checkpoint loads raise :class:`InjectedFault`
    (exercising retry-with-backoff). ``serve_latency_s`` sleeps every
    scorer dispatch by that much. ``fail_prefetches`` makes N consecutive
    slab-bucket host->device puts fail, after first letting
    ``fail_prefetches_after`` puts through healthy — the offset is what
    lands a lost-bucket fault mid-path instead of at residency build.
    """

    seed: int = 0
    engine: Optional[EngineFault] = None
    engine_fires: Optional[int] = None
    kill_after_points: Optional[int] = None
    serve_latency_s: float = 0.0
    fail_swaps: int = 0
    fail_loads: int = 0
    fail_prefetches: int = 0
    fail_prefetches_after: int = 0


class _ActivePlan:
    """Armed plan + its mutable consumable counters."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.engine_left = plan.engine_fires
        self.swaps_left = plan.fail_swaps
        self.loads_left = plan.fail_loads
        self.prefetch_ok_left = plan.fail_prefetches_after
        self.prefetches_left = plan.fail_prefetches


_LOCK = threading.Lock()
_ACTIVE: Optional[_ActivePlan] = None


@contextmanager
def inject_faults(plan: FaultPlan):
    """Arm ``plan`` for the dynamic extent of the block (process-global:
    the solver factories and serve hooks consult it from any thread).
    Nesting is an error — one scenario at a time keeps runs deterministic.
    """
    global _ACTIVE
    with _LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already armed (no nesting)")
        _ACTIVE = _ActivePlan(plan)
    try:
        yield plan
    finally:
        with _LOCK:
            _ACTIVE = None


def active_plan() -> Optional[FaultPlan]:
    a = _ACTIVE
    return None if a is None else a.plan


def arm_engine_fault() -> Optional[EngineFault]:
    """The engine fault to bake into the next solver build, consuming one
    of ``engine_fires`` — or None (no plan / fault exhausted)."""
    with _LOCK:
        a = _ACTIVE
        if a is None or a.plan.engine is None:
            return None
        if a.engine_left is None:
            _metrics.counter("faults.engine").inc()
            return a.plan.engine
        if a.engine_left <= 0:
            return None
        a.engine_left -= 1
        _metrics.counter("faults.engine").inc()
        return a.plan.engine


def maybe_kill(points_done: int) -> None:
    """Raise :class:`InjectedKill` when the armed plan says the process
    dies after ``points_done`` path points. No-op otherwise."""
    a = _ACTIVE
    if (a is not None and a.plan.kill_after_points is not None
            and points_done >= a.plan.kill_after_points):
        _metrics.counter("faults.kill").inc()
        raise InjectedKill(
            f"injected kill after {points_done} path points "
            f"(plan: kill_after_points={a.plan.kill_after_points})")


def serve_delay() -> float:
    """Sleep the armed plan's serve latency; returns the seconds slept."""
    a = _ACTIVE
    if a is None or a.plan.serve_latency_s <= 0.0:
        return 0.0
    _metrics.counter("faults.serve_delay").inc()
    time.sleep(a.plan.serve_latency_s)
    return a.plan.serve_latency_s


def take_swap_failure() -> bool:
    """Consume one injected ``PathStore.swap`` failure, if any remain."""
    with _LOCK:
        a = _ACTIVE
        if a is None or a.swaps_left <= 0:
            return False
        a.swaps_left -= 1
        _metrics.counter("faults.swap").inc()
        return True


def take_load_failure() -> bool:
    """Consume one injected checkpoint-load failure, if any remain."""
    with _LOCK:
        a = _ACTIVE
        if a is None or a.loads_left <= 0:
            return False
        a.loads_left -= 1
        _metrics.counter("faults.load").inc()
        return True


def take_prefetch_failure() -> bool:
    """Consume one injected slab-bucket prefetch failure, if any remain.

    The first ``fail_prefetches_after`` consults are let through healthy
    (each burns one unit of the offset); the next ``fail_prefetches``
    consults return True. The residency manager calls this once per
    host->device put *attempt*, so retries burn failures too — a count
    below the retry budget is transient, at or above it is fatal.
    """
    with _LOCK:
        a = _ACTIVE
        if a is None or a.prefetches_left <= 0:
            return False
        if a.prefetch_ok_left > 0:
            a.prefetch_ok_left -= 1
            return False
        a.prefetches_left -= 1
        _metrics.counter("faults.prefetch").inc()
        return True


# ---------------------------------------------------------------------------
# host-side checkpoint corruption (deterministic)
# ---------------------------------------------------------------------------

CORRUPTION_MODES = ("bitflip", "truncate", "drop-meta")


def corrupt_checkpoint(directory: str, mode: str = "bitflip", *,
                       seed: int = 0) -> str:
    """Deterministically damage a ``repro.checkpoint`` directory.

    ``bitflip`` flips one bit of the array payload at a seed-derived
    offset (CRC-detectable); ``truncate`` keeps only the first half of
    the payload (length-mismatch-detectable); ``drop-meta`` removes the
    manifest's ``meta`` side channel (consumers that need it must fail
    typed, not KeyError). Returns a description of what was done.
    """
    payload = os.path.join(directory, "arrays.npz")
    manifest = os.path.join(directory, "manifest.json")
    if mode == "bitflip":
        with open(payload, "rb") as fh:
            data = bytearray(fh.read())
        if not data:
            raise ValueError(f"{payload} is empty — nothing to flip")
        off = seed % len(data)
        data[off] ^= 0x01
        with open(payload, "wb") as fh:
            fh.write(bytes(data))
        return f"flipped bit 0 of byte {off}/{len(data)} in {payload}"
    if mode == "truncate":
        size = os.path.getsize(payload)
        with open(payload, "rb") as fh:
            head = fh.read(size // 2)
        with open(payload, "wb") as fh:
            fh.write(head)
        return f"truncated {payload} from {size} to {size // 2} bytes"
    if mode == "drop-meta":
        with open(manifest) as fh:
            doc = json.load(fh)
        doc.pop("meta", None)
        with open(manifest, "w") as fh:
            json.dump(doc, fh, indent=1)
        return f"dropped the meta side channel from {manifest}"
    raise ValueError(
        f"unknown corruption mode {mode!r}: expected one of "
        f"{CORRUPTION_MODES}")
