"""Tier-1 tests for the bucket-residency manager (out-of-HBM streaming).

Covers the satellite checklist: slab byte accounting, LRU eviction order
under budget pressure, hit/miss counter correctness, the double-buffer
prefetch order, the budget floor, streamed==resident bit-identity on a
local mesh (the 2x4 flavor runs in a fake-device subprocess, marked
slow), resume-after-kill of a streamed path, and strategy residency
resolution. The standalone-manager tests run against tiny host buckets
with no mesh at all — residency policy is plain Python.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data.residency import BucketResidencyManager
from repro.resilience import (
    FaultPlan,
    InjectedKill,
    RetriesExhausted,
    inject_faults,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _buckets(n_buckets=3, p_b=2, k=8):
    """Equal-size host buckets: (row_idx, values, feat_idx) triples of
    p_b*k*8 bytes each (int32 rows + float32 values)."""
    out = []
    for i in range(n_buckets):
        r = np.zeros((p_b, 1, k), np.int32)
        v = np.ones((p_b, 1, k), np.float32) * i
        out.append((r, v, np.arange(p_b) + i * p_b))
    return tuple(out)


def _mixed_density_X(n, p, seed=0):
    """Stratified per-column nnz -> several power-of-two capacity
    classes (streamed residency needs >= 3 buckets to ever evict)."""
    rng = np.random.default_rng(seed)
    levels = [4, 12, 28, min(60, n // 2)]
    X = np.zeros((n, p), np.float32)
    for j in range(p):
        rows = rng.choice(n, size=levels[j % len(levels)], replace=False)
        X[rows, j] = rng.normal(size=rows.size).astype(np.float32)
    return X


def _labels(X, seed=1):
    rng = np.random.default_rng(seed)
    p = X.shape[1]
    w = rng.normal(size=p) * (rng.random(p) < 0.3)
    prob = 1.0 / (1.0 + np.exp(-(X @ w)))
    return np.where(rng.random(X.shape[0]) < prob, 1.0, -1.0) \
        .astype(np.float32)


# ---------------------------------------------------------------------------
# byte accounting
# ---------------------------------------------------------------------------

def test_slab_buckets_nbytes_accounting():
    from repro.data.byfeature import to_by_feature, to_slab_buckets

    X = _mixed_density_X(128, 48)
    slabs = to_slab_buckets(to_by_feature(X), 1)
    assert len(slabs.buckets) >= 3, slabs.k_classes
    per = slabs.bucket_nbytes
    assert len(per) == len(slabs.buckets)
    for nb, (r, v, _) in zip(per, slabs.buckets):
        assert nb == r.nbytes + v.nbytes > 0
    assert slabs.nbytes == sum(per)


def test_manager_byte_accounting_matches_host_arrays():
    bks = _buckets(3)
    mgr = BucketResidencyManager(bks)
    per = tuple(r.nbytes + v.nbytes for r, v, _ in bks)
    assert mgr.bucket_bytes == per
    assert mgr.total_bytes == sum(per)
    assert mgr.min_budget_bytes == per[0] + per[1]  # equal-size buckets
    assert not mgr.streamed
    # resident mode: everything on device at construction, no host copy
    assert mgr.resident_indices() == (0, 1, 2)
    assert mgr.resident_bytes == mgr.total_bytes
    assert mgr.stats()["puts"] == 3 and mgr.stats()["bytes_h2d"] == sum(per)


# ---------------------------------------------------------------------------
# LRU policy + counters (standalone manager, no mesh)
# ---------------------------------------------------------------------------

def test_lru_eviction_order_under_budget_pressure():
    bks = _buckets(3)
    one = bks[0][0].nbytes + bks[0][1].nbytes
    mgr = BucketResidencyManager(bks, budget_bytes=2 * one)
    assert mgr.streamed and mgr.resident_indices() == ()
    mgr.get(0)
    mgr.get(1)
    assert mgr.resident_indices() == (0, 1)
    mgr.get(2)                               # evicts 0 (least recent)
    assert mgr.resident_indices() == (1, 2)
    mgr.get(0)                               # evicts 1
    assert mgr.resident_indices() == (2, 0)
    mgr.get(2)                               # hit: refresh recency only
    assert mgr.resident_indices() == (0, 2)
    st = mgr.stats()
    assert st["hits"] == 1 and st["misses"] == 4
    assert st["evictions"] == 2 and st["puts"] == 4
    assert st["bytes_h2d"] == 4 * one
    assert st["resident_bytes"] == 2 * one
    assert st["hit_rate"] == pytest.approx(0.2)


def test_streamed_get_returns_the_right_payload():
    bks = _buckets(3)
    one = bks[0][0].nbytes + bks[0][1].nbytes
    mgr = BucketResidencyManager(bks, budget_bytes=2 * one)
    for i in (2, 0, 1, 0, 2):
        r_dev, v_dev = mgr.get(i)
        np.testing.assert_array_equal(np.asarray(v_dev), bks[i][1])
        np.testing.assert_array_equal(np.asarray(r_dev), bks[i][0])


def test_budget_below_double_buffer_floor_raises():
    bks = _buckets(3)
    floor = BucketResidencyManager(bks).min_budget_bytes
    with pytest.raises(ValueError, match="double-buffer"):
        BucketResidencyManager(bks, budget_bytes=floor - 1)
    # exactly the floor is fine
    mgr = BucketResidencyManager(bks, budget_bytes=floor)
    assert mgr.streamed
    assert [f for *_, f in mgr.iter_buckets()]  # full pass completes


def test_iter_prefetches_next_bucket_before_yield():
    bks = _buckets(4)
    one = bks[0][0].nbytes + bks[0][1].nbytes
    mgr = BucketResidencyManager(bks, budget_bytes=2 * one)
    it = mgr.iter_buckets()
    next(it)
    # bucket 1's put was dispatched before bucket 0 was yielded
    assert mgr.resident_indices() == (0, 1)
    next(it)
    assert mgr.resident_indices() == (1, 2)
    feats = [f for *_, f in it]                 # drain the pass
    assert len(feats) == 2
    st = mgr.stats()
    assert st["misses"] == 4 and st["hits"] == 3  # each prefetch hit once
    # a second pass streams again from the LRU tail
    assert sum(1 for _ in mgr.iter_buckets()) == 4
    assert mgr.stats()["evictions"] > st["evictions"]


def test_iter_is_not_reentrant():
    mgr = BucketResidencyManager(_buckets(3))
    it = mgr.iter_buckets()
    next(it)
    with pytest.raises(RuntimeError, match="not reentrant"):
        next(mgr.iter_buckets())
    it.close()
    assert sum(1 for _ in mgr.iter_buckets()) == 3  # guard released


def test_out_of_range_bucket_raises():
    mgr = BucketResidencyManager(_buckets(2))
    with pytest.raises(IndexError):
        mgr.get(2)


# ---------------------------------------------------------------------------
# prefetch-failure injection
# ---------------------------------------------------------------------------

def test_transient_prefetch_failure_is_retried_transparently():
    bks = _buckets(3)
    one = bks[0][0].nbytes + bks[0][1].nbytes
    mgr = BucketResidencyManager(bks, budget_bytes=2 * one,
                                 retry_base_s=0.001)
    with inject_faults(FaultPlan(fail_prefetches=2)):
        r_dev, v_dev = mgr.get(0)
    np.testing.assert_array_equal(np.asarray(v_dev), bks[0][1])
    st = mgr.stats()
    assert st["retries"] == 2 and st["puts"] == 1 and st["misses"] == 1


def test_prefetch_failure_exhaustion_is_typed():
    bks = _buckets(3)
    one = bks[0][0].nbytes + bks[0][1].nbytes
    mgr = BucketResidencyManager(bks, budget_bytes=2 * one,
                                 retry_base_s=0.001)
    with inject_faults(FaultPlan(fail_prefetches=3)):
        with pytest.raises(RetriesExhausted):
            mgr.get(0)
    assert mgr.stats()["retries"] == 2 and mgr.stats()["puts"] == 0
    # the manager is still usable once the fault window passes
    mgr.get(0)
    assert mgr.resident_indices() == (0,)


# ---------------------------------------------------------------------------
# strategy resolution
# ---------------------------------------------------------------------------

def test_strategy_residency_resolution():
    from repro.api import DenseDesign, ShardedDesign, as_design
    from repro.api.strategy import resolve
    from repro.core.dglmnet import DGLMNETOptions
    from repro.data.byfeature import to_by_feature, to_slab_buckets
    from repro.launch.mesh import make_dev_mesh

    X = _mixed_density_X(128, 48)
    slabs = to_slab_buckets(to_by_feature(X), 1)
    mesh = make_dev_mesh(1, 1)
    opts = DGLMNETOptions(tile=16)

    plain = as_design(slabs, mesh=mesh, tile=16)
    assert resolve(plain, opts).residency == "resident"
    total = plain.slab_nbytes(16)

    under = as_design(slabs, mesh=mesh, tile=16,
                      device_budget_bytes=total - 1)
    assert resolve(under, opts).residency == "streamed"

    covering = as_design(slabs, mesh=mesh, tile=16,
                         device_budget_bytes=total)
    assert resolve(covering, opts).residency == "resident"

    dense = ShardedDesign(DenseDesign(X), mesh, tile=16,
                          device_budget_bytes=1 << 20)
    with pytest.raises(ValueError, match="slab layouts only"):
        resolve(dense, opts)

    with pytest.raises(ValueError, match="device_budget_bytes"):
        DGLMNETOptions(tile=16, device_budget_bytes=0)


# ---------------------------------------------------------------------------
# end-to-end: streamed == resident, bit for bit
# ---------------------------------------------------------------------------

def _path_pair(mesh, X, y, path_len=3):
    """(resident, streamed, streamed design) paths over the same slabs."""
    from repro.api import LogisticL1, as_design
    from repro.core.dglmnet import DGLMNETOptions
    from repro.core.distributed import _data_extent
    from repro.data.byfeature import to_by_feature, to_slab_buckets

    slabs = to_slab_buckets(to_by_feature(X), _data_extent(mesh))
    assert len(slabs.buckets) >= 3, slabs.k_classes
    opts = DGLMNETOptions(tile=16, max_iters=30)
    base = LogisticL1(opts=opts, mesh=mesh).path(
        as_design(slabs, mesh=mesh, tile=16), y, path_len=path_len)
    sizing = as_design(slabs, mesh=mesh, tile=16)
    budget = sizing.slab_nbytes(16) - min(sizing.slab_bucket_nbytes(16))
    des = as_design(slabs, mesh=mesh, tile=16, device_budget_bytes=budget)
    streamed = LogisticL1(opts=opts, mesh=mesh).path(
        des, y, path_len=path_len)
    return base, streamed, des


def test_streamed_path_bit_identical_local_mesh():
    from repro.launch.mesh import make_dev_mesh

    X = _mixed_density_X(128, 48)
    y = _labels(X)
    base, streamed, des = _path_pair(make_dev_mesh(1, 1), X, y)
    assert np.array_equal(np.asarray(streamed.betas),
                          np.asarray(base.betas))
    assert np.array_equal(streamed.f, base.f)
    assert np.array_equal(streamed.nnz, base.nnz)
    (stats,) = des.residency_stats().values()
    assert stats["streamed"] and stats["evictions"] > 0
    assert stats["misses"] > stats["n_buckets"]   # re-streamed across passes
    assert stats["bytes_h2d"] > stats["total_bytes"]
    assert stats["resident_bytes"] <= stats["budget_bytes"]


def test_streamed_path_resumes_after_kill():
    import tempfile

    from repro.api import LogisticL1, as_design
    from repro.core.dglmnet import DGLMNETOptions
    from repro.data.byfeature import to_by_feature, to_slab_buckets
    from repro.launch.mesh import make_dev_mesh

    mesh = make_dev_mesh(1, 1)
    X = _mixed_density_X(128, 48)
    y = _labels(X)
    slabs = to_slab_buckets(to_by_feature(X), 1)
    opts = DGLMNETOptions(tile=16, max_iters=30)
    base = LogisticL1(opts=opts, mesh=mesh).path(
        as_design(slabs, mesh=mesh, tile=16), y, path_len=3)
    sizing = as_design(slabs, mesh=mesh, tile=16)
    budget = sizing.slab_nbytes(16) - min(sizing.slab_bucket_nbytes(16))

    def design():
        return as_design(slabs, mesh=mesh, tile=16,
                         device_budget_bytes=budget)

    est = LogisticL1(opts=opts, mesh=mesh)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(InjectedKill):
            with inject_faults(FaultPlan(kill_after_points=2)):
                est.path(design(), y, path_len=3, checkpoint_every=1,
                         resume_from=d)
        resumed = est.path(design(), y, path_len=3, checkpoint_every=1,
                           resume_from=d)
    assert np.array_equal(np.asarray(resumed.betas), np.asarray(base.betas))
    assert np.array_equal(resumed.f, base.f)
    assert np.array_equal(resumed.nnz, base.nnz)


@pytest.mark.slow
def test_streamed_path_bit_identical_2x4_mesh():
    """Streamed == resident on a real 2x4 fake-device mesh (subprocess,
    per the 1-device isolation rule for in-process tests)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import numpy as np
        from repro.api import LogisticL1, as_design
        from repro.core.dglmnet import DGLMNETOptions
        from repro.data.byfeature import to_by_feature, to_slab_buckets
        from repro.launch.mesh import make_dev_mesh

        rng = np.random.default_rng(0)
        n, p = 256, 64
        levels = [4, 12, 28, 60]
        X = np.zeros((n, p), np.float32)
        for j in range(p):
            rows = rng.choice(n, size=levels[j % 4], replace=False)
            X[rows, j] = rng.normal(size=rows.size).astype(np.float32)
        w = rng.normal(size=p) * (rng.random(p) < 0.3)
        prob = 1.0 / (1.0 + np.exp(-(X @ w)))
        y = np.where(rng.random(n) < prob, 1.0, -1.0).astype(np.float32)

        mesh = make_dev_mesh(2, 4)
        slabs = to_slab_buckets(to_by_feature(X), 2)
        assert len(slabs.buckets) >= 3, slabs.k_classes
        opts = DGLMNETOptions(tile=16, max_iters=30)
        base = LogisticL1(opts=opts, mesh=mesh).path(
            as_design(slabs, mesh=mesh, tile=16), y, path_len=3)
        sizing = as_design(slabs, mesh=mesh, tile=16)
        budget = sizing.slab_nbytes(16) - min(sizing.slab_bucket_nbytes(16))
        des = as_design(slabs, mesh=mesh, tile=16,
                        device_budget_bytes=budget)
        streamed = LogisticL1(opts=opts, mesh=mesh).path(
            des, y, path_len=3)
        assert np.array_equal(np.asarray(streamed.betas),
                              np.asarray(base.betas))
        assert np.array_equal(streamed.f, base.f)
        assert np.array_equal(streamed.nnz, base.nnz)
        (stats,) = des.residency_stats().values()
        assert stats["streamed"] and stats["evictions"] > 0, stats
        print("OK streamed 2x4", stats["hit_rate"])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK streamed 2x4" in r.stdout
