"""Unified causal LM covering dense / MoE / SSM / hybrid / VLM arch types.

Layers are grouped into *segments* of consecutive identical kinds (dense
archs: 1 segment; deepseek-v3: dense-prefix + MoE segments; zamba2:
alternating ssm / hybrid_attn runs). Each segment's parameters are stacked
on a leading layer axis and executed with ``lax.scan`` — HLO size stays
O(#segments), not O(depth), which is what keeps the 512-device dry-run
compile tractable. Remat is applied per layer inside the scan.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import (
    init_layer,
    init_layer_cache,
    init_shared_attn_block,
    layer_forward,
)
from repro.models.layers import apply_norm, dense_init, embed_init, init_norm
from repro.sharding.ctx import constrain


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


def segments_of(cfg: ModelConfig) -> List[Tuple[str, int]]:
    """Group layer kinds into (kind, run-length) segments.

    Hybrid archs (zamba2: attention every k-th layer) would fragment into
    ~2L/k segments; instead they become ONE scanned segment of
    "hybrid_period" super-layers (k-1 mamba blocks + 1 shared-attn block)
    plus an ssm remainder — 27 compiles -> 2 for zamba2-7b.
    """
    if cfg.arch_type == "hybrid" and cfg.hybrid is not None:
        k = cfg.hybrid.attn_every
        groups, rem = divmod(cfg.num_layers, k)
        segs = [("hybrid_period", groups)] if groups else []
        if rem:
            segs.append(("ssm", rem))
        return segs
    segs: List[Tuple[str, int]] = []
    for k in cfg.layer_kinds():
        if segs and segs[-1][0] == k:
            segs[-1] = (k, segs[-1][1] + 1)
        else:
            segs.append((k, 1))
    return segs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    segs = segments_of(cfg)
    keys = jax.random.split(key, len(segs) + 5)

    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.d_model, dtype, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.padded_vocab, dtype)

    seg_params = []
    for i, (kind, n) in enumerate(segs):
        lk = jax.random.split(keys[2 + i], n)
        seg_params.append(jax.vmap(lambda k: init_layer(k, cfg, kind, dtype))(lk))
    params["segments"] = seg_params

    if cfg.arch_type == "hybrid" and cfg.hybrid is not None and cfg.hybrid.shared_attn:
        params["shared_attn"] = init_shared_attn_block(keys[-3], cfg, dtype)

    if cfg.frontend.kind != "none":
        params["frontend_proj"] = dense_init(
            keys[-2], cfg.frontend.embed_dim, cfg.d_model, dtype
        )

    if cfg.mtp_depth:
        mk = jax.random.split(keys[-1], 2)
        params["mtp"] = {
            "proj": dense_init(mk[0], 2 * cfg.d_model, cfg.d_model, dtype),
            "layer": jax.tree.map(
                lambda x: x[None], init_layer(mk[1], cfg, cfg.layer_kinds()[-1], dtype)
            ),
        }
    return params


def init_lm_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or dtype_of(cfg.compute_dtype)
    segs = segments_of(cfg)

    def seg_cache(kind, n):
        one = init_layer_cache(cfg, kind, batch, cache_len, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)

    return {"segments": [seg_cache(k, n) for k, n in segs]}


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _segment_forward(seg_p, x, *, cfg, kind, n, positions, mode, seg_cache,
                     cache_index, window, window_slice, shared_block, deterministic):
    def apply_layer(x, p_l, cache_l):
        return layer_forward(
            p_l, x, cfg=cfg, kind=kind, positions=positions, mode=mode,
            cache=cache_l, cache_index=cache_index, window=window,
            window_slice=window_slice, shared_block=shared_block,
            deterministic=deterministic,
        )

    if cfg.remat and mode == "train":
        apply_layer = jax.checkpoint(apply_layer)

    if n == 1:
        p0 = jax.tree.map(lambda a: a[0], seg_p)
        c0 = jax.tree.map(lambda a: a[0], seg_cache) if seg_cache is not None else None
        x, new_c, aux = apply_layer(x, p0, c0)
        new_c = jax.tree.map(lambda a: a[None], new_c) if new_c is not None else None
        return x, new_c, aux

    if not cfg.scan_layers:
        # unrolled python loop: O(depth) HLO, but exact cost_analysis
        # (HloCostAnalysis counts while-loop bodies once) — dry-run uses this.
        new_cs, auxs = [], []
        for i in range(n):
            p_l = jax.tree.map(lambda a: a[i], seg_p)
            c_l = jax.tree.map(lambda a: a[i], seg_cache) if seg_cache is not None else None
            x, new_c, aux_l = apply_layer(x, p_l, c_l)
            new_cs.append(new_c)
            auxs.append(aux_l)
        if new_cs[0] is not None:
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)
        else:
            new_cache = None
        aux = {}
        for a in auxs:
            for k_, v_ in (a or {}).items():
                aux[k_] = aux.get(k_, 0.0) + v_
        return x, new_cache, aux

    def body(carry, per_layer):
        p_l, cache_l = per_layer
        y, new_cache_l, aux_l = apply_layer(carry, p_l, cache_l)
        return y, (new_cache_l, aux_l)

    x, (new_cache, auxs) = jax.lax.scan(body, x, (seg_p, seg_cache))
    aux = jax.tree.map(lambda a: jnp.sum(a, axis=0), auxs) if auxs else {}
    return x, new_cache, aux


def lm_forward(
    params,
    inputs: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    mode: str = "train",                  # train | prefill | decode
    cache: Optional[dict] = None,
    cache_index=None,                     # int32 scalar: tokens already cached
    long_mode: bool = False,              # long_500k: sliding-window/native path
    deterministic: bool = True,
):
    """Returns (logits, new_cache, aux)."""
    cdtype = dtype_of(cfg.compute_dtype)
    tokens = inputs["tokens"]
    b, s_text = tokens.shape

    x = jnp.take(params["embed"], tokens, axis=0).astype(cdtype)
    x = constrain(x, "batch", None, None)

    prefix_len = 0
    for key_name in ("patch_embeds", "frame_embeds"):
        if key_name in inputs and inputs[key_name] is not None:
            pe = inputs[key_name].astype(cdtype) @ params["frontend_proj"].astype(cdtype)
            x = jnp.concatenate([pe, x], axis=1)
            prefix_len = pe.shape[1]
            break
    s = x.shape[1]

    if mode == "decode":
        assert cache_index is not None
        positions = jnp.broadcast_to(
            jnp.asarray(cache_index, jnp.int32)[None, None], (b, s)
        )
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    window = cfg.attention.sliding_window
    window_slice = False
    if long_mode and cfg.long_context_mode == "sliding_window":
        window = cfg.long_context_window
        window_slice = mode == "decode"
    if long_mode and cfg.arch_type == "hybrid":
        # zamba2: SSM spine native; shared attn blocks go sliding-window
        window = cfg.long_context_window
        window_slice = mode == "decode"

    segs = segments_of(cfg)
    shared_block = params.get("shared_attn")
    new_seg_caches = []
    aux_total: Dict[str, jnp.ndarray] = {}

    for i, (kind, n) in enumerate(segs):
        seg_cache = cache["segments"][i] if cache is not None else None
        x, new_c, aux = _segment_forward(
            params["segments"][i], x, cfg=cfg, kind=kind, n=n, positions=positions,
            mode=mode, seg_cache=seg_cache, cache_index=cache_index, window=window,
            window_slice=window_slice, shared_block=shared_block,
            deterministic=deterministic,
        )
        x = constrain(x, "batch", None, None)
        new_seg_caches.append(new_c)
        for k_, v_ in (aux or {}).items():
            aux_total[k_] = aux_total.get(k_, 0.0) + v_

    h = apply_norm(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head.astype(h.dtype)
    logits = constrain(logits, "batch", None, "model")

    # ----- MTP (DeepSeek-V3 multi-token prediction), training only --------
    if cfg.mtp_depth and mode == "train" and s_text > 1:
        emb_next = jnp.take(params["embed"], jnp.roll(tokens, -1, axis=1), axis=0)
        if prefix_len:
            h_text = h[:, prefix_len:, :]
        else:
            h_text = h
        h_mtp = jnp.concatenate([h_text, emb_next.astype(h.dtype)], axis=-1)
        h_mtp = h_mtp @ params["mtp"]["proj"].astype(h.dtype)
        mtp_pos = positions[:, prefix_len:] if prefix_len else positions
        p0 = jax.tree.map(lambda a: a[0], params["mtp"]["layer"])
        h_mtp, _, _ = layer_forward(
            p0, h_mtp, cfg=cfg, kind=cfg.layer_kinds()[-1], positions=mtp_pos,
            mode="train", shared_block=shared_block,
        )
        aux_total["mtp_logits"] = h_mtp @ head.astype(h_mtp.dtype)

    new_cache = {"segments": new_seg_caches} if mode in ("prefill", "decode") else None
    return logits, new_cache, aux_total
