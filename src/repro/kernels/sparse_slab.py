"""Pallas TPU kernels: sparse-native by-feature slab suite.

d-GLMNET's headline workloads are extremely sparse (webspam: ~0.02%
dense), and the paper's Table-1 layout stores each feature as its
``(row, value)`` nonzero list. These kernels compute the per-tile
statistics of the quadratic subproblem *directly from the slabs* —
no ``(n_loc, tile)`` densify scatter, no dense FLOPs:

* ``slab_gram_pallas`` — the weighted Gram tile ``G = X_F^T diag(w) X_F``
  and correlation ``c = X_F^T (w r)`` via a match-and-accumulate join over
  nnz slots: for each slot pair ``(k, k')`` a (T, T) broadcast compare of
  the row indices gates an outer-product FMA. Cost is O(T^2 K^2) cheap VPU
  ops against the dense path's O(n_loc T^2) MXU FLOPs + an O(nnz) HBM
  scatter — the sparse form wins when K (nnz per feature per shard) is
  small, exactly the regime the paper's datasets live in. The dispatch
  layer (``kernels.ops``) picks the dense fallback above the density
  threshold.
* ``slab_spmv_pallas`` — ``X_F @ d`` over the example axis without a
  scatter: the output is tiled over ``n_loc`` and each block accumulates
  the slots that match its row range via the same broadcast compare.

Both kernels receive *pre-gathered* weight operands (``w``/``w*r`` looked
up at the slab's row indices, zeroed at sentinels) — the XLA gather
outside the kernel is efficient on every backend, and it keeps the kernel
bodies free of dynamic indexing. Sentinel slots (row == n_loc padding)
must contribute exactly zero: the wrappers zero both the value and the
gathered-weight side, so even adversarial padding values cannot leak row
``n_loc``'s ghost weight into G, c, or the matvec.

Validated on CPU with ``interpret=True`` against ``ref.slab_gram_ref`` /
``ref.slab_spmv_ref`` (densify-based oracles).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import out_shape_struct


def _slab_gram_kernel(rows_ref, rowsT_ref, wv_ref, vaT_ref, cva_ref,
                      G_ref, c_ref):
    """Refs: rows (T, K) int32; rowsT (K, T) its transpose; wv (T, K) =
    w[row] * value (sentinel-zeroed); vaT (K, T) = value^T
    (sentinel-zeroed); cva (T, K) = value * (w r)[row]. Outs: G (T, T),
    c (1, T)."""
    t, k = rows_ref.shape
    c_ref[...] = jnp.sum(cva_ref[...], axis=1)[None, :]
    G_ref[...] = jnp.zeros_like(G_ref)

    def pair(i, _):
        ka = i // k
        kb = i - ka * k
        ra = pl.load(rows_ref, (slice(None), pl.ds(ka, 1)))    # (T, 1)
        rb = pl.load(rowsT_ref, (pl.ds(kb, 1), slice(None)))   # (1, T)
        wa = pl.load(wv_ref, (slice(None), pl.ds(ka, 1)))      # (T, 1)
        vb = pl.load(vaT_ref, (pl.ds(kb, 1), slice(None)))     # (1, T)
        eq = (ra == rb).astype(jnp.float32)                    # (T, T) match
        G_ref[...] = G_ref[...] + (wa * eq) * vb
        return 0

    jax.lax.fori_loop(0, k * k, pair, 0)


@partial(jax.jit, static_argnames=("interpret",))
def slab_gram_pallas(rows, wv, va, cva, *, interpret: bool = True):
    """Gram/correlation from one feature-tile slab.

    rows (T, K) int32 local row indices (sentinel anywhere >= n_loc);
    wv = w[rows] * values with sentinel slots zeroed; va = values with
    sentinel slots zeroed; cva = values * (w*r)[rows] sentinel-zeroed.
    Returns (G (T, T), c (T,)).
    """
    t, k = rows.shape
    out_g = out_shape_struct((t, t), jnp.float32, operands=(wv, va, cva))
    out_c = out_shape_struct((1, t), jnp.float32, operands=(wv, va, cva))
    G, c = pl.pallas_call(
        _slab_gram_kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec((t, k), lambda: (0, 0)),
            pl.BlockSpec((k, t), lambda: (0, 0)),
            pl.BlockSpec((t, k), lambda: (0, 0)),
            pl.BlockSpec((k, t), lambda: (0, 0)),
            pl.BlockSpec((t, k), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, t), lambda: (0, 0)),
            pl.BlockSpec((1, t), lambda: (0, 0)),
        ],
        out_shape=[out_g, out_c],
        interpret=interpret,
    )(rows, rows.T, wv.astype(jnp.float32), va.astype(jnp.float32).T,
      cva.astype(jnp.float32))
    return G, c[0]


def _slab_spmv_kernel(rows_ref, dv_ref, out_ref):
    """Refs: rows (N, 1) int32 flattened slot rows; dv (N, 1) = value *
    d[feature] (sentinel-zeroed); out (1, B), grid-tiled over examples."""
    b = out_ref.shape[1]
    base = pl.program_id(0) * b
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, b), 1) + base
    eq = (rows_ref[...] == lane).astype(jnp.float32)           # (N, B)
    out_ref[...] = jnp.sum(dv_ref[...] * eq, axis=0)[None, :]


@partial(jax.jit, static_argnames=("n_loc", "block", "interpret"))
def slab_spmv_pallas(rows, dv, *, n_loc: int, block: int = 256,
                     interpret: bool = True):
    """``X_F @ d`` over a slab without densify or scatter.

    rows (T, K) int32; dv (T, K) = values * d[:, None] with sentinel slots
    zeroed. Returns the (n_loc,) per-example product; output rows are tiled
    ``block`` at a time and each grid step accumulates its matching slots.
    """
    npad = n_loc + (-n_loc) % block
    rows_col = rows.reshape(-1, 1)
    dv_col = dv.astype(jnp.float32).reshape(-1, 1)
    n_slots = rows_col.shape[0]
    grid = (npad // block,)
    out = pl.pallas_call(
        _slab_spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_slots, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_slots, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=out_shape_struct((1, npad), jnp.float32,
                                   operands=(rows, dv)),
        interpret=interpret,
    )(rows_col, dv_col)
    return out[0, :n_loc]
