"""Shared fixtures. NOTE: no XLA_FLAGS here by design — tests see 1 device;
distributed tests spawn subprocesses with fake-device env (see
tests/test_distributed.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import GLMConfig
from repro.data.synthetic import make_glm_dataset


@pytest.fixture(scope="session")
def small_glm():
    """~2.5k x 128 dense synthetic logistic problem + lambda grid."""
    cfg = GLMConfig(name="test", num_examples=2560, num_features=128, density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    return ds


@pytest.fixture(scope="session")
def sparse_glm():
    cfg = GLMConfig(name="test-sparse", num_examples=2048, num_features=256,
                    density=0.1)
    return make_glm_dataset(cfg, jax.random.key(1))


@pytest.fixture(scope="session")
def glm_opt():
    """Reference optimum via long proximal-gradient run (oracle)."""

    def solve(X, y, lam, iters=6000):
        L = 0.25 * jnp.linalg.norm(X, ord=2) ** 2
        lr = float(1.0 / L)
        beta = jnp.zeros(X.shape[1])

        @jax.jit
        def step(beta):
            m = X @ beta
            g = X.T @ (jax.nn.sigmoid(m) - (y + 1) * 0.5)
            b = beta - lr * g
            return jnp.sign(b) * jnp.maximum(jnp.abs(b) - lr * lam, 0.0)

        for _ in range(iters):
            beta = step(beta)
        return beta

    return solve
