"""Ablation: WHY the paper's design (sequential CD within blocks +
block-diagonal Hessian across blocks + global line search) beats naive
fully-parallel coordinate updates (Shotgun-style Jacobi, Bradley et al.
2011 — the conflict problem the paper cites in §1), and where the blocked
semi-parallel cycle (PR 4) sits between the two.

Three-way sweep reproducing the paper's §1 motivation figure:

* **sequential** — the exact within-tile chain (``cd_cycle_gram_tile``);
* **blocked-B** — B-wide proximal-Jacobi blocks applied sequentially with
  the Gershgorin dominance safeguard (``cd_cycle_blocked_tile``),
  B in {4, 8, 16, 32};
* **jacobi** — all coordinates at once from one snapshot (Shotgun).

Per (correlation rho, method) cell: iterations to reach the reference
objective within tolerance, convergence flag, final relative gap, and
warm wall-time per outer iteration. On weakly correlated data every
method matches; as rho grows, full Jacobi conflicts (gap blows up or the
line search strangles the step) while the safeguarded blocked cycle
tracks the sequential chain at a fraction of its dependent steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Timer, emit
from repro.core import DGLMNETOptions, fit, lambda_max

TOL = 1e-4          # iterations-to-tolerance: rel gap vs reference optimum


def correlated_dataset(key, n, p, rho):
    """Equicorrelated-ish features: x = sqrt(1-rho)*z + sqrt(rho)*shared."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    z = jax.random.normal(k1, (n, p))
    shared = jax.random.normal(k2, (n, 1))
    X = jnp.sqrt(1 - rho) * z + jnp.sqrt(rho) * shared
    beta_true = jnp.where(jax.random.uniform(k3, (p,)) < 0.1,
                          jax.random.normal(k4, (p,)) * 3.0, 0.0)
    y = jnp.where(jax.random.uniform(jax.random.fold_in(k4, 1), (n,))
                  < jax.nn.sigmoid(X @ beta_true), 1.0, -1.0)
    return X, y


def iters_to_tol(history, f_ref, tol=TOL):
    """First outer iteration whose objective is within ``tol`` (relative)
    of the reference optimum; -1 if the run never got there."""
    for i, f in enumerate(history):
        if (f - f_ref) / abs(f_ref) < tol:
            return i
    return -1


def sweep_methods():
    """The three-way method grid: label -> DGLMNETOptions overrides."""
    grid = [("sequential", dict(method="gram"))]
    for b in (4, 8, 16, 32):
        grid.append((f"blocked-B{b}",
                     dict(method="gram", cycle_mode="blocked", block=b)))
    grid.append(("jacobi", dict(method="jacobi")))
    return grid


def run():
    key = jax.random.key(42)
    n, p = 4096, 256
    print("# rho,method,M,iters,iters_to_tol,converged,final_gap,warm_ms_per_iter")
    for rho in (0.0, 0.5, 0.9):
        X, y = correlated_dataset(jax.random.fold_in(key, int(rho * 10)), n, p, rho)
        lam = float(lambda_max(X, y)) / 32
        # reference optimum via well-converged cyclic run
        ref = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=1, method="gram",
                                                 tile=64, max_iters=200,
                                                 rel_tol=1e-10))
        for label, overrides in sweep_methods():
            for m in (1, 16):
                opts = DGLMNETOptions(num_blocks=m, tile=64, max_iters=150,
                                      **overrides)
                fit(X, y, lam, opts=opts)          # compile
                with Timer() as t:
                    res = fit(X, y, lam, opts=opts)
                    t.block = res.beta
                gap = (res.f - ref.f) / abs(ref.f)
                itt = iters_to_tol(res.objective_history, ref.f)
                per_iter_us = t.dt * 1e6 / max(res.n_iters, 1)
                print(f"# {rho},{label},{m},{res.n_iters},{itt},"
                      f"{res.converged},{gap:.2e},{per_iter_us / 1e3:.2f}")
                emit(f"ablation.rho{rho}.{label}.M{m}", per_iter_us,
                     f"iters={res.n_iters};to_tol={itt};gap={gap:.1e}")


if __name__ == "__main__":
    run()
