"""d-GLMNET (paper Algorithms 1-3): single-process implementation that
*simulates* M machines via feature blocks — bit-identical math to the
distributed version (core/distributed.py), which maps blocks onto the
`model` mesh axis.

The public entry points:

* ``dglmnet_iteration`` — one jitted outer iteration (subproblems + combine).
* ``fit`` — the device-resident outer loop: a single jitted
  ``lax.while_loop`` program built by ``core.engine.make_solver``; no
  per-iteration host synchronization (one ``device_get`` per solve).
* ``fit_python_loop`` — the seed's host-driven loop, kept as the reference
  oracle for the engine's trajectory tests and the path benchmark's
  "seed-style" baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.linesearch import f_alpha, line_search
from repro.core.objective import (
    l1_norm,
    margins,
    neg_log_likelihood,
    objective,
    working_stats,
)
from repro.core.subproblem import solve_subproblem


_CYCLE_MODES = ("sequential", "blocked", "auto")
_METHODS = ("gram", "blocked", "residual", "jacobi")


@dataclass(frozen=True)
class DGLMNETOptions:
    num_blocks: int = 1              # M simulated machines (feature blocks)
    method: str = "gram"             # gram | blocked | residual | jacobi
    tile: int = 128                  # Gram tile size (MXU-aligned)
    n_cycles: int = 1                # CD cycles per subproblem (paper: 1)
    use_kernel: bool = False         # Pallas tile kernels (interpret on CPU)
    max_iters: int = 100
    rel_tol: float = 1e-6            # relative objective decrease stop
    snap_tol: float = 1e-4           # alpha->1 snap-back tolerance (relative)
    nu: float = 1e-6
    # within-tile CD cycle: "sequential" (exact chain, the default),
    # "blocked" (semi-parallel B-wide Jacobi blocks with the Gershgorin
    # safeguard), or "auto" (kernels.prefer_blocked_cd tile-size heuristic)
    cycle_mode: str = "sequential"
    block: int = 16                  # B: coordinates per semi-parallel block
    # device-residency budget for mesh slab layouts: below the padded
    # slab byte total, work buckets stream host->device through each
    # pass (bit-identical, epoch-style copies); None = fully resident
    device_budget_bytes: Optional[int] = None

    def __post_init__(self):
        # Eager validation with actionable messages — a bad bundle used to
        # surface as a shape error from deep inside a shard_map trace.
        if self.cycle_mode not in _CYCLE_MODES:
            raise ValueError(
                f"unknown cycle_mode {self.cycle_mode!r}: expected one of "
                f"{_CYCLE_MODES} (the within-tile CD cycle flavour)"
            )
        if self.method not in _METHODS:
            raise ValueError(
                f"unknown method {self.method!r}: expected one of {_METHODS}"
            )
        if self.block < 1 or (self.block & (self.block - 1)):
            raise ValueError(
                f"block must be a power of two >= 1 (the Gershgorin "
                f"safeguard halves it down to 1), got {self.block}"
            )
        if self.tile < 1:
            raise ValueError(f"tile must be >= 1, got {self.tile}")
        if self.num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {self.num_blocks}")
        if self.n_cycles < 1:
            raise ValueError(f"n_cycles must be >= 1, got {self.n_cycles}")
        if self.max_iters < 1:
            raise ValueError(f"max_iters must be >= 1, got {self.max_iters}")
        if self.device_budget_bytes is not None \
                and self.device_budget_bytes < 1:
            raise ValueError(
                f"device_budget_bytes must be a positive byte count (or "
                f"None for fully-resident slabs), got "
                f"{self.device_budget_bytes}")


class FitState(NamedTuple):
    beta: jnp.ndarray
    m: jnp.ndarray                   # margin cache X @ beta
    f: jnp.ndarray                   # objective value


@dataclass
class FitResult:
    beta: jnp.ndarray
    f: float
    n_iters: int
    objective_history: List[float] = field(default_factory=list)
    alpha_history: List[float] = field(default_factory=list)
    unit_step_frac: float = 0.0
    converged: bool = False
    # engine.STATUS_* code; non-OK means the solve tripped a guardrail and
    # beta/f are the last certified iterate, not the final proposed step
    status: int = 0

    @property
    def nnz(self) -> int:
        return int(jnp.sum(jnp.abs(self.beta) > 0))

    @property
    def status_name(self) -> str:
        return engine.status_name(self.status)

    @property
    def ok(self) -> bool:
        return self.status == engine.STATUS_OK


def _pad_features(X, beta, num_blocks):
    p = X.shape[1]
    pad = (-p) % num_blocks
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        beta = jnp.pad(beta, (0, pad))
    return X, beta, p


def _iteration(X, y, beta, m, lam, opts: DGLMNETOptions, w=None, z=None):
    """One outer iteration: block subproblems -> combined (dbeta, dm).

    Blocks are solved with vmap — numerically identical to M machines
    solving independently (block-diagonal Hessian, paper eq. (9)).
    Un-jitted body: jitted standalone as ``dglmnet_iteration`` and traced
    into the engine's while_loop by ``fit``. The engine passes the fused
    working stats ``(w, z)`` in (one margins sweep per outer iteration);
    the standalone form computes them itself.
    """
    if w is None:
        w, z = working_stats(m, y)
    Xp, betap, p = _pad_features(X, beta, opts.num_blocks)
    n, pp = Xp.shape
    mblk = opts.num_blocks
    pb = pp // mblk

    Xb = Xp.reshape(n, mblk, pb).transpose(1, 0, 2)       # (M, n, pb)
    bb = betap.reshape(mblk, pb)

    def solve_one(Xm, bm):
        return solve_subproblem(
            Xm, w, z, bm, lam,
            method=opts.method, n_cycles=opts.n_cycles, tile=opts.tile,
            use_kernel=opts.use_kernel, cycle_mode=opts.cycle_mode,
            block=opts.block,
        )

    dbeta_b, dm_b = jax.vmap(solve_one)(Xb, bb)           # (M, pb), (M, n)
    dbeta = dbeta_b.reshape(pp)[:p]                       # "MPI_AllReduce" concat
    dm = dm_b.sum(axis=0)                                 # sum of block margins

    # grad(L)^T dbeta from margins only: (p - (y+1)/2)^T dm
    pr = jax.nn.sigmoid(m)
    grad_dot = jnp.dot(pr - (y + 1.0) * 0.5, dm)
    return dbeta, dm, grad_dot


dglmnet_iteration = jax.jit(_iteration, static_argnames=("opts",))


def _build_solver(opts: DGLMNETOptions, fault=None):
    def iteration(X, y, beta, m, lam, w, z):
        return _iteration(X, y, beta, m, lam, opts, w, z)

    return engine.make_solver(
        iteration,
        max_iters=opts.max_iters,
        rel_tol=opts.rel_tol,
        snap_tol=opts.snap_tol,
        fault=fault,
    )


@lru_cache(maxsize=64)
def _cached_solver(opts: DGLMNETOptions):
    return _build_solver(opts)


def _solver_for(opts: DGLMNETOptions):
    """One compiled while_loop program per options bundle (lam is traced,
    so a whole regularization path reuses a single compilation). When a
    ``repro.resilience`` fault plan arms an engine fault, an *uncached*
    poisoned build is returned instead — fault programs never enter (or
    evict from) the healthy cache."""
    from repro.resilience import arm_engine_fault

    fault = arm_engine_fault()
    if fault is not None:
        return _build_solver(opts, fault=fault)
    return _cached_solver(opts)


def fit(
    X,
    y,
    lam: float,
    *,
    beta0: Optional[jnp.ndarray] = None,
    opts: DGLMNETOptions = DGLMNETOptions(),
    verbose: bool = False,
) -> FitResult:
    """Paper Algorithm 1 with the Algorithm 3 line search, the paper's
    convergence criterion and sparsity snap-back — run entirely on device
    as one jitted while_loop (see core/engine.py).

    Legacy shim: delegates to the ``repro.api`` front door
    (``LogisticL1(opts).fit(DenseDesign(X), ...)``), which owns the solve
    body; results are bit-identical to the pre-API driver."""
    from repro.api import DenseDesign, LogisticL1

    return LogisticL1(opts=opts).fit(DenseDesign(X), y, lam, beta0=beta0,
                                     verbose=verbose)


def fit_python_loop(
    X,
    y,
    lam: float,
    *,
    beta0: Optional[jnp.ndarray] = None,
    opts: DGLMNETOptions = DGLMNETOptions(),
    verbose: bool = False,
) -> FitResult:
    """The seed's host-driven outer loop (one objective sync per
    iteration). Reference oracle for the engine; also the path benchmark's
    "seed-style" baseline. Same math as ``fit``."""
    n, p = X.shape
    beta = jnp.zeros(p, jnp.float32) if beta0 is None else beta0.astype(jnp.float32)
    m = margins(X, beta)
    f = objective(m, y, beta, lam)

    hist, alphas = [float(f)], []
    unit_steps = 0
    converged = False
    it = 0

    for it in range(1, opts.max_iters + 1):
        dbeta, dm, grad_dot = dglmnet_iteration(X, y, beta, m, lam, opts)
        res = line_search(m, dm, y, beta, dbeta, lam, grad_dot)
        alpha, f_new = res.alpha, res.f_new
        unit_steps += int(res.took_unit_step)
        alphas.append(float(alpha))

        rel_dec = (hist[-1] - float(f_new)) / max(abs(hist[-1]), 1e-12)
        stop = rel_dec < opts.rel_tol or it == opts.max_iters

        if stop:
            # Sparsity snap-back: prefer alpha=1 if the objective increase
            # is tolerable (keeps coordinates that landed exactly on 0).
            # The histories report the *applied* step: overwrite the
            # recorded alpha and count the promoted unit step.
            f_unit = float(f_alpha(1.0, m, dm, y, beta, dbeta, lam))
            if f_unit <= float(f_new) * (1.0 + opts.snap_tol) + 1e-12:
                if float(alpha) != 1.0:
                    unit_steps += 1
                alpha, f_new = jnp.float32(1.0), jnp.float32(f_unit)
                alphas[-1] = float(alpha)
            beta = beta + alpha * dbeta
            m = m + alpha * dm
            hist.append(float(f_new))
            converged = rel_dec < opts.rel_tol
            break

        beta = beta + alpha * dbeta
        m = m + alpha * dm
        hist.append(float(f_new))
        if verbose:
            print(f"  iter {it:3d}  f={hist[-1]:.6f}  alpha={float(alpha):.4f}")

    return FitResult(
        beta=beta,
        f=hist[-1],
        n_iters=it,
        objective_history=hist,
        alpha_history=alphas,
        unit_step_frac=unit_steps / max(it, 1),
        converged=converged,
    )
