"""Version-compat shims for JAX API surface that moved between releases.

The repo targets the modern API (``jax.shard_map``, varying-manual-axes
typing via ``vma``, ``jax.sharding.AxisType``); older installs (<= 0.4.x)
expose the same functionality under ``jax.experimental.shard_map`` with
``check_rep`` and no vma typing. Everything that touches those surfaces
goes through this module so the rest of the codebase reads as
current-API-only.
"""
from __future__ import annotations

import inspect
from typing import Optional

import jax

HAS_SHARD_MAP = hasattr(jax, "shard_map")
HAS_PCAST = hasattr(jax.lax, "pcast")
HAS_VMA = "vma" in inspect.signature(jax.ShapeDtypeStruct.__init__).parameters
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the experimental spelling.

    ``check_vma`` maps onto the old ``check_rep``; the legacy replication
    checker predates pcast/vma annotations and rejects scan carries whose
    replication changes mid-loop (exactly our residual carry), so on old
    JAX the check is disabled rather than half-translated — numerics are
    covered by the distributed-vs-local equivalence tests.
    """
    if HAS_SHARD_MAP:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def pcast_varying(x, axis_name: str):
    """Mark ``x`` as varying over ``axis_name`` (no-op before vma typing)."""
    if HAS_PCAST:
        return jax.lax.pcast(x, axis_name, to="varying")
    return x


def out_shape_struct(shape, dtype, operands=()):
    """``jax.ShapeDtypeStruct`` carrying the joint vma of ``operands``.

    Under ``shard_map(check_vma=True)`` a ``pallas_call`` out_shape must
    declare the mesh axes its outputs vary over; older JAX has neither the
    kwarg nor ``jax.typeof``, where the plain struct is correct.
    """
    if not HAS_VMA:
        return jax.ShapeDtypeStruct(shape, dtype)
    vma = frozenset()
    for operand in operands:
        try:
            vma = vma | jax.typeof(operand).vma
        except AttributeError:  # plain arrays outside shard_map
            pass
    return jax.ShapeDtypeStruct(shape, dtype, vma=vma)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict (pre-0.5 JAX returned a
    one-dict-per-device list)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the install has them."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(
            axis_shapes, axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)
