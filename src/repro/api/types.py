"""Leaf types shared by the front door and the legacy shims.

Import-order note: ``repro.core.__init__`` imports ``core.regpath`` (a
shim over :mod:`repro.api.estimator`), while the estimator imports half of
``repro.core`` — a cycle if the shim needed the full estimator at import
time. It only needs :class:`PathPoint`/:class:`PathResult`, so those live
here with no repro-internal imports at import time (``PathResult.save`` /
``load`` pull in :mod:`repro.checkpoint` lazily — itself a leaf).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclass
class PathPoint:
    """One regularization-path point (paper Algorithm 5)."""

    lam: float
    nnz: int
    f: float
    n_iters: int
    beta: jnp.ndarray
    metrics: dict = field(default_factory=dict)
    screen: dict = field(default_factory=dict)   # active-set telemetry
    # engine.STATUS_* code of the solve that produced this point (0 = OK;
    # non-OK points carry the driver's degraded/skip decision in screen)
    status: int = 0

    @property
    def ok(self) -> bool:
        return self.status == 0


@dataclass
class PathResult:
    """The certified regularization path as one typed object.

    ``LogisticL1.path`` used to return a bare ``list[PathPoint]`` that died
    with the process; this is the loss-agnostic replacement the serving
    layer (:class:`repro.serve.PathStore`) loads: the whole path's
    coefficients as ONE stacked ``(L, p)`` array (device-residency and
    sharding are one ``device_put`` away), per-lambda scalars as arrays,
    and the per-lambda metric/telemetry dicts alongside.

    List back-compat: iteration, ``len``, and integer/slice indexing yield
    :class:`PathPoint` views (``pts[-1].beta``, ``max(pts, key=...)``,
    ``zip(pts, ref)`` all keep working), so the historical list-of-points
    consumers — examples, benchmarks, the legacy ``regularization_path``
    shims — need no change.
    """

    lambdas: np.ndarray          # (L,) descending lambda grid
    betas: jnp.ndarray           # (L, p) stacked coefficients
    nnz: np.ndarray              # (L,) int64
    f: np.ndarray                # (L,) float64 objective values
    n_iters: np.ndarray          # (L,) int64
    metrics: List[dict] = field(default_factory=list)   # per-lambda eval
    screen: List[dict] = field(default_factory=list)    # active-set telemetry
    # (L,) int64 engine.STATUS_* per point; None on results loaded from
    # pre-status checkpoints (treated as all-OK)
    status: Optional[np.ndarray] = None

    @property
    def statuses(self) -> np.ndarray:
        """Per-point status codes, defaulting to all-OK for legacy data."""
        if self.status is None:
            return np.zeros(len(self), np.int64)
        return self.status

    @property
    def all_ok(self) -> bool:
        return bool(np.all(self.statuses == 0))

    # -- construction -------------------------------------------------------

    @classmethod
    def from_points(cls, points: Sequence[PathPoint]) -> "PathResult":
        """Stack a list of per-lambda points into one result."""
        pts = list(points)
        return cls(
            lambdas=np.asarray([p.lam for p in pts], np.float64),
            betas=jnp.stack([p.beta for p in pts]) if pts
            else jnp.zeros((0, 0), jnp.float32),
            nnz=np.asarray([p.nnz for p in pts], np.int64),
            f=np.asarray([p.f for p in pts], np.float64),
            n_iters=np.asarray([p.n_iters for p in pts], np.int64),
            metrics=[dict(p.metrics) for p in pts],
            screen=[dict(p.screen) for p in pts],
            status=np.asarray([p.status for p in pts], np.int64),
        )

    # -- list back-compat ---------------------------------------------------

    def __len__(self) -> int:
        return int(self.lambdas.shape[0])

    def point(self, i: int) -> PathPoint:
        """The ``i``-th path point as a :class:`PathPoint` view (the beta
        row is a view into the stacked array, not a copy)."""
        return PathPoint(
            lam=float(self.lambdas[i]), nnz=int(self.nnz[i]),
            f=float(self.f[i]), n_iters=int(self.n_iters[i]),
            beta=self.betas[i],
            metrics=self.metrics[i] if self.metrics else {},
            screen=self.screen[i] if self.screen else {},
            status=int(self.statuses[i]),
        )

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self.point(j) for j in range(len(self))[i]]
        n = len(self)
        if i < -n or i >= n:
            raise IndexError(f"path index {i} out of range for {n} points")
        return self.point(i % n)

    def __iter__(self) -> Iterator[PathPoint]:
        for i in range(len(self)):
            yield self.point(i)

    # -- lambda selection ---------------------------------------------------

    def index_of(self, lam: float) -> int:
        """Operating-point selection: the index of the stored lambda
        nearest to ``lam`` in log space (the grid is geometric, so log
        distance — not absolute — picks the intended point)."""
        if len(self) == 0:
            raise ValueError("empty path")
        lams = np.maximum(np.asarray(self.lambdas, np.float64), 1e-300)
        return int(np.argmin(np.abs(np.log(lams) - np.log(max(lam, 1e-300)))))

    # -- persistence (fit once, serve many) ---------------------------------

    def save(self, directory: str) -> str:
        """Persist via the repo checkpointer: the stacked betas as the
        array payload, everything else (lambdas, per-lambda scalars,
        metric/telemetry dicts) in the manifest's JSON meta — so a serving
        process can load the path without the training code or data."""
        from repro.checkpoint import save_pytree

        meta = {
            "kind": "PathResult",
            "lambdas": [float(v) for v in self.lambdas],
            "nnz": [int(v) for v in self.nnz],
            "f": [float(v) for v in self.f],
            "n_iters": [int(v) for v in self.n_iters],
            "metrics": [_jsonable(d) for d in self.metrics],
            "screen": [_jsonable(d) for d in self.screen],
            "status": [int(v) for v in self.statuses],
            "p": int(self.betas.shape[1]) if self.betas.ndim == 2 else 0,
            "dtype": str(self.betas.dtype),
        }
        return save_pytree({"betas": self.betas}, directory, meta=meta)

    @classmethod
    def load(cls, directory: str, *, sharding=None) -> "PathResult":
        """Inverse of :meth:`save`. ``sharding`` (a NamedSharding) places
        the stacked betas as they load — e.g. ``P(None, "model")`` to land
        them feature-sharded for a mesh :class:`~repro.serve.PathStore`."""
        from repro.checkpoint import load_pytree, read_meta

        meta = read_meta(directory)
        if meta is None or meta.get("kind") != "PathResult":
            raise ValueError(
                f"{directory} is not a PathResult checkpoint (missing or "
                f"mismatched manifest meta)"
            )
        like = {"betas": jnp.zeros((len(meta["lambdas"]), meta["p"]),
                                   jnp.dtype(meta["dtype"]))}
        shardings = None if sharding is None else {"betas": sharding}
        tree = load_pytree(directory, like, shardings=shardings)
        return cls(
            lambdas=np.asarray(meta["lambdas"], np.float64),
            betas=tree["betas"],
            nnz=np.asarray(meta["nnz"], np.int64),
            f=np.asarray(meta["f"], np.float64),
            n_iters=np.asarray(meta["n_iters"], np.int64),
            metrics=list(meta["metrics"]),
            screen=list(meta["screen"]),
            # pre-status checkpoints load as status=None (treated all-OK)
            status=(np.asarray(meta["status"], np.int64)
                    if "status" in meta else None),
        )


def _jsonable(d: Optional[dict]) -> dict:
    """Per-lambda dicts hold numpy scalars (metrics) — coerce for JSON."""
    out = {}
    for k, v in (d or {}).items():
        if isinstance(v, (np.integer,)):
            out[k] = int(v)
        elif isinstance(v, (np.floating,)):
            out[k] = float(v)
        else:
            out[k] = v
    return out
