"""Golden fixture: a JAX module with none of the lint hazards.

Jitted math with static shape arithmetic only, and a timer that blocks
on the output before stopping the clock.
"""
import time

import jax
import jax.numpy as jnp


@jax.jit
def well_behaved(x):
    return jnp.tanh(x) * x.shape[0]


def timed(fn, x):
    t0 = time.perf_counter()
    y = jax.block_until_ready(fn(x))
    return y, time.perf_counter() - t0
