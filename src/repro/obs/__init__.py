"""repro.obs — unified observability: metrics registry + trace spans.

The subsystem has three rules that every instrumented call site obeys:

1. **Disabled means free.** With no active registry/tracer the module-level
   helpers (`counter`, `gauge`, `histogram`, `span`) return shared null
   singletons whose methods are no-ops — a couple of attribute loads and a
   comparison per call site, no allocation, no locking.
2. **Timestamps only at existing sync points.** Spans wrap code that
   already synchronizes with the device (the `engine.device_get` counted
   fetch, `engine.fetch`, `np.asarray` on scores). Tracing never adds a
   device->host transfer or an XLA compile; `tests/test_sanitizers.py`
   certifies both.
3. **Legacy counters stay the source of truth.** `batcher.stats`,
   `residency_stats()` and friends are mirrored onto the registry through
   read-only callbacks (`register_callback`), never rewritten — their
   values remain bit-identical to pre-obs behavior.

Typical use::

    from repro.obs import observe

    with observe() as obs:
        est.path(design, y, path_len=20)
    obs.export("run1")          # run1.trace.json / run1.summary.json / ...
    print(obs.summary()["phases"])

`run1.trace.json` opens directly in Perfetto / chrome://tracing.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator

from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    counter,
    gauge,
    get_registry,
    histogram,
    use_registry,
)
from repro.obs.trace import Tracer, event, get_tracer, span, use_tracer
from repro.obs.export import (
    chrome_trace,
    summarize,
    write_chrome_trace,
    write_jsonl,
    write_summary,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ObsSession",
    "Tracer",
    "chrome_trace",
    "counter",
    "event",
    "gauge",
    "get_registry",
    "get_tracer",
    "histogram",
    "observe",
    "render_summary",   # lazy: resolved from repro.obs.report on access
    "span",
    "summarize",
    "use_registry",
    "use_tracer",
    "write_chrome_trace",
    "write_jsonl",
    "write_summary",
]


def __getattr__(name: str):
    # render_summary lives in repro.obs.report; importing it eagerly here
    # would shadow `python -m repro.obs.report` (runpy's found-in-
    # sys.modules warning), so resolve it lazily on attribute access
    if name == "render_summary":
        from repro.obs.report import render_summary

        return render_summary
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class ObsSession:
    """Handle on one `observe()` window: its tracer + registry + exports."""

    def __init__(self, tracer: Tracer, registry: MetricsRegistry) -> None:
        self.tracer = tracer
        self.registry = registry

    def summary(self) -> dict:
        return summarize(self.tracer, self.registry)

    def export(self, prefix: str) -> dict:
        """Write ``{prefix}.trace.json`` (Chrome trace-event format),
        ``{prefix}.events.jsonl`` and ``{prefix}.summary.json``; return
        ``{"trace": path, "events": path, "summary": path}``."""
        paths = {
            "trace": f"{prefix}.trace.json",
            "events": f"{prefix}.events.jsonl",
            "summary": f"{prefix}.summary.json",
        }
        write_chrome_trace(self.tracer, paths["trace"])
        write_jsonl(self.tracer, paths["events"])
        write_summary(self.summary(), paths["summary"])
        return paths


@contextmanager
def observe() -> Iterator[ObsSession]:
    """Activate a fresh tracer + registry for the enclosed block.

    Nestable and re-entrant: the previously active pair (if any) is
    restored on exit, so a traced benchmark can run inside a traced
    launcher without either clobbering the other.
    """
    tracer = Tracer()
    registry = MetricsRegistry()
    with use_tracer(tracer), use_registry(registry):
        yield ObsSession(tracer, registry)
