"""Regularization path (paper Algorithm 5) — warm-started, screened engine.

Find lambda_max for which beta = 0, then solve with
lambda = lambda_max * 2^{-i}, i = 1..path_len, warm-starting each solve from
the previous beta.

Beyond the seed's loop-of-fits, the engine exploits the two pieces of
path-level structure the follow-up literature (Mahajan et al. 1405.4544,
Trofimov & Genkin 1611.02101) identifies as decisive for distributed L1:

* **One compiled program for the whole path** — lam is a traced operand of
  the device-resident solver (core/engine.py), so consecutive lambdas reuse
  the same jitted while_loop; restricted problems are bucketed to
  power-of-two capacities so at most O(log(p/tile)) shapes ever compile.
* **Sequential-strong-rule screening with a KKT post-check**
  (core/screening.py) — each solve only pays for the features the strong
  rule admits at that lambda (plus warm-start support); the discarded set
  is certified optimal afterwards via the full-gradient KKT condition, and
  violators (rare) re-enter and re-solve. Large-p path points cost
  O(active) instead of O(p).

Both drivers share one strong-rule/KKT loop (:func:`_screened_point`):

* :func:`regularization_path` — single-process restricted solves
  (``core.dglmnet.fit``), dense gradient pass.
* :func:`regularization_path_distributed` — restricted solves are
  ``fit_distributed`` / ``fit_distributed_sparse`` on a mesh; the
  active-set gather becomes a feature-axis reshard into a
  capacity-bucketed P(model) layout, and with by-feature sparse slabs the
  screen streams (row_idx, values) tiles under shard_map (psum over the
  data axes) so a dense (n, p) X is never materialized anywhere — the
  paper's headline webspam regime (p = 16.6M).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.dglmnet import DGLMNETOptions, FitResult, fit
from repro.core.distributed import (
    DistributedFitResult,
    check_slab_shapes,
    fit_distributed,
    fit_distributed_sparse,
)
from repro.core.objective import lambda_max, margins, objective
from repro.core.screening import (
    budgeted_admission,
    capacity_bucket,
    gather_columns,
    kkt_violations,
    make_sparse_screen,
    nll_grad_abs,
    scatter_columns,
    strong_rule_mask,
)
from repro.data.byfeature import ByFeature, SlabBuckets, scatter_features


@dataclass
class PathPoint:
    lam: float
    nnz: int
    f: float
    n_iters: int
    beta: jnp.ndarray
    metrics: dict = field(default_factory=dict)
    screen: dict = field(default_factory=dict)   # active-set telemetry


def _lambda_grid(lmax: float, path_len: int,
                 extra_lams: Optional[List[float]]) -> List[float]:
    lams = [lmax * 2.0 ** (-i) for i in range(1, path_len + 1)]
    if extra_lams:
        lams = sorted(set(lams) | set(extra_lams), reverse=True)
    return lams


def _screened_point(p, lam, lam_prev, beta, m, *, grad_abs, restricted_solve,
                    empty_result, cap_tile, kkt_tol, max_kkt_rounds,
                    prev_mask=None, violation_budget: Optional[int] = 512):
    """One path point of the strong-rule/KKT loop, solver-agnostic.

    ``grad_abs(m) -> |g|`` is the full-gradient pass (dense matvec or the
    sharded slab stream); ``restricted_solve(mask, cap, beta) -> (res,
    beta_full, m_full)`` solves the capacity-``cap`` restricted problem
    warm-started from ``beta``. Only the active-set and violation *counts*
    are synced to host (to pick the capacity bucket and decide
    termination) — the solves themselves stay device-resident.

    Blitz-style dynamic working-set growth (Johnson & Guestrin; ROADMAP
    follow-on): ``prev_mask`` carries the working set across path points
    instead of resetting it to the strong rule each lambda — previously
    admitted violators that solved to zero would otherwise be dropped,
    violate again at the next lambda, and cost a re-solve round. Within a
    point, violators re-enter under a per-round budget of
    ``min(violation_budget, 2 * |A|)`` (the strongest first), so one bad
    screen can't blow the capacity bucket up a power-of-two step. The final
    certification is unchanged: the loop only exits on a clean KKT pass
    over everything outside the working set (the penultimate round lifts
    the budget so certification can always complete within
    ``max_kkt_rounds``). Returns the certified mask alongside the result
    for the driver to carry.
    """
    g_abs = grad_abs(m)
    mask = strong_rule_mask(g_abs, lam, lam_prev, beta)
    if prev_mask is not None:
        mask = jnp.logical_or(mask, prev_mask)

    res = None
    rounds = 0
    cap = 0
    deferred = 0
    for rounds in range(1, max_kkt_rounds + 1):
        count = int(mask.sum())
        if count == 0:
            # empty working set: beta stays 0 (strong rule + no support)
            beta_new, m_new = beta, m
            res = empty_result(beta)
        else:
            cap = capacity_bucket(count, p, tile=cap_tile)
            res, beta_new, m_new = restricted_solve(mask, cap, beta)
        g_abs = grad_abs(m_new)
        viol = kkt_violations(g_abs, lam, mask, tol=kkt_tol)
        n_viol = int(viol.sum())
        if n_viol == 0:
            break
        if violation_budget is not None and rounds < max_kkt_rounds - 1:
            budget = min(violation_budget, 2 * max(count, 1))
            admitted = budgeted_admission(viol, g_abs, budget)
            # ties at the cutoff may admit more than the budget — count
            # what actually stayed out, not the nominal overflow
            deferred += n_viol - int(admitted.sum())
        else:
            admitted = viol                       # safety valve: admit all
        mask = jnp.logical_or(mask, admitted)     # violators re-enter
        beta, m = beta_new, m_new                 # keep this round's progress
    else:
        raise RuntimeError(
            f"KKT check failed to certify within {max_kkt_rounds} rounds "
            f"at lambda={lam} (last violation count > 0)"
        )

    info = {"active": int(mask.sum()), "capacity": cap, "kkt_rounds": rounds,
            "deferred": deferred}
    return res, beta_new, m_new, info, mask


def _fit_screened(X, y, lam, lam_prev, beta, m, opts, *, kkt_tol,
                  max_kkt_rounds, prev_mask=None, violation_budget=512):
    """Single-process path point: strong-rule restricted ``fit`` + KKT
    certification. Returns (res, beta_full, m_full, info, mask)."""
    n, p = X.shape

    def grad_abs(m_cur):
        return nll_grad_abs(X, y, m_cur)

    def restricted_solve(mask, cap, beta_cur):
        X_sub, beta_sub, idx = gather_columns(X, beta_cur, mask, cap)
        res = fit(X_sub, y, lam, beta0=beta_sub, opts=opts)
        beta_full = scatter_columns(res.beta, idx, p)
        return res, beta_full, X_sub @ res.beta   # == X @ beta_full (pads 0)

    def empty_result(beta_cur):
        return FitResult(beta=beta_cur, f=float("nan"), n_iters=0,
                         objective_history=[], alpha_history=[])

    return _screened_point(
        p, lam, lam_prev, beta, m, grad_abs=grad_abs,
        restricted_solve=restricted_solve, empty_result=empty_result,
        cap_tile=opts.tile, kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
        prev_mask=prev_mask, violation_budget=violation_budget,
    )


def regularization_path(
    X,
    y,
    *,
    path_len: int = 20,
    opts: DGLMNETOptions = DGLMNETOptions(),
    eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
    extra_lams: Optional[List[float]] = None,
    verbose: bool = False,
    screen: bool = True,
    kkt_tol: float = 1e-3,
    max_kkt_rounds: int = 8,
    carry_working_set: bool = True,
    violation_budget: Optional[int] = 512,
) -> List[PathPoint]:
    """Returns one PathPoint per lambda (decreasing). ``eval_fn(beta)``
    computes test metrics (e.g. AUPRC) per point — the paper's Figure 1.

    ``screen=True`` (default) runs the strong-rule/KKT engine; ``False``
    reproduces the seed's full-p warm-started loop (the oracle the
    screening tests compare against). ``carry_working_set`` grows the
    working set blitz-style across path points (the certified set at each
    lambda seeds the next) instead of resetting to the strong rule;
    ``violation_budget`` caps per-round violator admission at
    ``min(budget, 2 * |A|)``. Both cut re-solve rounds near the dense end
    of the path; set ``carry_working_set=False, violation_budget=None``
    for the pre-blitz reset-every-lambda behaviour.
    """
    lmax = float(lambda_max(X, y))
    lams = _lambda_grid(lmax, path_len, extra_lams)

    n, p = X.shape
    beta = jnp.zeros(p, jnp.float32)
    m = jnp.zeros(n, jnp.float32)
    lam_prev = lmax
    carry_mask = None
    points: List[PathPoint] = []
    for lam in lams:
        if screen:
            res, beta, m, info, mask = _fit_screened(
                X, y, lam, lam_prev, beta, m, opts,
                kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
                prev_mask=carry_mask, violation_budget=violation_budget,
            )
            if carry_working_set:
                carry_mask = mask
        else:
            res = fit(X, y, lam, beta0=beta, opts=opts)
            beta = res.beta
            m = margins(X, beta)
            info = {}
        lam_prev = lam
        nnz = int(jnp.sum(jnp.abs(beta) > 0))
        f = float(res.f) if res.n_iters else float(objective(m, y, beta, lam))
        metrics = eval_fn(beta) if eval_fn else {}
        points.append(
            PathPoint(lam=lam, nnz=nnz, f=f, n_iters=res.n_iters,
                      beta=beta, metrics=metrics, screen=info)
        )
        if verbose:
            print(
                f"lambda={lam:10.4f} nnz={nnz:6d} f={points[-1].f:12.4f} "
                f"iters={res.n_iters:3d} {info} {metrics}"
            )
    return points


def regularization_path_distributed(
    data,
    y,
    mesh,
    *,
    path_len: int = 20,
    opts: DGLMNETOptions = DGLMNETOptions(),
    eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
    extra_lams: Optional[List[float]] = None,
    verbose: bool = False,
    kkt_tol: float = 1e-3,
    max_kkt_rounds: int = 8,
    carry_working_set: bool = True,
    violation_budget: Optional[int] = 512,
) -> List[PathPoint]:
    """The screened path with every restricted solve on the mesh
    (Algorithm 5 run distributed — the paper's webspam-scale regime).
    ``carry_working_set`` / ``violation_budget`` are the blitz-style
    working-set growth knobs shared with :func:`regularization_path`.

    ``data`` is either a dense (n, p) X (restricted solves are
    ``fit_distributed``), a :class:`~repro.data.byfeature.ByFeature`, a
    pre-built ``(row_idx, values)`` slab pair of shape (p, DP, K) with
    local row indices, or an nnz-bucketed
    :class:`~repro.data.byfeature.SlabBuckets` layout (restricted solves
    are ``fit_distributed_sparse``). In the sparse forms the
    strong-rule/KKT gradient passes stream the slabs under shard_map
    (``core.screening.make_sparse_screen``, per capacity class when
    bucketed) and the active-set gather/scatter operates on slabs
    (``data.byfeature.gather_features``), so no dense (n, p) X is ever
    materialized on host. Restricted solves additionally trim the slab
    capacity axis to the working set's own power-of-two K class
    (``data.byfeature.k_class``): light working sets stop paying the
    power-law head's global max-nnz padding, and sufficiently sparse ones
    drop into the sparse-native slab kernels
    (``kernels.slab_gram``/``slab_spmv``) instead of densifying.

    The active-set gather is the feature-axis reshard: the working set's
    columns/slabs are packed into a capacity-bucketed P(model) layout
    (``capacity_bucket`` with tile ``model_dim * opts.tile``, so restricted
    shapes stay mesh-aligned and at most O(log(p/tile)) programs compile),
    and the restricted solution is scattered back to the full feature axis.
    """
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import _data_axes, _data_extent

    daxes = _data_axes(mesh)
    ddim = _data_extent(mesh)
    mdim = mesh.shape["model"]
    cap_tile = mdim * opts.tile
    n = y.shape[0]

    known_packed = not isinstance(data, tuple)   # our own builders pack
    if isinstance(data, ByFeature):
        from repro.data.byfeature import to_slabs

        if data.n != n:
            raise ValueError(f"ByFeature has n={data.n} but len(y)={n}")
        row_idx, values, _ = to_slabs(data, ddim)
        data = (row_idx, values)

    if isinstance(data, tuple):
        # a flat (row_idx, values) pair is exactly a one-bucket layout;
        # wrapping it keeps a single screened sparse driver below (the
        # per-bucket loop runs the full shape/row-range validation)
        row_idx, values = data
        n_loc_flat = n // max(ddim, 1)
        if known_packed:
            front_packed = True
        else:
            # user-built slabs may interleave sentinel and live slots
            # (nothing before this PR required packing); the k_cap trim
            # slices the K axis positionally, so only front-packed slabs
            # (what to_slabs emits) are eligible — otherwise solve at the
            # full capacity
            valid = row_idx < n_loc_flat
            front_packed = bool(jnp.all(valid[..., 1:] <= valid[..., :-1]))
        data = SlabBuckets(
            buckets=((row_idx, values,
                      np.arange(row_idx.shape[0], dtype=np.int64)),),
            n_loc=n_loc_flat, p=row_idx.shape[0])
    else:
        # to_slab_buckets front-packs by construction; hand-built
        # SlabBuckets must honor the invariant documented on the class
        front_packed = True

    sparse = isinstance(data, SlabBuckets)
    to_output = None                   # work-axis beta -> original order
    if sparse:
        from repro.data.byfeature import gather_features_buckets, k_class

        slabs: SlabBuckets = data
        slab_sharding = NamedSharding(mesh, P("model", daxes, None))
        vsharding = NamedSharding(mesh, P(daxes))
        n_loc = slabs.n_loc
        work_buckets = []
        feat_map_parts = []
        k_arr_parts = []
        for r_b, v_b, fid in slabs.buckets:
            if check_slab_shapes(r_b, v_b, mesh, n) != n_loc:
                raise ValueError("bucket n_loc inconsistent with mesh/n")
            # pad each bucket's feature axis so the streaming screen's
            # tile walk and every capacity bucket stay mesh-aligned;
            # all-sentinel slabs have zero gradient and are never admitted
            pad_b = (-r_b.shape[0]) % cap_tile
            if pad_b:
                r_b = jnp.pad(r_b, ((0, pad_b), (0, 0), (0, 0)),
                              constant_values=n_loc)
                v_b = jnp.pad(v_b, ((0, pad_b), (0, 0), (0, 0)))
            # k per feature on host *before* the slabs land sharded (and
            # feature-axis concats below stay off-mesh: concatenating
            # P(model)-sharded pieces of different lengths miscompiles on
            # current JAX, so per-bucket screen outputs are resharded to
            # replicated first — they are O(p) vectors the driver's
            # elementwise mask math wants replicated anyway)
            k_arr_parts.append(
                np.asarray((r_b < n_loc).sum(axis=-1).max(axis=-1)))
            r_b = jax.device_put(r_b, slab_sharding)
            v_b = jax.device_put(v_b, slab_sharding)
            work_buckets.append((r_b, v_b, fid))
            feat_map_parts.append(np.concatenate([
                np.asarray(fid, np.int32),
                np.full(pad_b, slabs.p, np.int32)]))
        slabs_work = SlabBuckets(tuple(work_buckets), n_loc, slabs.p)
        p = slabs.p
        p_work = sum(b[0].shape[0] for b in work_buckets)
        feat_map = jnp.asarray(np.concatenate(feat_map_parts))  # sentinel p
        k_arr = jnp.asarray(np.concatenate(k_arr_parts))
        k_max = max(slabs_work.k_classes)
        y = jax.device_put(y, vsharding)
        screen_fn = make_sparse_screen(mesh, n_loc, opts.tile)
        rsharding = NamedSharding(mesh, P())

        def grad_abs(m_cur):
            return jnp.concatenate([
                jax.device_put(screen_fn(r_b, v_b, y, m_cur), rsharding)
                for r_b, v_b, _ in work_buckets])

        def make_restricted_solve(lam):
            def restricted_solve(mask, cap, beta_cur):
                # slab-capacity class of this working set: heavy features
                # only make a solve pay for K they actually carry
                if front_packed:
                    k_need = int(jnp.max(jnp.where(mask, k_arr, 0)))
                    k_cap = k_class(k_need, k_max)
                else:
                    k_cap = k_max
                rows_sub, vals_sub, beta_sub, idx = gather_features_buckets(
                    slabs_work, beta_cur, mask, cap, k_cap)
                res = fit_distributed_sparse(
                    rows_sub, vals_sub, y, lam, mesh, beta0=beta_sub,
                    opts=opts)
                return res, scatter_features(res.beta, idx, p_work), res.m
            return restricted_solve

        def to_output(beta_work):
            # bucket-permuted work axis -> original feature ids (padding
            # rows dropped via the sentinel-p scatter)
            return jnp.zeros(p, beta_work.dtype).at[feat_map].set(
                beta_work, mode="drop")

        m = jax.device_put(jnp.zeros(n, jnp.float32), vsharding)
        # at beta = 0 the NLL gradient is -0.5 * X^T y, so the sparse
        # screen pass at zero margins *is* lambda_max — no dense X needed
        lmax = float(jnp.max(grad_abs(m)))
    else:
        X = data
        if X.shape[0] != n:
            raise ValueError(f"X rows {X.shape[0]} != len(y) {n}")
        p = p_work = X.shape[1]

        def grad_abs(m_cur):
            return nll_grad_abs(X, y, m_cur)

        def make_restricted_solve(lam):
            def restricted_solve(mask, cap, beta_cur):
                X_sub, beta_sub, idx = gather_columns(X, beta_cur, mask, cap)
                res = fit_distributed(X_sub, y, lam, mesh, beta0=beta_sub,
                                      opts=opts)
                return res, scatter_columns(res.beta, idx, p_work), res.m
            return restricted_solve

        m = jnp.zeros(n, jnp.float32)
        lmax = float(lambda_max(X, y))

    def empty_result(beta_cur):
        return DistributedFitResult(beta=beta_cur, f=float("nan"), n_iters=0,
                                    objective_history=[])

    lams = _lambda_grid(lmax, path_len, extra_lams)
    beta = jnp.zeros(p_work, jnp.float32)
    lam_prev = lmax
    carry_mask = None
    points: List[PathPoint] = []
    for lam in lams:
        res, beta, m, info, mask = _screened_point(
            p_work, lam, lam_prev, beta, m, grad_abs=grad_abs,
            restricted_solve=make_restricted_solve(lam),
            empty_result=empty_result, cap_tile=cap_tile,
            kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
            prev_mask=carry_mask, violation_budget=violation_budget,
        )
        if carry_working_set:
            carry_mask = mask
        lam_prev = lam
        beta_out = to_output(beta) if to_output is not None else beta[:p]
        nnz = int(jnp.sum(jnp.abs(beta_out) > 0))
        f = float(res.f) if res.n_iters else float(objective(m, y, beta, lam))
        metrics = eval_fn(beta_out) if eval_fn else {}
        points.append(
            PathPoint(lam=lam, nnz=nnz, f=f, n_iters=res.n_iters,
                      beta=beta_out, metrics=metrics, screen=info)
        )
        if verbose:
            print(
                f"lambda={lam:10.4f} nnz={nnz:6d} f={points[-1].f:12.4f} "
                f"iters={res.n_iters:3d} {info} {metrics}"
            )
    return points
