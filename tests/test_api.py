"""repro.api front door: Design protocol equivalence across layouts,
shim-vs-estimator bit-identity for the legacy entry points (local flavors;
mesh flavors in test_api_mesh.py), strategy/options validation, and the
one-lambda_max satellite."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    BucketedSlabDesign,
    DenseDesign,
    Design,
    LogisticL1,
    ShardedDesign,
    SlabDesign,
    as_design,
    lambda_max_design,
    make_design_eval,
    resolve,
)
from repro.configs.base import GLMConfig
from repro.core import DGLMNETOptions, fit, lambda_max, regularization_path
from repro.data.byfeature import SlabBuckets, to_by_feature, to_slab_buckets
from repro.data.synthetic import make_glm_dataset


@pytest.fixture(scope="module")
def api_glm():
    cfg = GLMConfig(name="api", num_examples=640, num_features=96,
                    density=0.25)
    return make_glm_dataset(cfg, jax.random.key(5))


def _designs(X):
    """One design per layout over the same matrix."""
    bf = to_by_feature(X)
    return {
        "dense": DenseDesign(X),
        "slab": SlabDesign.from_by_feature(bf),
        "slab-dp2": SlabDesign.from_by_feature(bf, dp=2),
        "bucketed": BucketedSlabDesign.from_by_feature(bf, dp=2),
    }


# ---------------------------------------------------------------------------
# Design protocol equivalence across layouts
# ---------------------------------------------------------------------------

def test_designs_satisfy_protocol(api_glm):
    for name, d in _designs(api_glm.X_train).items():
        assert isinstance(d, Design), name
        assert d.shape == tuple(api_glm.X_train.shape), name


def test_correlation_matches_dense_across_layouts(api_glm):
    X = api_glm.X_train
    v = jax.random.normal(jax.random.key(1), (X.shape[0],))
    ref = np.asarray(X.T @ v)
    for name, d in _designs(X).items():
        got = np.asarray(d.correlation(v))
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3,
                                   err_msg=name)


def test_margins_matches_dense_across_layouts(api_glm):
    X = api_glm.X_train
    beta = jax.random.normal(jax.random.key(2), (X.shape[1],)) * 0.1
    ref = np.asarray(X @ beta)
    for name, d in _designs(X).items():
        np.testing.assert_allclose(np.asarray(d.margins(beta)), ref,
                                   rtol=1e-4, atol=1e-4, err_msg=name)


def test_gram_tile_matches_dense_across_layouts(api_glm):
    X = api_glm.X_train
    n = X.shape[0]
    w = jax.nn.sigmoid(jax.random.normal(jax.random.key(3), (n,))) * 0.25
    r = jax.random.normal(jax.random.key(4), (n,))
    G_ref, c_ref = _designs(X)["dense"].gram_tile(w, r, 32, 16)
    for name, d in _designs(X).items():
        G, c = d.gram_tile(w, r, 32, 16)
        np.testing.assert_allclose(np.asarray(G), np.asarray(G_ref),
                                   rtol=1e-4, atol=1e-3, err_msg=name)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   rtol=1e-4, atol=1e-3, err_msg=name)


def test_gather_scatter_roundtrip_across_layouts(api_glm):
    X = api_glm.X_train
    p = X.shape[1]
    beta = jax.random.normal(jax.random.key(6), (p,))
    mask = jnp.arange(p) % 3 == 0
    for name, d in _designs(X).items():
        sub, beta_sub, idx = d.gather(beta, mask, 64)
        assert sub.shape[1] == 64, name
        # restricted margins == masked full margins (padding is inert)
        m_sub = np.asarray(sub.margins(beta_sub))
        m_ref = np.asarray(X @ jnp.where(mask, beta, 0.0))
        np.testing.assert_allclose(m_sub, m_ref, rtol=1e-4, atol=1e-4,
                                   err_msg=name)
        # scatter restores exactly the masked coefficients, original order
        back = np.asarray(d.scatter(beta_sub, idx))
        np.testing.assert_allclose(
            back, np.asarray(jnp.where(mask, beta, 0.0)), rtol=1e-6,
            atol=1e-7, err_msg=name)


def test_as_design_coercions(api_glm):
    X = api_glm.X_train
    bf = to_by_feature(X)
    assert as_design(X).layout == "dense"
    assert as_design(bf).layout == "slab"
    assert as_design(to_slab_buckets(bf, 2)).layout == "bucketed"
    d = as_design((bf.row_idx[:, None, :], bf.values[:, None, :]),
                  n=X.shape[0])
    assert d.layout == "slab" and d.front_packed
    # sentinels not front-packed (here: K axis reversed) are detected and
    # disable the positional K trim
    ri = np.asarray(bf.row_idx)[:, ::-1]
    vv = np.asarray(bf.values)[:, ::-1]
    d2 = as_design((jnp.asarray(ri)[:, None, :], jnp.asarray(vv)[:, None, :]),
                   n=X.shape[0])
    assert not d2.front_packed
    with pytest.raises(TypeError):
        as_design({"not": "a design"})
    with pytest.raises(ValueError):
        as_design((bf.row_idx, bf.values))      # slabs need n=


# ---------------------------------------------------------------------------
# satellite: one lambda_max, Design.correlation-based
# ---------------------------------------------------------------------------

def test_lambda_max_dense_equals_slab(api_glm):
    X, y = api_glm.X_train, api_glm.y_train
    ref = float(lambda_max(X, y))
    for name, d in _designs(X).items():
        got = float(lambda_max_design(d, y))
        assert got == pytest.approx(ref, rel=1e-5), name
    # the dense entry point and the design helper are bit-identical
    assert float(lambda_max_design(DenseDesign(X), y)) == ref


# ---------------------------------------------------------------------------
# shim-vs-front-door bit-identity (local entry points)
# ---------------------------------------------------------------------------

def test_fit_shim_bit_identical(api_glm):
    X, y = api_glm.X_train, api_glm.y_train
    lam = float(lambda_max(X, y)) / 16
    opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=30)
    legacy = fit(X, y, lam, opts=opts)
    front = LogisticL1(opts=opts).fit(DenseDesign(X), y, lam)
    assert bool(jnp.all(legacy.beta == front.beta))
    assert legacy.f == front.f
    assert legacy.n_iters == front.n_iters
    assert legacy.alpha_history == front.alpha_history
    assert legacy.objective_history == front.objective_history
    assert legacy.unit_step_frac == front.unit_step_frac
    assert legacy.converged == front.converged


def test_regularization_path_shim_bit_identical(api_glm):
    X, y = api_glm.X_train, api_glm.y_train
    opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=40)
    legacy = regularization_path(X, y, path_len=5, opts=opts)
    front = LogisticL1(opts=opts).path(DenseDesign(X), y, path_len=5)
    assert len(legacy) == len(front) == 5
    for a, b in zip(legacy, front):
        assert a.lam == b.lam and a.f == b.f and a.nnz == b.nnz
        assert a.n_iters == b.n_iters and a.screen == b.screen
        assert bool(jnp.all(a.beta == b.beta))


def test_local_slab_path_matches_dense(api_glm):
    """The front door's local slab/bucketed paths land on the dense path's
    solutions — a capability no legacy entry point had."""
    X, y = api_glm.X_train, api_glm.y_train
    opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=60, rel_tol=1e-7)
    ref = LogisticL1(opts=opts).path(DenseDesign(X), y, path_len=4)
    for name in ("slab", "bucketed"):
        pts = LogisticL1(opts=opts).path(_designs(X)[name], y, path_len=4)
        for pr, pb in zip(ref, pts):
            rel = abs(pb.f - pr.f) / max(abs(pr.f), 1e-9)
            assert rel < 1e-4, (name, pb.lam, pb.f, pr.f)
            np.testing.assert_allclose(np.asarray(pb.beta),
                                       np.asarray(pr.beta),
                                       rtol=1e-2, atol=1e-3)


def test_warm_start_estimator(api_glm):
    X, y = api_glm.X_train, api_glm.y_train
    lam = float(lambda_max(X, y)) / 8
    opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=60)
    est = LogisticL1(opts=opts, warm_start=True)
    est.fit(DenseDesign(X), y, lam)
    cold_iters = est.fit(DenseDesign(X), y, lam / 2, beta0=jnp.zeros(
        X.shape[1], jnp.float32)).n_iters
    est.fit(DenseDesign(X), y, lam)
    warm_iters = est.fit(DenseDesign(X), y, lam / 2).n_iters
    assert warm_iters <= cold_iters


def test_streamed_eval_matches_host_eval(api_glm):
    from repro.train.metrics import glm_eval_fn

    X, y = api_glm.X_train, api_glm.y_train
    Xt, yt = api_glm.X_test, api_glm.y_test
    beta = jax.random.normal(jax.random.key(9), (X.shape[1],)) * 0.1
    host = glm_eval_fn(Xt, yt)(beta)
    streamed = make_design_eval(SlabDesign.from_dense(Xt), yt)(beta)
    assert set(host) == set(streamed)
    for k in host:
        assert host[k] == pytest.approx(streamed[k], rel=1e-4, abs=1e-5), k


# ---------------------------------------------------------------------------
# satellite: early validation
# ---------------------------------------------------------------------------

def test_options_validation_messages():
    with pytest.raises(ValueError, match="unknown cycle_mode"):
        DGLMNETOptions(cycle_mode="bogus")
    with pytest.raises(ValueError, match="power of two"):
        DGLMNETOptions(block=12)
    with pytest.raises(ValueError, match="unknown method"):
        DGLMNETOptions(method="nope")
    with pytest.raises(ValueError, match="tile must be"):
        DGLMNETOptions(tile=0)
    with pytest.raises(ValueError, match="max_iters"):
        DGLMNETOptions(max_iters=0)


def test_resolver_validation_and_auto_cycle(api_glm):
    X = api_glm.X_train
    with pytest.raises(ValueError, match="divide tile"):
        resolve(DenseDesign(X),
                DGLMNETOptions(cycle_mode="blocked", tile=40, block=16))
    # auto resolves to a concrete mode via the tile-size heuristic
    strat = resolve(DenseDesign(X),
                    DGLMNETOptions(cycle_mode="auto", tile=128, block=16))
    assert strat.opts.cycle_mode == "blocked"
    strat = resolve(DenseDesign(X),
                    DGLMNETOptions(cycle_mode="auto", tile=16, block=16))
    assert strat.opts.cycle_mode == "sequential"
    assert strat.execution == "local" and strat.solver == "dense"


def test_sharded_design_requires_model_axis(api_glm):
    from repro.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="'model' axis"):
        ShardedDesign(DenseDesign(api_glm.X_train), mesh)


# ---------------------------------------------------------------------------
# hypothesis: layout equivalence over random sparse matrices
# ---------------------------------------------------------------------------

def test_layout_equivalence_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0.05, 0.3, 0.9]),
           st.sampled_from([1, 2, 4]))
    def run(seed, density, dp):
        rng = np.random.default_rng(seed)
        n, p = 32 * dp, 24
        X = rng.standard_normal((n, p)).astype(np.float32)
        X *= rng.random((n, p)) < density
        X = jnp.asarray(X)
        v = jnp.asarray(rng.standard_normal(n).astype(np.float32))
        beta = jnp.asarray(rng.standard_normal(p).astype(np.float32))
        ref_c = np.asarray(X.T @ v)
        ref_m = np.asarray(X @ beta)
        bf = to_by_feature(X)
        for d in (SlabDesign.from_by_feature(bf, dp),
                  BucketedSlabDesign.from_by_feature(bf, dp)):
            np.testing.assert_allclose(np.asarray(d.correlation(v)), ref_c,
                                       rtol=1e-3, atol=1e-3)
            np.testing.assert_allclose(np.asarray(d.margins(beta)), ref_m,
                                       rtol=1e-3, atol=1e-3)
            np.testing.assert_allclose(np.asarray(d.densify()),
                                       np.asarray(X), atol=1e-6)

    run()
