"""End-to-end behaviour tests for the paper's system.

The headline claim (Figure 1): across the regularization path, d-GLMNET
dominates distributed online learning via truncated gradient on testing
quality at comparable sparsity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLMConfig
from repro.core import (
    DGLMNETOptions,
    TGOptions,
    lambda_max,
    regularization_path,
    truncated_gradient_fit,
)
from repro.data.synthetic import make_glm_dataset
from repro.train.metrics import auprc, glm_eval_fn


import pytest


@pytest.mark.slow
def test_regularization_path_and_figure1_dominance():
    cfg = GLMConfig(name="sys", num_examples=4096, num_features=256, density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    X, y = ds.X_train, ds.y_train

    pts = regularization_path(
        X, y, path_len=8,
        opts=DGLMNETOptions(num_blocks=8, tile=32, max_iters=40),
        eval_fn=glm_eval_fn(ds.X_test, ds.y_test))
    assert len(pts) == 8
    # nnz grows (weakly) as lambda decreases
    nnzs = [p.nnz for p in pts]
    assert nnzs == sorted(nnzs)
    best_dglmnet = max(p.metrics["auprc"] for p in pts)

    # truncated-gradient baseline, best over a small parameter sweep
    lam = float(lambda_max(X, y)) / 64
    best_tg = 0.0
    for lr in (0.1, 0.5):
        snaps = truncated_gradient_fit(
            X, y, lam,
            opts=TGOptions(num_machines=8, passes=6, learning_rate=lr),
            key=jax.random.key(1))
        for _, b in snaps:
            best_tg = max(best_tg, auprc(ds.X_test @ b, ds.y_test))

    # the paper's Figure-1 conclusion, qualitatively
    assert best_dglmnet >= best_tg - 0.02, (best_dglmnet, best_tg)
    # and the model is genuinely predictive
    assert best_dglmnet > 0.7


def test_path_quality_tracks_true_support():
    """With enough signal the path recovers most of the true support."""
    cfg = GLMConfig(name="sys2", num_examples=4096, num_features=128, density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(3), k_true=8, label_noise=0.0)
    X, y = ds.X_train, ds.y_train
    pts = regularization_path(
        X, y, path_len=10, opts=DGLMNETOptions(num_blocks=4, tile=32, max_iters=40))
    true_support = set(np.flatnonzero(np.abs(np.asarray(ds.beta_true)) > 0))
    best_recall = 0.0
    for p in pts:
        sel = set(np.flatnonzero(np.abs(np.asarray(p.beta)) > 1e-6))
        if sel:
            best_recall = max(best_recall, len(sel & true_support) / len(true_support))
    assert best_recall >= 0.75
