"""Quick dev sanity: one forward/prefill/decode per smoke arch."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import MODEL_CONFIGS
from repro.models import forward, init_cache, init_params

only = sys.argv[1:] or list(MODEL_CONFIGS)

for name in only:
    cfg = MODEL_CONFIGS[name].smoke()
    key = jax.random.key(0)
    params = init_params(key, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    b, s = 2, 64
    inputs = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend.kind != "none" and not cfg.encdec.enabled:
        inputs["patch_embeds" if cfg.frontend.kind == "vision_patches" else "frame_embeds"] = (
            jnp.ones((b, cfg.frontend.tokens_per_item, cfg.frontend.embed_dim), jnp.float32)
        )
    if cfg.encdec.enabled:
        inputs["frame_embeds"] = jnp.ones((b, 32, cfg.frontend.embed_dim), jnp.float32)

    logits, _, aux = forward(params, inputs, cfg, mode="train")
    assert not bool(jnp.isnan(logits).any()), f"{name}: NaN logits"

    # decode one token against a small cache
    cache = init_cache(cfg, b, 128)
    tok = inputs["tokens"][:, :1]
    dec_in = {"tokens": tok}
    logits_d, new_cache, _ = forward(
        params, dec_in, cfg, mode="decode", cache=cache,
        cache_index=jnp.asarray(5, jnp.int32),
    )
    assert not bool(jnp.isnan(logits_d).any()), f"{name}: NaN decode"
    print(f"OK {name:26s} params={n:,} logits={logits.shape} decode={logits_d.shape} aux={sorted(aux)}")
