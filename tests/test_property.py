"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import soft_threshold, working_stats
from repro.core.linesearch import f_alpha
from repro.core.objective import P_EPS, W_MIN, neg_log_likelihood

# ranges bounded to keep float32 rounding away from the exact-arithmetic
# assertions (at |x| ~ 1e6, eps(f32) > typical thresholds)
finite_f = st.floats(-1e4, 1e4, allow_nan=False, allow_infinity=False)


@given(x=finite_f, a=st.floats(0, 1e4, allow_nan=False))
@settings(deadline=None)
def test_soft_threshold_properties(x, a):
    t = float(soft_threshold(jnp.float32(x), jnp.float32(a)))
    # shrinkage: |T(x,a)| <= |x|, and exact zero inside the threshold
    assert abs(t) <= abs(x) * (1 + 1e-6) + 1e-3
    xf, af = float(jnp.float32(x)), float(jnp.float32(a))
    if abs(xf) <= af:
        assert t == 0.0
    else:
        # sign preserved, magnitude reduced by exactly a (within fp)
        assert np.sign(t) == np.sign(xf)
        np.testing.assert_allclose(abs(t), abs(xf) - af, rtol=1e-4, atol=1e-3)


@given(m=st.lists(st.floats(-50, 50), min_size=1, max_size=64),
       signs=st.lists(st.booleans(), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_working_stats_bounds(m, signs):
    n = min(len(m), len(signs))
    mm = jnp.asarray(m[:n], jnp.float32)
    yy = jnp.where(jnp.asarray(signs[:n]), 1.0, -1.0)
    w, z = working_stats(mm, yy)
    w_np = np.asarray(w)
    # 0 < w <= 1/4 (+clamp floor)
    assert (w_np >= W_MIN - 1e-9).all()
    assert (w_np <= 0.25 + 1e-6).all()
    # z is finite thanks to the probability clamp
    assert np.isfinite(np.asarray(z)).all()
    # w*z = ytilde - p  (the classic identity)
    p = np.clip(jax.nn.sigmoid(mm), P_EPS, 1 - P_EPS)
    np.testing.assert_allclose(
        w_np * np.asarray(z), np.asarray((yy + 1) / 2 - p), atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_objective_convex_along_direction(seed):
    """f(alpha) = NLL(m + a dm) + lam|beta + a dbeta|_1 is convex on [0,1]:
    midpoint below chord."""
    key = jax.random.key(seed)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    n, p = 64, 16
    m = jax.random.normal(k1, (n,))
    dm = jax.random.normal(k2, (n,))
    y = jnp.sign(jax.random.normal(k3, (n,)))
    beta = jax.random.normal(k4, (p,))
    dbeta = jax.random.normal(k5, (p,))
    lam = 0.5
    f0 = float(f_alpha(0.0, m, dm, y, beta, dbeta, lam))
    f1 = float(f_alpha(1.0, m, dm, y, beta, dbeta, lam))
    fm = float(f_alpha(0.5, m, dm, y, beta, dbeta, lam))
    assert fm <= 0.5 * (f0 + f1) + 1e-3 * (abs(f0) + abs(f1))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_nll_nonnegative_and_margin_monotone(seed):
    key = jax.random.key(seed)
    m = jax.random.normal(key, (32,)) * 3
    y = jnp.sign(m) * jnp.where(jax.random.uniform(jax.random.fold_in(key, 1), (32,)) < 0.8, 1.0, -1.0)
    nll = float(neg_log_likelihood(m, y))
    assert nll >= 0.0
    # scaling margins toward correct labels cannot increase NLL
    nll2 = float(neg_log_likelihood(m + 0.1 * y, y))
    assert nll2 <= nll + 1e-5


@st.composite
def slab_cases(draw):
    """Random ragged slabs: duplicate rows within a feature, empty
    features, sentinel padding, non-128-multiple tiles, and n_loc both
    above and below the slab capacity."""
    t = draw(st.integers(1, 24))
    k = draw(st.integers(1, 8))
    n_loc = draw(st.integers(1, 48))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    rows = np.full((t, k), n_loc, np.int32)
    vals = np.zeros((t, k), np.float32)
    for f in range(t):
        kk = int(rng.integers(0, k + 1))      # 0 -> empty feature
        rows[f, :kk] = np.sort(rng.integers(0, n_loc, size=kk))
        vals[f, :kk] = rng.standard_normal(kk)
    return (jnp.asarray(rows), jnp.asarray(vals), n_loc,
            jnp.asarray(np.abs(rng.standard_normal(n_loc)) + 0.01,
                        dtype=jnp.float32),
            jnp.asarray(rng.standard_normal(n_loc), dtype=jnp.float32),
            jnp.asarray(rng.standard_normal(t), dtype=jnp.float32))


@given(case=slab_cases())
@settings(max_examples=30, deadline=None)
def test_slab_gram_matches_densify_oracle(case):
    """ops.slab_gram == the densify-based oracle over ragged/duplicate/
    empty slabs — the sparse-native join must be exact, not approximate."""
    from repro.kernels import ops
    from repro.kernels.ref import slab_gram_ref

    rows, vals, n_loc, w, r, _ = case
    G_ref, c_ref = slab_gram_ref(rows, vals, w, r)
    G, c = ops.slab_gram(rows, vals, w, r)
    np.testing.assert_allclose(np.asarray(G), np.asarray(G_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=2e-4)


@given(case=slab_cases())
@settings(max_examples=30, deadline=None)
def test_slab_spmv_matches_densify_oracle(case):
    from repro.kernels import ops
    from repro.kernels.ref import slab_spmv_ref

    rows, vals, n_loc, _, _, d = case
    out = ops.slab_spmv(rows, vals, d, n_loc=n_loc)
    out_ref = slab_spmv_ref(rows, vals, d, n_loc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-4)


@given(case=slab_cases())
@settings(max_examples=10, deadline=None)
def test_slab_pallas_interpret_matches_oracle(case):
    """The Pallas kernels themselves (interpret mode) on the same
    hypothesis-generated slabs."""
    from repro.kernels import ops
    from repro.kernels.ref import slab_gram_ref, slab_spmv_ref
    from repro.kernels.sparse_slab import slab_gram_pallas, slab_spmv_pallas

    rows, vals, n_loc, w, r, d = case
    G_ref, c_ref = slab_gram_ref(rows, vals, w, r)
    safe, va, wv, cva = ops._sentinel_zeroed(rows, vals, w, r, n_loc)
    G, c = slab_gram_pallas(safe, wv, va, cva, interpret=True)
    np.testing.assert_allclose(np.asarray(G), np.asarray(G_ref), atol=2e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=2e-4)
    dv = jnp.where(rows < n_loc, vals, 0.0) * d[:, None]
    out = slab_spmv_pallas(jnp.minimum(rows, n_loc), dv, n_loc=n_loc,
                           block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(slab_spmv_ref(rows, vals, d, n_loc)),
                               atol=2e-4)


@given(f=st.sampled_from([8, 16, 64]), seed=st.integers(0, 1000),
       lam=st.floats(0.0, 5.0))
@settings(max_examples=25, deadline=None)
def test_gram_cd_decreases_quadratic_objective(f, seed, lam):
    """One CD cycle never increases the penalized quadratic model."""
    from repro.core.subproblem import cd_cycle_gram_tile

    key = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    A = jax.random.normal(k1, (2 * f, f))
    G = A.T @ A / f + 1e-3 * jnp.eye(f)
    c = jax.random.normal(k2, (f,)) * 2
    beta = jax.random.normal(k3, (f,)) * 0.3
    d = cd_cycle_gram_tile(G, c, beta, jnp.zeros(f), lam, 1e-6)

    def qobj(dd):
        return float(0.5 * dd @ G @ dd - c @ dd + lam * jnp.sum(jnp.abs(beta + dd)))

    assert qobj(d) <= qobj(jnp.zeros(f)) + 1e-4
