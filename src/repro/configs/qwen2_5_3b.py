"""qwen2.5-3b [dense] — GQA + QKV bias [hf:Qwen/Qwen2.5-0.5B family]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    arch_type="dense",
    citation="hf:Qwen/Qwen2.5-0.5B (family card); assignment table",
    num_layers=36,
    d_model=2048,
    d_ff=11008,
    vocab_size=151936,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=2,
        head_dim=128,
        qkv_bias=True,           # Qwen2.5 uses Q/K/V bias
        rope_theta=1_000_000.0,
    ),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
    optimizer="adamw",
    long_context_mode="sliding_window",
)
