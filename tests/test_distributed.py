"""Distributed (shard_map) d-GLMNET: equivalence with the single-process
simulation, run in subprocesses with 8 fake CPU devices (tests themselves
must see 1 device, per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def test_distributed_equals_local():
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, fit, fit_distributed, lambda_max
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='d', num_examples=2560, num_features=256, density=1.0)
        ds = make_glm_dataset(cfg, jax.random.key(0))
        X, y = ds.X_train, ds.y_train
        lam = float(lambda_max(X, y)) / 32
        opts = DGLMNETOptions(num_blocks=4, method='gram', tile=32, max_iters=40)
        res_local = fit(X, y, lam, opts=opts)
        mesh = make_dev_mesh(2, 4)
        res_dist = fit_distributed(X, y, lam, mesh, opts=opts)
        rel = abs(res_local.f - res_dist.f) / abs(res_local.f)
        assert rel < 1e-4, (res_local.f, res_dist.f)
        print('OK', res_local.f, res_dist.f)
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_divisibility_and_slab_guards():
    """The guard messages state the actual requirement (the data extent
    must divide n, not the reverse), and the sparse step validates its slab
    shapes against the mesh before any device work."""
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.core import DGLMNETOptions, fit_distributed, fit_distributed_sparse
        from repro.launch.mesh import make_dev_mesh

        mesh = make_dev_mesh(2, 4)
        X = jnp.ones((17, 16)); y = jnp.ones(17)   # 17 % 2 != 0
        try:
            fit_distributed(X, y, 1.0, mesh)
            raise AssertionError('dense guard did not fire')
        except ValueError as e:
            assert 'data extent 2 must divide n=17' in str(e), str(e)

        rows = jnp.zeros((16, 3, 4), jnp.int32)    # DP=3 != data extent 2
        vals = jnp.zeros((16, 3, 4), jnp.float32)
        try:
            fit_distributed_sparse(rows, vals, jnp.ones(18), 1.0, mesh)
            raise AssertionError('slab DP guard did not fire')
        except ValueError as e:
            assert 'must equal the mesh data extent 2' in str(e), str(e)

        rows = jnp.zeros((16, 2, 4), jnp.int32)
        try:
            fit_distributed_sparse(rows, vals, jnp.ones(18), 1.0, mesh)
            raise AssertionError('slab shape guard did not fire')
        except ValueError as e:
            assert 'must match and be (p, DP, K)' in str(e), str(e)

        vals = jnp.zeros((16, 2, 4), jnp.float32)
        try:
            fit_distributed_sparse(rows, vals, jnp.ones(17), 1.0, mesh)
            raise AssertionError('sparse n guard did not fire')
        except ValueError as e:
            assert 'data extent 2 must divide n=17' in str(e), str(e)

        # slabs built for a larger n than y implies: local rows out of range
        rows = jnp.full((16, 2, 4), 30, jnp.int32)   # n_loc from y is 9
        try:
            fit_distributed_sparse(rows, vals, jnp.ones(18), 1.0, mesh)
            raise AssertionError('slab row-range guard did not fire')
        except ValueError as e:
            assert 'exceeds the local example count 9' in str(e), str(e)
        print('OK guards')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_distributed_telemetry_parity():
    """DistributedFitResult surfaces the engine epilogue telemetry
    (alpha_history, unit_step_frac, converged) exactly like FitResult —
    same jitted program, same numbers."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, fit, fit_distributed, lambda_max
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='t', num_examples=1024, num_features=128, density=1.0)
        ds = make_glm_dataset(cfg, jax.random.key(3))
        X, y = ds.X_train, ds.y_train
        lam = float(lambda_max(X, y)) / 32
        opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=40)
        loc = fit(X, y, lam, opts=opts)
        dist = fit_distributed(X, y, lam, make_dev_mesh(2, 4), opts=opts)
        assert dist.n_iters == loc.n_iters, (dist.n_iters, loc.n_iters)
        assert dist.converged == loc.converged
        assert dist.unit_step_frac == loc.unit_step_frac, (
            dist.unit_step_frac, loc.unit_step_frac)
        np.testing.assert_allclose(np.asarray(dist.alpha_history),
                                   np.asarray(loc.alpha_history),
                                   rtol=1e-5, atol=1e-6)
        assert dist.m is not None and dist.m.shape == y.shape
        np.testing.assert_allclose(np.asarray(dist.m), np.asarray(X @ dist.beta),
                                   rtol=1e-4, atol=1e-4)
        print('OK telemetry')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_distributed_sparse_regpath_matches_single_process():
    """The tentpole acceptance: the distributed screened path over
    by-feature sparse slabs on a 2x4 fake-device mesh matches the
    single-process screened path per lambda, every point KKT-certified —
    and the driver never sees a dense (n, p) X (only the reference does)."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import (DGLMNETOptions, regularization_path,
                                regularization_path_distributed)
        from repro.core.objective import margins
        from repro.core.screening import nll_grad_abs
        from repro.data.byfeature import to_by_feature, to_slabs
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='sp', num_examples=1024, num_features=96, density=0.3)
        ds = make_glm_dataset(cfg, jax.random.key(11))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=60, rel_tol=1e-7)
        mesh = make_dev_mesh(2, 4)

        bf = to_by_feature(X)
        slabs = to_slabs(bf, 2)[:2]
        pts_ref = regularization_path(X, y, path_len=6, opts=opts, screen=True)
        pts_dist = regularization_path_distributed(slabs, y, mesh, path_len=6,
                                                   opts=opts)
        assert len(pts_dist) == 6
        for pr, pd in zip(pts_ref, pts_dist):
            rel = abs(pd.f - pr.f) / max(abs(pr.f), 1e-9)
            assert rel < 1e-4, (pd.lam, pd.f, pr.f)
            assert abs(pd.nnz - pr.nnz) <= 2, (pd.lam, pd.nnz, pr.nnz)
            br, bd = np.abs(np.asarray(pr.beta)), np.abs(np.asarray(pd.beta))
            disagree = (br > 0) != (bd > 0)
            assert np.all(np.maximum(br, bd)[disagree] < 1e-2), pd.lam
            np.testing.assert_allclose(np.asarray(pd.beta), np.asarray(pr.beta),
                                       rtol=1e-2, atol=1e-3)
            # KKT certificate at the returned distributed solution
            g = nll_grad_abs(X, y, margins(X, pd.beta))
            inactive = np.asarray(pd.beta) == 0
            assert bool(jnp.all(g[inactive] <= pd.lam * (1 + 2e-3) + 1e-5)), pd.lam
        assert any(p.screen['active'] < X.shape[1] for p in pts_dist)
        print('OK sparse distributed path')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_sparse_fit_densify_fallback_equivalence():
    """fit_distributed_sparse must produce the same solve whether the
    nnz-density heuristic picks the sparse-native slab kernels or the
    once-per-solve on-mesh densify fallback — and both must match the
    dense fit. Low density so the slab-native path is the natural one."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, fit, lambda_max
        from repro.core.distributed import fit_distributed_sparse
        from repro.data.byfeature import to_by_feature, to_slabs
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='fb', num_examples=2048, num_features=64,
                        density=0.005)
        ds = make_glm_dataset(cfg, jax.random.key(8))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        lam = float(lambda_max(X, y)) / 16
        opts = DGLMNETOptions(tile=16, max_iters=30)
        mesh = make_dev_mesh(2, 4)
        row_idx, values, n_loc = to_slabs(to_by_feature(X), 2)
        from repro.kernels.ops import prefer_slab_gram
        assert prefer_slab_gram(n_loc, row_idx.shape[2]), (
            'density too high for the slab-native regime', row_idx.shape)

        ref = fit(X, y, lam, opts=opts)
        res_auto = fit_distributed_sparse(row_idx, values, y, lam, mesh,
                                          opts=opts)
        res_sparse = fit_distributed_sparse(row_idx, values, y, lam, mesh,
                                            opts=opts, densify=False)
        res_dense = fit_distributed_sparse(row_idx, values, y, lam, mesh,
                                           opts=opts, densify=True)
        for res in (res_auto, res_sparse, res_dense):
            assert abs(res.f - ref.f) / abs(ref.f) < 1e-4, (res.f, ref.f)
            np.testing.assert_allclose(np.asarray(res.beta),
                                       np.asarray(ref.beta),
                                       rtol=1e-2, atol=1e-3)
        # the two mesh paths solve the *same* block partition: bitwise-tight
        np.testing.assert_allclose(np.asarray(res_sparse.beta),
                                   np.asarray(res_dense.beta),
                                   rtol=1e-5, atol=1e-6)
        print('OK densify fallback equivalence')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_bucketed_regpath_matches_single_process():
    """The distributed screened path over the nnz-bucketed SlabBuckets
    layout == the single-process screened path per lambda, with betas
    mapped back to the original feature order."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import (DGLMNETOptions, regularization_path,
                                regularization_path_distributed)
        from repro.data.byfeature import to_by_feature, to_slab_buckets
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='bk', num_examples=1024, num_features=96,
                        density=0.08)
        ds = make_glm_dataset(cfg, jax.random.key(11))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=60,
                              rel_tol=1e-7)
        mesh = make_dev_mesh(2, 4)
        slabs = to_slab_buckets(to_by_feature(X), 2)
        assert len(slabs.buckets) >= 2, 'want multiple K classes'
        pts_ref = regularization_path(X, y, path_len=5, opts=opts,
                                      screen=True)
        pts = regularization_path_distributed(slabs, y, mesh, path_len=5,
                                              opts=opts)
        for pr, pb in zip(pts_ref, pts):
            rel = abs(pb.f - pr.f) / max(abs(pr.f), 1e-9)
            assert rel < 1e-4, (pb.lam, pb.f, pr.f)
            np.testing.assert_allclose(np.asarray(pb.beta),
                                       np.asarray(pr.beta),
                                       rtol=1e-2, atol=1e-3)

        # pre-built slabs with sentinel slots interleaved among live ones
        # (legal input; nothing ever promised front-packing): the K-trim
        # must be disabled, not silently drop live entries
        from repro.data.byfeature import to_slabs
        row_idx, values, n_loc = to_slabs(to_by_feature(X), 2)
        ri, vv = np.array(row_idx), np.array(values)
        rng = np.random.default_rng(0)
        for j in range(ri.shape[0]):
            for s in range(ri.shape[1]):
                perm = rng.permutation(ri.shape[2])
                ri[j, s], vv[j, s] = ri[j, s][perm], vv[j, s][perm]
        pts_shuf = regularization_path_distributed(
            (jnp.asarray(ri), jnp.asarray(vv)), y, mesh, path_len=5,
            opts=opts)
        for pr, pb in zip(pts_ref, pts_shuf):
            assert abs(pb.f - pr.f) / max(abs(pr.f), 1e-9) < 1e-4, (
                pb.lam, pb.f, pr.f)
            np.testing.assert_allclose(np.asarray(pb.beta),
                                       np.asarray(pr.beta),
                                       rtol=1e-2, atol=1e-3)
        print('OK bucketed path')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_distributed_dense_regpath_matches_single_process():
    """Dense-X flavor of the distributed screened path: restricted solves
    are fit_distributed; per-lambda agreement with the single-process
    engine on a model x data mesh."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import (DGLMNETOptions, regularization_path,
                                regularization_path_distributed)
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='dd', num_examples=1280, num_features=128, density=1.0)
        ds = make_glm_dataset(cfg, jax.random.key(12))
        X, y = ds.X_train, ds.y_train
        opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=60, rel_tol=1e-7)
        mesh = make_dev_mesh(2, 4)
        pts_ref = regularization_path(X, y, path_len=6, opts=opts, screen=True)
        pts_dist = regularization_path_distributed(X, y, mesh, path_len=6,
                                                   opts=opts)
        for pr, pd in zip(pts_ref, pts_dist):
            rel = abs(pd.f - pr.f) / max(abs(pr.f), 1e-9)
            assert rel < 1e-4, (pd.lam, pd.f, pr.f)
            np.testing.assert_allclose(np.asarray(pd.beta), np.asarray(pr.beta),
                                       rtol=1e-2, atol=1e-3)
        print('OK dense distributed path')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_distributed_model_axis_only():
    """Paper-faithful 1-D split (features only): data axis of size 1."""
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, fit, fit_distributed, lambda_max
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='d', num_examples=1024, num_features=128, density=1.0)
        ds = make_glm_dataset(cfg, jax.random.key(1))
        X, y = ds.X_train, ds.y_train
        lam = float(lambda_max(X, y)) / 16
        opts = DGLMNETOptions(num_blocks=8, method='gram', tile=16, max_iters=30)
        mesh = make_dev_mesh(1, 8)
        res = fit_distributed(X, y, lam, mesh, opts=opts)
        res_l = fit(X, y, lam, opts=opts)
        rel = abs(res.f - res_l.f) / abs(res_l.f)
        assert rel < 1e-4, (res.f, res_l.f)
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]


@pytest.mark.slow
def test_distributed_blocked_cycle_equals_local():
    """The blocked semi-parallel cycle through both distributed restricted
    paths (dense shard_map + by-feature sparse slabs, plus the Pallas
    blocked_cd kernel inside shard_map) matches the single-process blocked
    fit — the same tile math runs either way."""
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import (DGLMNETOptions, fit, fit_distributed,
                                fit_distributed_sparse, lambda_max)
        from repro.data.byfeature import to_by_feature, to_slabs
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='d', num_examples=1024, num_features=128,
                        density=0.2)
        ds = make_glm_dataset(cfg, jax.random.key(3))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        lam = float(lambda_max(X, y)) / 16
        mesh = make_dev_mesh(2, 4)
        opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=25,
                              cycle_mode='blocked', block=8)
        ref = fit(X, y, lam, opts=opts)
        dist = fit_distributed(X, y, lam, mesh, opts=opts)
        assert abs(dist.f - ref.f) / abs(ref.f) < 1e-5, (dist.f, ref.f)
        row_idx, values, _ = to_slabs(to_by_feature(X), 2)
        sp = fit_distributed_sparse(row_idx, values, y, lam, mesh,
                                    opts=opts, densify=False)
        assert abs(sp.f - ref.f) / abs(ref.f) < 1e-4, (sp.f, ref.f)
        kopts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=10,
                               cycle_mode='blocked', block=8,
                               use_kernel=True)
        k = fit_distributed(X, y, lam, mesh, opts=kopts)
        kref = fit(X, y, lam, opts=DGLMNETOptions(
            num_blocks=4, tile=32, max_iters=10, cycle_mode='blocked',
            block=8))
        assert abs(k.f - kref.f) / abs(kref.f) < 1e-4, (k.f, kref.f)
        print('OK blocked distributed == local')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_distributed_with_kernel():
    """Pallas gram_cd kernel inside shard_map (interpret mode)."""
    r = _run("""
        import jax, jax.numpy as jnp
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, fit_distributed, fit, lambda_max
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='d', num_examples=1024, num_features=64, density=1.0)
        ds = make_glm_dataset(cfg, jax.random.key(2))
        X, y = ds.X_train, ds.y_train
        lam = float(lambda_max(X, y)) / 16
        opts = DGLMNETOptions(num_blocks=4, tile=16, max_iters=15, use_kernel=True)
        mesh = make_dev_mesh(2, 4)
        res = fit_distributed(X, y, lam, mesh, opts=opts)
        ref = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=4, tile=16, max_iters=15))
        assert abs(res.f - ref.f) / abs(ref.f) < 1e-3
        print('OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]


@pytest.mark.slow
def test_flash_decode_equals_gather_decode():
    """Seq-parallel flash-decode must match the gather path numerically."""
    r = _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs.base import AttentionConfig
        from repro.models.attention import attention_forward, init_attention, init_kv_cache
        from repro.launch.mesh import make_dev_mesh
        from repro.sharding.ctx import mesh_context

        cfg = AttentionConfig(num_heads=8, num_kv_heads=2, head_dim=16)
        d_model = 128
        key = jax.random.key(0)
        p = init_attention(key, cfg, d_model, jnp.float32)
        b, cache_len = 2, 32
        x = jax.random.normal(jax.random.fold_in(key, 1), (b, 1, d_model))
        cache = init_kv_cache(cfg, d_model, b, cache_len, jnp.float32)
        kf = jax.random.normal(jax.random.fold_in(key, 2), (b, 12, 2, 16))
        vf = jax.random.normal(jax.random.fold_in(key, 3), (b, 12, 2, 16))
        cache = {'k': cache['k'].at[:, :12].set(kf), 'v': cache['v'].at[:, :12].set(vf)}
        pos = jnp.full((b, 1), 12, jnp.int32)

        def decode(seq_par):
            def f(p, x, cache):
                y, _ = attention_forward(
                    p, x, cfg=cfg, d_model=d_model, positions=pos, mode='decode',
                    cache=cache, cache_index=jnp.asarray(12, jnp.int32),
                    seq_parallel_decode=seq_par)
                return y
            return f

        y_ref = jax.jit(decode(False))(p, x, cache)
        mesh = make_dev_mesh(2, 4)
        with mesh_context(mesh):
            y_fd = jax.jit(decode(True))(p, x, cache)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_fd),
                                   atol=2e-5)
        print('OK flash-decode == gather decode')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_dev_mesh_dryrun_lowering():
    """dryrun.py end-to-end on the dev mesh (8 devices) for one arch/shape
    per kind — proves the launcher machinery without the 512-dev cost."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    for arch, shape in [("tinyllama-1.1b", "train_4k"),
                        ("mamba2-2.7b", "decode_32k")]:
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", "dev"],
            capture_output=True, text=True, timeout=900, env=env,
        )
        assert r.returncode == 0, (arch, shape, r.stdout[-2000:], r.stderr[-2000:])
        assert "1 ok, 0 skip, 0 error" in r.stdout


@pytest.mark.slow
def test_dryrun_screened_path_lowering():
    """--glm-screened: the sparse screen + blocked-cycle steps lower on a
    mesh (dev size here; the 16x16 production form is the same code with
    REPRO_DRYRUN_DEVICES=512)."""
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--glm-screened",
         "--mesh", "dev"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "3 ok, 0 skip, 0 error" in r.stdout


@pytest.mark.slow
def test_sparse_subproblem_equals_dense():
    """By-feature sparse distributed step == dense distributed step."""
    r = _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, lambda_max, margins, objective
        from repro.core.distributed import (
            make_dglmnet_step, make_dglmnet_step_sparse)
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='s', num_examples=1024, num_features=64, density=0.2)
        ds = make_glm_dataset(cfg, jax.random.key(5))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        lam = float(lambda_max(X, y)) / 16
        mesh = make_dev_mesh(2, 4)
        opts = DGLMNETOptions(tile=16)

        # build the (p, DP, K) by-feature slabs with LOCAL row indices
        Xn = np.asarray(X)
        dp, p = 2, X.shape[1]
        n_loc = n // dp
        K = max(int((Xn[s*n_loc:(s+1)*n_loc, j] != 0).sum())
                for s in range(dp) for j in range(p))
        row_idx = np.full((p, dp, K), n_loc, np.int32)
        values = np.zeros((p, dp, K), np.float32)
        for s in range(dp):
            for j in range(p):
                rows = np.nonzero(Xn[s*n_loc:(s+1)*n_loc, j])[0]
                row_idx[j, s, :len(rows)] = rows
                values[j, s, :len(rows)] = Xn[s*n_loc + rows, j]

        beta = jnp.zeros(p); m = margins(X, beta)
        dense = make_dglmnet_step(mesh, opts)
        sparse = make_dglmnet_step_sparse(mesh, opts)
        b1, m1, f1, a1 = dense(X, y, beta, m, lam)
        b2, m2, f2, a2 = sparse(jnp.asarray(row_idx), jnp.asarray(values),
                                y, beta, m, lam)
        np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-4)
        np.testing.assert_allclose(float(f1), float(f2), rtol=1e-5)
        print('OK sparse == dense')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
