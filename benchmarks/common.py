"""Shared benchmark setup: CPU-scale synthetic twins of the paper's Table 2
datasets (aspect/density preserved; see repro/configs/glm.py)."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax

from repro.configs.base import GLMConfig
from repro.data.synthetic import GLMDataset, make_glm_dataset

# CPU-scale twins (paper scale is exercised via the dry-run, not here)
TWINS = {
    "epsilon-twin": GLMConfig(
        name="epsilon-twin", citation="Table 2: epsilon (dense)",
        num_examples=6400, num_features=512, density=1.0, avg_nnz_per_example=512),
    "webspam-twin": GLMConfig(
        name="webspam-twin", citation="Table 2: webspam (sparse, wide)",
        num_examples=5120, num_features=4096, density=0.02,
        avg_nnz_per_example=82),
    "dna-twin": GLMConfig(
        name="dna-twin", citation="Table 2: dna (many examples, narrow)",
        num_examples=25600, num_features=128, density=0.25,
        avg_nnz_per_example=32),
}


def load_twin(name: str) -> GLMDataset:
    import zlib

    # deterministic across processes (hash() is salted per-interpreter)
    return make_glm_dataset(TWINS[name], jax.random.key(zlib.crc32(name.encode())))


@dataclass
class Timer:
    """``with Timer() as t: t.block = fn()`` — assign the produced value
    to ``block`` inside the with-body and ``__exit__`` runs
    ``jax.block_until_ready`` on it before stopping the clock, so ``dt``
    measures the JAX work, not the async enqueue. Leave ``block`` unset
    only when the timed section already ends on host values."""

    t0: float = 0.0
    block: object = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        if a[0] is None and self.block is not None:
            jax.block_until_ready(self.block)
        self.dt = time.perf_counter() - self.t0


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
