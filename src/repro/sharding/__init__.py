from repro.sharding.rules import (  # noqa: F401
    batch_axes,
    cache_pspecs,
    input_pspecs,
    opt_state_pspecs,
    param_pspecs,
)
