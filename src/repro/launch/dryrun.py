import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, print memory/cost analyses, and emit roofline terms.

The two lines above MUST stay the first statements in this module (jax locks
the device count at first init). Do not import this module from tests —
run it as a script / subprocess:

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --json out.json
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, MODEL_CONFIGS, get_shape, SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh, num_chips  # noqa: E402
from repro.launch.roofline import analyze  # noqa: E402
from repro.launch.specs import input_specs, params_specs, skip_reason, state_specs  # noqa: E402
from repro.models.params import count_params_analytic  # noqa: E402
from repro.sharding.rules import (  # noqa: E402
    cache_pspecs,
    input_pspecs,
    opt_state_pspecs,
    param_pspecs,
)
from repro.train.train_step import make_prefill_step, make_serve_step, make_train_step  # noqa: E402


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def model_flops_estimate(cfg, shape) -> float:
    """6*N*D for train (fwd+bwd), 2*N_active*D for inference steps."""
    n_active = count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch * 1  # decode: one token


def lower_combo(arch: str, shape_name: str, mesh, mesh_name: str,
                verbose: bool = True, unroll: bool = False) -> dict:
    """Two-phase dry-run for one combo:

    1. scan-layers compile  -> proves lowering + per-device memory fit
       (deployment form: O(1)-in-depth HLO).
    2. (optional, --unroll) unrolled compile -> exact HloCostAnalysis FLOPs /
       bytes / collective-bytes (XLA counts while-loop bodies once, so the
       scan form under-reports; see EXPERIMENTS §Roofline methodology).
    """
    import dataclasses

    from repro.sharding.ctx import mesh_context, unroll_context

    cfg = MODEL_CONFIGS[arch]
    shape = get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}

    specs = input_specs(cfg, shape)

    with mesh_context(mesh):
        out = _lower_inner(cfg, arch, shape, shape_name, mesh, mesh_name,
                           specs, time.time(), verbose)
        if unroll:
            try:
                cost = _depth_probe_cost(cfg, arch, shape, shape_name, mesh,
                                         mesh_name)
                out.update(cost)
                if verbose:
                    print(f"    [depth-probe cost] "
                          f"t_comp={out['t_compute']*1e3:.2f}ms "
                          f"t_mem={out['t_memory']*1e3:.2f}ms "
                          f"t_coll={out['t_collective']*1e3:.2f}ms "
                          f"bottleneck={out['bottleneck']} "
                          f"useful={out['useful_flops_ratio']:.3f}")
            except Exception:  # noqa: BLE001
                traceback.print_exc()
                out["cost_source"] = "scan-underestimate"
        return out


def _depth_probe_cost(cfg, arch, shape, shape_name, mesh, mesh_name) -> dict:
    """Exact-cost extrapolation: HloCostAnalysis counts loop bodies once, so
    instead of unrolling the full depth (intractable compiles), lower two
    shallow fully-unrolled variants. Per-layer/unit cost is exactly linear,
    so  cost(L) = a + b*L  recovers the full-depth FLOPs / bytes /
    collective-bytes. Hybrid archs use one vs two 6-layer periods as the
    unit; MoE archs keep their dense prefix in `a`."""
    from repro.launch.roofline import analyze
    from repro.sharding.ctx import unroll_context

    prefix = cfg.first_dense_layers if cfg.moe.enabled else 0
    if cfg.arch_type == "hybrid" and cfg.hybrid is not None:
        k = cfg.hybrid.attn_every
        l1, l2 = k, 2 * k
        n_units, rem_frac = divmod(cfg.num_layers, k)
        rem_frac = rem_frac / k  # remainder ssm layers ~ fraction of a period
    else:
        l1, l2 = prefix + 1, prefix + 2
        n_units, rem_frac = cfg.num_layers - prefix, 0.0

    def probe(layers):
        c = dataclasses.replace(
            cfg, num_layers=layers, scan_layers=False, microbatch=1,
        )
        specs_p = input_specs(c, get_shape(shape_name))
        with unroll_context(True):
            r = _lower_inner(c, arch, shape, shape_name, mesh, mesh_name,
                             specs_p, time.time(), False)
        return r

    t0 = time.time()
    r1 = probe(l1)
    r2 = probe(l2)
    units1 = (1 if cfg.arch_type == "hybrid" else l1 - prefix)
    units2 = (2 if cfg.arch_type == "hybrid" else l2 - prefix)

    def extrap(key):
        b = (r2[key] - r1[key]) / (units2 - units1)
        a = r1[key] - b * units1
        return max(a + b * (n_units + rem_frac), 0.0)

    flops = extrap("flops")
    hbm = extrap("hbm_bytes")
    coll = extrap("collective_bytes")
    colls = {
        kk: max(
            r1["collectives"][kk]
            + (r2["collectives"][kk] - r1["collectives"][kk])
            / (units2 - units1) * (n_units + rem_frac - units1),
            0,
        )
        for kk in r1["collectives"]
    }
    from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

    chips = num_chips(mesh)
    t_comp = flops / (chips * PEAK_FLOPS_BF16)
    t_mem = hbm / HBM_BW
    t_coll = coll / ICI_BW_PER_LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    mf = model_flops_estimate(cfg, get_shape(shape_name))
    return {
        "flops": flops, "hbm_bytes": hbm, "collective_bytes": coll,
        "collectives": colls, "t_compute": t_comp, "t_memory": t_mem,
        "t_collective": t_coll, "bottleneck": max(terms, key=terms.get),
        "useful_flops_ratio": mf / flops if flops else 0.0,
        # allow[bench-timing]: times a lowering depth probe — host-synchronous; no device work to block on
        "cost_source": "depth-probe", "cost_compile_s": time.time() - t0,
    }


def _lower_inner(cfg, arch, shape, shape_name, mesh, mesh_name, specs, t0,
                 verbose):
    if shape.kind == "train":
        fn = make_train_step(cfg)
        state_sds = state_specs(cfg)
        pspec = param_pspecs(cfg, state_sds["params"], mesh)
        ospec = opt_state_pspecs(cfg, state_sds["opt"], pspec, mesh)
        st_shard = {"params": _named(mesh, pspec), "opt": _named(mesh, ospec),
                    "step": NamedSharding(mesh, P())}
        b_shard = _named(mesh, input_pspecs(cfg, specs["batch"], mesh))
        jitted = jax.jit(fn, in_shardings=(st_shard, b_shard), donate_argnums=0)
        lowered = jitted.lower(state_sds, specs["batch"])
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        p_sds = params_specs(cfg)
        pspec = param_pspecs(cfg, p_sds, mesh)
        b_shard = _named(mesh, input_pspecs(cfg, specs["batch"], mesh))
        jitted = jax.jit(fn, in_shardings=(_named(mesh, pspec), b_shard))
        lowered = jitted.lower(p_sds, specs["batch"])
    else:  # decode
        long_mode = shape_name == "long_500k"
        fn = make_serve_step(cfg, long_mode=long_mode)
        p_sds = params_specs(cfg)
        pspec = param_pspecs(cfg, p_sds, mesh)
        c_shard = _named(mesh, cache_pspecs(cfg, specs["cache"], mesh))
        t_shard = _named(mesh, input_pspecs(cfg, {"tokens": specs["tokens"]}, mesh))["tokens"]
        jitted = jax.jit(
            fn,
            in_shardings=(_named(mesh, pspec), c_shard, NamedSharding(mesh, P()), t_shard),
            donate_argnums=1,
        )
        lowered = jitted.lower(p_sds, specs["cache"], specs["cache_index"], specs["tokens"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    # allow[bench-timing]: times lower()/compile() — host-synchronous; no device work to block on
    t_compile = time.time() - t0

    roof = analyze(
        compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
        chips=num_chips(mesh), model_flops=model_flops_estimate(cfg, shape),
    )
    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- {arch} x {shape_name} x {mesh_name} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"    memory_analysis: {mem}")
        from repro.compat import cost_analysis

        ca = cost_analysis(compiled)
        print(f"    cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"    roofline: t_comp={roof.t_compute*1e3:.2f}ms "
              f"t_mem={roof.t_memory*1e3:.2f}ms t_coll={roof.t_collective*1e3:.2f}ms "
              f"bottleneck={roof.bottleneck} useful={roof.useful_flops_ratio:.2f}")
    out = roof.to_dict()
    out.update(status="ok", lower_s=t_lower, compile_s=t_compile,
               memory_analysis=str(mem))
    return out


def lower_glm(name: str, mesh, mesh_name: str, verbose: bool = True) -> dict:
    """Dry-run the paper's own workload: one distributed d-GLMNET outer
    iteration (subproblem + AllReduce + line search) at Table-2 scale.

    epsilon/dna lower densely; glm-webspam (dense X would be 10.5 TB) uses
    the by-feature sparse step (paper Table-1 layout, DESIGN §2.3). The
    step programs come from the ``repro.api`` strategy resolver
    (``mesh_programs``) — the same resolution live solves get.
    """
    from repro.api import mesh_programs
    from repro.configs.glm import GLM_CONFIGS
    from repro.core.dglmnet import DGLMNETOptions
    from repro.launch.roofline import analyze

    cfg = GLM_CONFIGS[name]
    mdim = mesh.shape["model"]
    tile = 128
    n = cfg.num_examples
    ddim = num_chips(mesh) // mdim
    n -= n % ddim
    p = ((cfg.num_features + mdim * tile - 1) // (mdim * tile)) * (mdim * tile)

    opts = DGLMNETOptions(tile=tile, method="gram")
    sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    t0 = time.time()
    if name == "glm-webspam":
        # by-feature sparse layout (paper Table 1): dense X would be 10.5 TB.
        # K = padded nnz per feature per data shard (avg 72/16 -> 64 covers
        # the tail with the sentinel mechanism).
        k_pad = 64
        step, _ = mesh_programs(mesh, opts, layout="slab")
        lowered = jax.jit(step).lower(
            sds((p, ddim, k_pad), jnp.int32), sds((p, ddim, k_pad), jnp.float32),
            sds((n,), jnp.float32), sds((p,), jnp.float32),
            sds((n,), jnp.float32), sds((), jnp.float32),
        )
    else:
        step, _ = mesh_programs(mesh, opts, layout="dense")
        lowered = jax.jit(step).lower(
            sds((n, p), jnp.float32), sds((n,), jnp.float32),
            sds((p,), jnp.float32), sds((n,), jnp.float32),
            sds((), jnp.float32),
        )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    # allow[bench-timing]: times lower()/compile() — host-synchronous; no device work to block on
    t_compile = time.time() - t0
    # model flops: one outer iteration = Gram tiles + margins ~ 2*n*p*(tile+2)
    mf = 2.0 * n * p * (tile + 2)
    roof = analyze(compiled, arch=name, shape="dglmnet_step",
                   mesh_name=mesh_name, chips=num_chips(mesh), model_flops=mf)
    mem = compiled.memory_analysis()
    if verbose:
        print(f"--- {name} x dglmnet_step x {mesh_name} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"    memory_analysis: {mem}")
        print(f"    roofline: t_comp={roof.t_compute*1e3:.2f}ms "
              f"t_mem={roof.t_memory*1e3:.2f}ms "
              f"t_coll={roof.t_collective*1e3:.2f}ms "
              f"bottleneck={roof.bottleneck}")
    out = roof.to_dict()
    out.update(status="ok", lower_s=t_lower, compile_s=t_compile,
               memory_analysis=str(mem))
    return out


def lower_glm_screened(mesh, mesh_name: str, verbose: bool = True) -> list:
    """Lowering-only dry-run of the *screened distributed path*'s moving
    parts at Table-2 dims on the production mesh (ROADMAP "production mesh
    scale"): proves the 16x16 lowering of

    * the sparse strong-rule screen (``core.screening.make_sparse_screen``
      slab stream, psum over data axes) at webspam shape;
    * the by-feature sparse subproblem step over slabs with the *blocked*
      semi-parallel CD cycle (slab_gram/slab_spmv suite +
      ``cd_cycle_blocked_tile``);
    * the dense subproblem step with the Pallas ``blocked_cd`` kernel
      (epsilon shape, ``use_kernel=True``).

    No ``.compile()`` and no execution — ``.lower()`` alone certifies the
    shard_map programs partition at mesh scale; compile cost for the full
    p=16.6M scan is the production TPU's business, not CI's. All programs
    come from ``repro.api.mesh_programs`` — the strategy resolver the live
    solves use.
    """
    from repro.api import mesh_programs
    from repro.configs.glm import GLM_CONFIGS
    from repro.core.dglmnet import DGLMNETOptions

    mdim = mesh.shape["model"]
    ddim = num_chips(mesh) // mdim
    tile = 128
    opts = DGLMNETOptions(tile=tile, method="gram", cycle_mode="blocked",
                          block=16)
    sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    results = []

    def record(label, fn, *args):
        t0 = time.time()
        fn(*args)          # .lower() inside; any failure propagates
        out = {"arch": label, "shape": "screened_path", "mesh": mesh_name,
               # allow[bench-timing]: times .lower() only — host-synchronous; no device work to block on
               "status": "ok", "lower_s": time.time() - t0}
        if verbose:
            print(f"--- {label} x screened_path x {mesh_name} "
                  f"(lower {out['lower_s']:.1f}s, lowering-only)")
        results.append(out)

    # webspam: sparse screen + sparse blocked step over (p, DP, K) slabs
    cfg = GLM_CONFIGS["glm-webspam"]
    n = cfg.num_examples - cfg.num_examples % ddim
    n_loc = n // ddim
    p = ((cfg.num_features + mdim * tile - 1) // (mdim * tile)) * (mdim * tile)
    k_pad = 64
    slab_i = sds((p, ddim, k_pad), jnp.int32)
    slab_f = sds((p, ddim, k_pad), jnp.float32)
    vec_n = sds((n,), jnp.float32)
    step_sparse, screen = mesh_programs(mesh, opts, layout="slab",
                                        n_loc=n_loc)
    record("glm-webspam-screen",
           lambda: screen.lower(slab_i, slab_f, vec_n, vec_n))
    record("glm-webspam-blocked-step",
           lambda: jax.jit(step_sparse).lower(
               slab_i, slab_f, vec_n, sds((p,), jnp.float32), vec_n,
               sds((), jnp.float32)))

    # epsilon: dense step with the Pallas blocked_cd kernel on the mesh
    cfg = GLM_CONFIGS["glm-epsilon"]
    n = cfg.num_examples - cfg.num_examples % ddim
    p = ((cfg.num_features + mdim * tile - 1) // (mdim * tile)) * (mdim * tile)
    step_dense, _ = mesh_programs(
        mesh, DGLMNETOptions(tile=tile, cycle_mode="blocked", block=16,
                             use_kernel=True), layout="dense")
    record("glm-epsilon-blocked-kernel-step",
           lambda: jax.jit(step_dense).lower(
               sds((n, p), jnp.float32), sds((n,), jnp.float32),
               sds((p,), jnp.float32), sds((n,), jnp.float32),
               sds((), jnp.float32)))
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both", "dev"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None, help="write results JSON here")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer loops for exact cost_analysis")
    ap.add_argument("--glm", action="store_true",
                    help="also dry-run the paper's GLM workload (Table-2 dims)")
    ap.add_argument("--glm-screened", action="store_true",
                    help="lowering-only dry-run of the screened distributed "
                         "path (sparse screen + blocked-cycle steps) at "
                         "Table-2 dims")
    ap.add_argument("--flash-decode", action="store_true",
                    help="seq-parallel flash-decode attention (hillclimb)")
    args = ap.parse_args()
    if args.flash_decode:
        import contextlib

        from repro.sharding.ctx import flash_decode_context

        _stack = contextlib.ExitStack()
        _stack.enter_context(flash_decode_context(True))

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.mesh == "dev":
        from repro.launch.mesh import make_dev_mesh

        mesh_list = [(make_dev_mesh(), "2x4-dev")]
    else:
        multis = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
        mesh_list = [
            (make_production_mesh(multi_pod=m), "2x16x16" if m else "16x16")
            for m in multis
        ]

    results = []
    for mesh, mesh_name in mesh_list:
        if args.glm:
            from repro.configs.glm import GLM_CONFIGS

            for gname in GLM_CONFIGS:
                try:
                    results.append(lower_glm(gname, mesh, mesh_name))
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    results.append({"arch": gname, "shape": "dglmnet_step",
                                    "mesh": mesh_name, "status": "error",
                                    "error": repr(e)})
        if args.glm_screened:
            try:
                results.extend(lower_glm_screened(mesh, mesh_name))
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                results.append({"arch": "glm-screened",
                                "shape": "screened_path", "mesh": mesh_name,
                                "status": "error", "error": repr(e)})
        if (args.glm or args.glm_screened) and args.arch is None \
                and not args.all:
            continue
        for arch in archs:
            for shape in shapes:
                try:
                    results.append(
                        lower_combo(arch, shape, mesh, mesh_name, unroll=args.unroll)
                    )
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": mesh_name, "status": "error",
                                    "error": repr(e)})
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run: {ok} ok, {skip} skip, {err} error ===")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    raise SystemExit(1 if err else 0)


if __name__ == "__main__":
    main()
