"""Golden fixture: trips host-sync-in-jit and nothing else.

``float()`` on a traced operand inside a jitted function forces a host
sync (or a ConcretizationTypeError) at the worst possible place.
"""
import jax


@jax.jit
def squash(x):
    return float(x) + 1.0
