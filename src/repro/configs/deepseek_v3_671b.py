"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP
[arXiv:2412.19437].

Optimizer is Adafactor: AdamW state for 671B params does not fit
256 x 16GB v5e chips even fully sharded (see EXPERIMENTS §Dry-run).
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    citation="arXiv:2412.19437 (DeepSeek-V3)",
    num_layers=61,
    d_model=7168,
    d_ff=18432,                  # dense-MLP width for the first dense layers
    vocab_size=129280,
    attention=AttentionConfig(
        num_heads=128,
        num_kv_heads=128,        # MLA: latent cache, head count for Q/compute
        head_dim=128,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_rope_head_dim=64,
        qk_nope_head_dim=128,
        v_head_dim=128,
        rope_theta=10000.0,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        num_shared_experts=1,
        expert_d_ff=2048,        # assignment table d_ff=2048 = per-expert width
        capacity_factor=1.25,
        aux_loss_weight=0.0001,  # DSv3 uses aux-loss-free balancing; keep tiny aux
    ),
    first_dense_layers=3,        # DeepSeek-V3 keeps the first 3 layers dense
    mtp_depth=1,                 # one MTP head (DeepSeek-V3 MTP)
    norm="rmsnorm",
    act="silu",
    microbatch=16,
    optimizer="adafactor",
    long_context_mode="sliding_window",
)
