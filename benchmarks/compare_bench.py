"""CI perf gate: compare a fresh BENCH_regpath.json against the committed
baseline and fail when the warm screened-path time regresses.

The headline metric is ``engine.warm_s`` — the warm wall-clock of the
screened path engine, which is what repeated production paths pay (cold
time is dominated by XLA compiles and is allowed to drift). The gate is a
ratio so the baseline only needs regenerating when shapes change:

    python -m benchmarks.compare_bench \
        --fresh BENCH_regpath.json \
        --baseline benchmarks/baselines/BENCH_regpath_tiny.json \
        --max-ratio 1.3

Exits non-zero when fresh/baseline > max-ratio or when the configs don't
match (a silent shape change would make the ratio meaningless).
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--max-ratio", type=float, default=1.3,
                    help="fail when fresh warm_s exceeds baseline by this "
                         "factor (default 1.3)")
    ap.add_argument("--normalize", action="store_true",
                    help="divide each warm_s by the same run's seed-style "
                         "warm_s before comparing, so raw machine speed "
                         "cancels (use on heterogeneous CI runners)")
    args = ap.parse_args()

    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.baseline) as fh:
        base = json.load(fh)

    if fresh["config"] != base["config"]:
        print(f"FAIL: config mismatch — fresh {fresh['config']} vs "
              f"baseline {base['config']}; regenerate the baseline")
        return 1

    fresh_warm = fresh["engine"]["warm_s"]
    base_warm = base["engine"]["warm_s"]
    unit = "s"
    if args.normalize:
        fresh_warm /= max(fresh["seed_style"]["warm_s"], 1e-12)
        base_warm /= max(base["seed_style"]["warm_s"], 1e-12)
        unit = "x seed-style"
    ratio = fresh_warm / max(base_warm, 1e-12)
    print(f"engine warm path: fresh {fresh_warm:.3f}{unit} vs baseline "
          f"{base_warm:.3f}{unit} -> ratio {ratio:.2f}x (gate {args.max_ratio}x)")
    if ratio > args.max_ratio:
        print(f"FAIL: warm path time regressed {ratio:.2f}x > "
              f"{args.max_ratio}x")
        return 1
    print("OK: warm path time within gate")
    return 0


if __name__ == "__main__":
    sys.exit(main())
