"""L1-regularized logistic regression objective (paper eq. (1)-(4)).

All functions work from the *margin cache* m_i = beta^T x_i — the paper's
O(n) state (it stores exp(beta^T x_i)); every line-search/objective
evaluation is O(n + p), never a pass over X.

Conventions: y in {-1, +1}; X dense (n, p) float32 (sparse data stays in
by-feature slab form end-to-end — kernels/sparse_slab.py computes the
tile statistics without densifying; see DESIGN.md §2.3 on TPU adaptation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# numerical guards (BBR/GLMNET-style probability clamp)
P_EPS = 1e-5
W_MIN = 1e-6


def margins(X: jnp.ndarray, beta: jnp.ndarray) -> jnp.ndarray:
    return X @ beta


def neg_log_likelihood(m: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """L(beta) = sum_i log(1 + exp(-y_i m_i)), computed stably."""
    return jnp.sum(jax.nn.softplus(-y * m))


def l1_norm(beta: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(beta))


def objective(m: jnp.ndarray, y: jnp.ndarray, beta: jnp.ndarray, lam: float) -> jnp.ndarray:
    """f(beta) = L(beta) + lam * ||beta||_1, from cached margins."""
    return neg_log_likelihood(m, y) + lam * l1_norm(beta)


def working_stats(m: jnp.ndarray, y: jnp.ndarray):
    """GLMNET working responses (paper eq. (4)).

    p_i = sigmoid(m_i); w_i = p(1-p); z_i = ((y+1)/2 - p)/w.
    Returns (w, z) with probability clamped for numerical stability.
    """
    p = jax.nn.sigmoid(m)
    p = jnp.clip(p, P_EPS, 1.0 - P_EPS)
    w = jnp.maximum(p * (1.0 - p), W_MIN)
    z = ((y + 1.0) * 0.5 - p) / w
    return w, z


def grad_nll_from_margins(m: jnp.ndarray, y: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """nabla L(beta) = X^T (p - (y+1)/2)   (for the Armijo D term)."""
    p = jax.nn.sigmoid(m)
    return X.T @ (p - (y + 1.0) * 0.5)


def lambda_max(X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Smallest lambda for which beta* = 0 (Algorithm 5 start).

    At beta=0: p=0.5, w=1/4, z=2y  =>  |sum_i w x_ij z| = |0.5 sum_i x_ij y_i|.

    Delegates to the one ``Design.correlation``-based implementation
    (``repro.api.lambda_max_design``) so the dense entry and the sparse
    screen's m = 0 pass can never drift apart (lazy import: api sits above
    this module).
    """
    from repro.api import DenseDesign, lambda_max_design

    return lambda_max_design(DenseDesign(X), y)


def soft_threshold(x: jnp.ndarray, a) -> jnp.ndarray:
    """T(x, a) = sgn(x) max(|x| - a, 0)   (paper eq. (6))."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - a, 0.0)
