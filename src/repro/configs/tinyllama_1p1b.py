"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    citation="arXiv:2401.02385 (TinyLlama)",
    num_layers=22,
    d_model=2048,
    d_ff=5632,
    vocab_size=32000,
    attention=AttentionConfig(
        num_heads=32,
        num_kv_heads=4,
        head_dim=64,             # 2048 / 32
        rope_theta=10000.0,
    ),
    norm="rmsnorm",
    act="silu",
    optimizer="adamw",
    long_context_mode="sliding_window",
)
