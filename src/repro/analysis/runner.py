"""Walk files, run rules, apply suppressions, render findings.

``python -m repro.analysis src tests benchmarks`` is the CI lint lane;
exit status 0 means every finding is either fixed or explicitly
allowlisted with a justification (per-line ``# allow[rule-id]: why``
pragmas or ``analysis-allowlist.toml`` entries).
"""
from __future__ import annotations

import json
import os
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import AllowEntry, Finding, Suppressions

#: paths never scanned: the golden fixtures *intentionally* trip rules
DEFAULT_EXCLUDES = ("tests/fixtures/analysis",)

DEFAULT_ALLOWLIST = "analysis-allowlist.toml"


@dataclass
class Report:
    findings: List[Finding]
    suppressed: List[Finding]
    n_files: int
    parse_errors: List[Finding] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings and not self.parse_errors

    def as_json(self) -> dict:
        return {
            "version": 1,
            "ok": self.ok,
            "files": self.n_files,
            "findings": [f.as_json()
                         for f in self.findings + self.parse_errors],
            "suppressed": [f.as_json() for f in self.suppressed],
        }


def _walk_py(paths: Sequence[str], root: str,
             excludes: Sequence[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        ap = os.path.join(root, p) if not os.path.isabs(p) else p
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(ap)
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = [d for d in sorted(dirnames)
                           if d != "__pycache__" and not d.startswith(".")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    rels = []
    for ap in out:
        rel = os.path.relpath(ap, root).replace(os.sep, "/")
        if any(rel.startswith(e) for e in excludes):
            continue
        rels.append(rel)
    return sorted(set(rels))


def load_project(paths: Sequence[str], *, root: Optional[str] = None,
                 excludes: Sequence[str] = DEFAULT_EXCLUDES,
                 ) -> tuple[Project, List[Finding]]:
    root = root or os.getcwd()
    modules: List[ModuleInfo] = []
    errors: List[Finding] = []
    for rel in _walk_py(paths, root, excludes):
        with open(os.path.join(root, rel), "r", encoding="utf-8") as fh:
            source = fh.read()
        try:
            modules.append(ModuleInfo.parse(rel, source))
        except SyntaxError as e:
            errors.append(Finding(
                file=rel, line=e.lineno or 1, rule="parse-error",
                message=f"file does not parse: {e.msg}",
            ))
    return Project(root=root, modules=modules), errors


def run_analysis(paths: Sequence[str], *, root: Optional[str] = None,
                 rules: Optional[Sequence[str]] = None,
                 allowlist: Optional[str] = DEFAULT_ALLOWLIST,
                 excludes: Sequence[str] = DEFAULT_EXCLUDES) -> Report:
    from repro.analysis.rules import ALL_RULES, RULES_BY_ID

    root = root or os.getcwd()
    project, parse_errors = load_project(paths, root=root, excludes=excludes)

    selected = ALL_RULES if rules is None else [
        RULES_BY_ID[r] for r in rules
    ]
    findings: List[Finding] = []
    for rule in selected:
        findings.extend(rule.check(project))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))

    entries: List[AllowEntry] = []
    if allowlist:
        al_path = os.path.join(root, allowlist)
        if os.path.exists(al_path):
            entries = Suppressions.load_toml(al_path)
    supp = Suppressions(entries)
    lines_by_file: Dict[str, List[str]] = {
        m.path: m.lines for m in project.modules
    }
    kept, suppressed = supp.filter(findings, lines_by_file)
    return Report(findings=kept, suppressed=suppressed,
                  n_files=len(project.modules), parse_errors=parse_errors)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    from repro.analysis.rules import ALL_RULES

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=("invariant lint pass: device-resident / mesh-correct "
                     "contract rules for this repo"),
    )
    ap.add_argument("paths", nargs="*", default=["src", "tests", "benchmarks"],
                    help="files or directories to scan (default: src tests "
                         "benchmarks)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--allowlist", default=DEFAULT_ALLOWLIST,
                    help="TOML allowlist path (default: "
                         f"{DEFAULT_ALLOWLIST}; pass '' to disable)")
    ap.add_argument("--root", default=None,
                    help="repo root paths are relative to (default: cwd)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.RULE_ID:20s} {r.DOC}")
        return 0

    report = run_analysis(
        args.paths or ["src", "tests", "benchmarks"],
        root=args.root,
        rules=args.rules.split(",") if args.rules else None,
        allowlist=args.allowlist or None,
    )
    if args.format == "json":
        print(json.dumps(report.as_json(), indent=2))
    else:
        for f in report.findings + report.parse_errors:
            print(f.render())
        print(f"# scanned {report.n_files} files: "
              f"{len(report.findings) + len(report.parse_errors)} finding(s), "
              f"{len(report.suppressed)} suppressed")
    return 0 if report.ok else 1
