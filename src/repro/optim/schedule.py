"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        t = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched
