"""Quadratic subproblem solver (paper Algorithm 2).

Minimize, over the machine's feature block S_m,

    L_q(beta, dbeta) + lam * ||beta + dbeta||_1
    = 1/2 sum_i w_i (z_i - dbeta^T x_i)^2 + lam * ||beta + dbeta||_1 + C

with ONE cycle of cyclic coordinate descent (the paper found one cycle
sufficient; ``n_cycles`` is configurable). Damping: h_j += nu (paper's
H~ + nu*I with nu = 1e-6).

Two mathematically identical implementations:

* ``cd_cycle_residual`` — the paper-literal form: sequential sweep with the
  per-example residual r_i = z_i - dbeta^T x_i updated after each coordinate.
  O(n * p_b) streaming work; the reference/oracle.
* ``cd_cycle_gram`` — the TPU-native form (DESIGN.md §2.3): per feature tile
  compute G = X_F^T diag(w) X_F and c = X_F^T (w*r) with MXU matmuls, run the
  sequential cycle on the F x F Gram tile (Pallas kernel `gram_cd`), then
  reconstruct the residual update with one more matmul. Identical iterates.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.objective import soft_threshold

NU = 1e-6


# ---------------------------------------------------------------------------
# paper-literal residual-update CD
# ---------------------------------------------------------------------------

def cd_cycle_residual(
    X: jnp.ndarray,          # (n, p_b) the machine's feature block
    w: jnp.ndarray,          # (n,)
    r: jnp.ndarray,          # (n,) residual z - dbeta^T x (block-local)
    beta: jnp.ndarray,       # (p_b,) current weights for this block
    dbeta: jnp.ndarray,      # (p_b,) accumulated update for this block
    lam: float,
    nu: float = NU,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One cycle over all features in the block. Returns (dbeta, r)."""

    h_all = (w[:, None] * X * X).sum(axis=0) + nu   # (p_b,) curvature per coord

    def body(j, carry):
        dbeta, r = carry
        xj = jax.lax.dynamic_slice_in_dim(X, j, 1, axis=1)[:, 0]
        g = jnp.dot(w * xj, r)                      # sum_i w x_ij r_i
        h = h_all[j]
        b_old = beta[j] + dbeta[j]
        b_new = soft_threshold(g + b_old * h, lam) / h
        delta = b_new - b_old
        r = r - delta * xj
        dbeta = dbeta.at[j].add(delta)
        return dbeta, r

    dbeta, r = jax.lax.fori_loop(0, X.shape[1], body, (dbeta, r))
    return dbeta, r


# ---------------------------------------------------------------------------
# Gram-tile CD (TPU-native; same iterates)
# ---------------------------------------------------------------------------

def cd_cycle_jacobi_tile(
    G: jnp.ndarray,
    c: jnp.ndarray,
    beta: jnp.ndarray,
    dbeta0: jnp.ndarray,
    lam: float,
    nu: float = NU,
) -> jnp.ndarray:
    """Shotgun-style ablation (Bradley et al. 2011, paper §1): ALL
    coordinates updated in parallel from the same residual (Jacobi), no
    within-tile sequencing. Fully parallel but updates conflict when
    features correlate — the paper's motivation for sequential cycles within
    blocks + a global line search. Used by the ablation benchmark only."""
    diag = jnp.diagonal(G) + nu
    b_old = beta + dbeta0
    u = c + b_old * diag
    b_new = soft_threshold(u, lam) / diag
    return b_new - b_old


def cd_cycle_gram_tile(
    G: jnp.ndarray,          # (F, F) = X_F^T diag(w) X_F
    c: jnp.ndarray,          # (F,)   = X_F^T (w * r) at tile entry
    beta: jnp.ndarray,       # (F,)
    dbeta0: jnp.ndarray,     # (F,) accumulated update at tile entry
    lam: float,
    nu: float = NU,
) -> jnp.ndarray:
    """Sequential CD cycle on a Gram tile; returns the *delta within this
    cycle* d (so dbeta becomes dbeta0 + d). Pure-jnp oracle for the Pallas
    kernel ``gram_cd``.

    Maintains s = G @ d so that  g_j = c_j - s_j  equals  sum w x_j r  with
    r the live residual.
    """
    f = G.shape[0]
    diag = jnp.diagonal(G) + nu

    def body(j, carry):
        d, s = carry
        g = c[j] - s[j]
        h = diag[j]
        b_old = beta[j] + dbeta0[j] + d[j]
        b_new = soft_threshold(g + b_old * h, lam) / h
        delta = b_new - b_old
        s = s + delta * G[:, j]
        d = d.at[j].add(delta)
        return d, s

    # zeros_like(c) keeps shard_map varying-axis metadata consistent
    d, _ = jax.lax.fori_loop(0, f, body, (jnp.zeros_like(c), jnp.zeros_like(c)))
    return d


def cd_cycle_gram(
    X: jnp.ndarray,
    w: jnp.ndarray,
    r: jnp.ndarray,
    beta: jnp.ndarray,
    dbeta: jnp.ndarray,
    lam: float,
    *,
    tile: int = 256,
    nu: float = NU,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One full CD cycle over the block via Gram tiles (exact, tiled).

    Residual is updated *between* tiles with a dense matmul, so iterates are
    identical to ``cd_cycle_residual``.
    """
    n, p_b = X.shape
    pad = (-p_b) % tile
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        beta = jnp.pad(beta, (0, pad))
        dbeta = jnp.pad(dbeta, (0, pad))
    pt = X.shape[1]
    nt = pt // tile
    Xt = X.reshape(n, nt, tile)

    if use_kernel:
        from repro.kernels.ops import gram_cd as tile_solver
    else:
        tile_solver = None

    def tile_step(carry, idx):
        r, dbeta_f = carry
        Xf = Xt[:, idx, :]                           # (n, F)
        wX = w[:, None] * Xf
        G = Xf.T @ wX                                # (F, F) MXU
        c = wX.T @ r                                 # (F,)
        b_f = jax.lax.dynamic_slice(beta, (idx * tile,), (tile,))
        db_f = jax.lax.dynamic_slice(dbeta_f, (idx * tile,), (tile,))
        if tile_solver is not None:
            d = tile_solver(G, c, b_f, db_f, lam, nu)
        else:
            d = cd_cycle_gram_tile(G, c, b_f, db_f, lam, nu)
        r = r - Xf @ d                               # residual to next tile
        dbeta_f = jax.lax.dynamic_update_slice(dbeta_f, db_f + d, (idx * tile,))
        return (r, dbeta_f), None

    (r, dbeta), _ = jax.lax.scan(tile_step, (r, dbeta), jnp.arange(nt))
    return dbeta[:p_b], r


def solve_subproblem(
    X: jnp.ndarray,
    w: jnp.ndarray,
    z: jnp.ndarray,
    beta: jnp.ndarray,
    lam: float,
    *,
    method: str = "gram",        # "gram" | "residual"
    n_cycles: int = 1,
    tile: int = 256,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Algorithm 2 on one feature block.

    Returns (dbeta, dmargin) where dmargin = X @ dbeta (the per-example
    update the paper all-reduces alongside dbeta).
    """
    dbeta = jnp.zeros_like(beta)
    r = z                                            # dbeta = 0 initially

    for _ in range(n_cycles):
        if method == "residual":
            dbeta, r = cd_cycle_residual(X, w, r, beta, dbeta, lam)
        elif method == "gram":
            dbeta, r = cd_cycle_gram(
                X, w, r, beta, dbeta, lam, tile=tile, use_kernel=use_kernel
            )
        elif method == "jacobi":
            # Shotgun-style ablation: fully parallel updates, no sequencing
            wX = w[:, None] * X
            G = X.T @ wX
            c = wX.T @ r
            d = cd_cycle_jacobi_tile(G, c, beta, dbeta, lam)
            dbeta = dbeta + d
            r = r - X @ d
        else:
            raise ValueError(method)

    return dbeta, X @ dbeta
