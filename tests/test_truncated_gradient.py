"""Baseline (distributed online learning via truncated gradient) tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import TGOptions, lambda_max, margins, objective, truncated_gradient_fit


def test_tg_learns(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 64
    snaps = truncated_gradient_fit(
        X, y, lam, opts=TGOptions(num_machines=8, passes=8, learning_rate=0.1,
                                  decay=0.5),
        key=jax.random.key(0))
    beta0 = jnp.zeros(X.shape[1])
    f0 = float(objective(margins(X, beta0), y, beta0, lam))
    f_end = float(objective(margins(X, snaps[-1][1]), y, snaps[-1][1], lam))
    assert f_end < f0, (f_end, f0)


def test_tg_sparsity_increases_with_lambda(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lmax = float(lambda_max(X, y))
    nnz = []
    for lam in (lmax / 4, lmax / 64):
        snaps = truncated_gradient_fit(
            X, y, lam, opts=TGOptions(num_machines=4, passes=5), key=jax.random.key(1))
        nnz.append(int((jnp.abs(snaps[-1][1]) > 1e-8).sum()))
    assert nnz[0] <= nnz[1]


def test_tg_snapshots_every_pass(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    snaps = truncated_gradient_fit(
        X, y, 1.0, opts=TGOptions(num_machines=4, passes=3), key=jax.random.key(2))
    assert [s[0] for s in snaps] == [1, 2, 3]
