"""Pallas TPU kernel: fused logistic working statistics.

One pass over the margin cache m_i = beta^T x_i producing everything the
d-GLMNET outer iteration needs from the examples axis (paper eq. (4)):

    p = sigmoid(m) (clamped), w = p(1-p), z = ((y+1)/2 - p)/w,
    nll_partial = sum softplus(-y m)

Fusing avoids 4 separate HBM sweeps over the O(n) vectors — this matters
because the examples axis is the big one (n up to 45M in Table 2). Tiled
(1, BLOCK) over n with a grid; per-block partial NLL sums are reduced by
the caller.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

P_EPS = 1e-5
W_MIN = 1e-6


def _logistic_stats_kernel(m_ref, y_ref, w_ref, z_ref, nll_ref):
    m = m_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    p = jax.nn.sigmoid(m)
    p = jnp.clip(p, P_EPS, 1.0 - P_EPS)
    w = jnp.maximum(p * (1.0 - p), W_MIN)
    w_ref[...] = w
    z_ref[...] = ((y + 1.0) * 0.5 - p) / w
    nll_ref[0, 0] = jnp.sum(jax.nn.softplus(-y * m))


@partial(jax.jit, static_argnames=("block", "interpret"))
def logistic_stats_pallas(m, y, *, block: int = 4096, interpret: bool = True):
    """Returns (w, z, nll). m, y: (n,) float32."""
    n = m.shape[0]
    pad = (-n) % block
    if pad:
        # padded tail: y=+1, m=+40 -> w=W_MIN clamp, softplus ~ 0
        m = jnp.pad(m, (0, pad), constant_values=40.0)
        y = jnp.pad(y, (0, pad), constant_values=1.0)
    npad = m.shape[0]
    grid = (npad // block,)

    w, z, nll = pl.pallas_call(
        _logistic_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, npad), jnp.float32),
            jax.ShapeDtypeStruct((1, npad), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(m[None].astype(jnp.float32), y[None].astype(jnp.float32))
    return w[0, :n], z[0, :n], jnp.sum(nll)
