"""Batched path scoring: one jitted dispatch per request batch.

The scoring step is ``kernels.ops.slab_path_spmv`` over a
:class:`~repro.serve.ingest.PackedBatch` — the by-feature slab layout the
training kernels consume, request rows playing the example axis, each row
gathering its own operating point from the store's stacked ``(L, p)``
coefficients. Locally that is one jitted call; on a mesh it is the same
``shard_map`` shape as ``core.distributed.make_slab_margins`` (feature
shards run the slab kernel, one psum over ``model`` assembles the scores)
with the beta *stack* left P(model)-sharded in place. Either way exactly
one program launches per batch and only the ``(batch,)`` scores travel to
host.

Because the per-entry coefficient gather feeds the *same* masking/scatter
machinery as ``slab_spmv`` (see ``slab_path_spmv``'s docstring), a batch
whose rows all request lambda ``l`` scores bit-identically to
``LogisticL1.decision_function(design, beta=path[l])`` on the same slabs —
locally and through the mesh.
"""
from __future__ import annotations

from collections import OrderedDict
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.obs import trace as obs_trace
from repro.resilience import serve_delay
from repro.serve.ingest import PackedBatch
from repro.serve.store import PathStore, StoreSnapshot


class NonFiniteScores(RuntimeError):
    """Every published snapshot the scorer tried produced NaN/Inf scores
    for this batch. Raised only after the store has been pinned back to
    its last-good snapshot (when one existed) and the batch retried — so
    a caller seeing this knows rollback did not help and the *batch*
    itself is suspect."""


@partial(jax.jit, static_argnames=("n_loc",))
def _score_local(rows, vals, lam_idx, betas, *, n_loc: int):
    return kops.slab_path_spmv(rows, vals, lam_idx, betas, n_loc=n_loc)


def make_path_margins(mesh, n_loc: int, model_axis: str = "model"):
    """Sharded batched path scoring ``(row_idx, values, lam_idx, betas) ->
    scores`` — ``core.distributed.make_slab_margins`` with the replicated
    beta vector replaced by the P(model)-sharded ``(L, p_pad)`` stack plus
    a per-row operating-point index. Each (model, data) shard gathers its
    own coefficient block rows and runs the slab kernel; one psum over
    ``model`` assembles the exact scores.

    Deliberately NOT module-cached: a process-lifetime cache here pins the
    mesh (and through jit internals, the last dispatch's arguments —
    i.e. a retired snapshot's beta stack) for as long as the module
    lives. :class:`PathScorer` owns a small per-instance cache instead,
    so dropping the scorer drops the compiled programs and
    ``PathStore.swap`` can actually release the old coefficients."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core.distributed import _data_axes

    daxes = _data_axes(mesh)
    dspec = P(daxes) if daxes else P()

    @jax.jit
    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(model_axis, daxes, None), P(model_axis, daxes, None),
                  dspec, P(None, model_axis)),
        out_specs=dspec,
    )
    def path_margins(row_idx, values, lam_idx, betas):
        rows, vals = row_idx[:, 0, :], values[:, 0, :]
        s_loc = kops.slab_path_spmv(rows, vals, lam_idx, betas,
                                    n_loc=n_loc)
        return jax.lax.psum(s_loc, model_axis)

    return path_margins


class PathScorer:
    """Scores request batches against a :class:`PathStore`.

    Each :meth:`score` call takes ONE store snapshot up front and resolves
    lambdas + scores entirely against it, so a concurrent
    ``PathStore.swap`` can never mix coefficient versions inside a batch;
    the returned version says which path the whole batch was scored with.
    """

    #: distinct (mesh, n_loc) program geometries kept per scorer; a
    #: serving process sees a handful of batch capacities, so eviction
    #: means at worst a recompile, never wrong scores
    _CACHE_MAX = 8

    def __init__(self, store: PathStore):
        self.store = store
        self._margins: "OrderedDict[tuple, object]" = OrderedDict()

    def _margins_for(self, mesh, n_loc: int):
        """Per-instance LRU of compiled sharded scoring programs."""
        key = (mesh, n_loc)
        fn = self._margins.get(key)
        if fn is None:
            fn = make_path_margins(mesh, n_loc)
            self._margins[key] = fn
            while len(self._margins) > self._CACHE_MAX:
                self._margins.popitem(last=False)
        else:
            self._margins.move_to_end(key)
        return fn

    def score(self, batch: PackedBatch,
              lams) -> Tuple[np.ndarray, int]:
        """Score a packed batch; ``lams[i]`` is row i's requested lambda.

        Returns ``(scores, version)``: ``scores`` are the ``(n_live,)``
        margins x_i^T beta_{lam_i} (feed ``jax.nn.sigmoid`` for
        probabilities), ``version`` the store version used for every row.

        Non-finite guard: scores cross to host here anyway (the one
        device->host hop of the serve loop), so they are checked before
        being returned. A snapshot that yields NaN/Inf is quarantined —
        the store pins back to its last-good snapshot and the batch is
        rescored against that — and only if no snapshot survives does
        :class:`NonFiniteScores` escape. Requests never see poison.

        The ``score`` span closes at the existing ``np.asarray`` host
        sync on the scores — tracing adds no extra device->host hop.
        """
        with obs_trace.span("score", rows=int(batch.n_live)) as sp:
            scores, version = self._score(batch, lams)
            sp.set(version=version)
            return scores, version

    def _score(self, batch: PackedBatch,
               lams) -> Tuple[np.ndarray, int]:
        lams = np.asarray(lams, np.float64).reshape(-1)
        if lams.shape[0] != batch.n_live:
            raise ValueError(
                f"{lams.shape[0]} lambdas for {batch.n_live} requests")
        while True:
            snap = self.store.snapshot      # one read per attempt
            if batch.p != snap.p:
                raise ValueError(
                    f"batch hashed to p={batch.p} but the store serves "
                    f"p={snap.p}")
            if batch.p_pad != snap.p_pad:
                raise ValueError(
                    f"batch feature padding {batch.p_pad} != store padding "
                    f"{snap.p_pad} — pack with pad_p_to=store.pad_p_to")
            # lambdas resolve against the snapshot actually scored with
            lam_idx = np.zeros(batch.batch_cap, np.int32)
            if batch.n_live:
                lam_idx[:batch.n_live] = snap.indices_of(lams)
            serve_delay()                   # chaos latency injection point
            scores = np.asarray(self._dispatch(batch, lam_idx, snap))
            live = scores[:batch.n_live]
            if np.all(np.isfinite(live)):
                return live, snap.version
            # rollback-and-retry: each quarantine() retires one version,
            # so the loop is bounded by the (finite) rollback chain
            if not self.store.quarantine(snap.version):
                raise NonFiniteScores(
                    f"non-finite scores from path version {snap.version} "
                    f"and no last-good snapshot left to pin to"
                )

    def _dispatch(self, batch: PackedBatch, lam_idx: np.ndarray,
                  snap: StoreSnapshot):
        mesh = self.store.mesh
        if mesh is None:
            if batch.dp != 1:
                raise ValueError(
                    f"local scoring needs dp=1 slabs, got dp={batch.dp}")
            return _score_local(
                jnp.asarray(batch.row_idx[:, 0, :]),
                jnp.asarray(batch.values[:, 0, :]),
                jnp.asarray(lam_idx), snap.betas, n_loc=batch.batch_cap)
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core.distributed import _data_axes, _data_extent

        if batch.dp != _data_extent(mesh):
            raise ValueError(
                f"batch dp={batch.dp} != mesh data extent "
                f"{_data_extent(mesh)} — pack with dp=store ddim")
        daxes = _data_axes(mesh)
        slab_sh = NamedSharding(mesh, P("model", daxes, None))
        fn = self._margins_for(mesh, batch.n_loc)
        # request slabs are transient placements, routed through the
        # residency module's sanctioned door (bucket-residency rule)
        from repro.data.residency import put_slab

        rows_dev, vals_dev = put_slab(batch.row_idx, batch.values, slab_sh)
        return fn(
            rows_dev, vals_dev,
            jax.device_put(lam_idx, NamedSharding(mesh, P(daxes))),
            snap.betas)
