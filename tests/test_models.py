"""Model-zoo unit tests: attention equivalences, SSD scan consistency,
MoE dispatch conservation, MLA decode vs prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import MODEL_CONFIGS
from repro.configs.base import AttentionConfig, MoEConfig, SSMConfig
from repro.models.attention import (
    attention_forward,
    init_attention,
    init_kv_cache,
    sdpa,
)
from repro.models.moe import capacity, init_moe, moe_forward
from repro.models.ssm import init_mamba2, init_ssm_cache, mamba2_forward


def test_sdpa_chunked_equals_single_block():
    key = jax.random.key(0)
    b, s, h, dh = 2, 256, 4, 32
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = sdpa(q, k, v, pos, pos, scale=0.1, q_chunk=1024)   # single block
    chunked = sdpa(q, k, v, pos, pos, scale=0.1, q_chunk=64)  # 4 chunks
    np.testing.assert_allclose(full, chunked, atol=1e-5)


def test_sliding_window_limits_attention():
    """With window w, output at position t must not depend on tokens < t-w+1."""
    key = jax.random.key(1)
    b, s, h, dh, w = 1, 64, 2, 16, 8
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out1 = sdpa(q, k, v, pos, pos, scale=0.25, window=w)
    # perturb v at position 0: outputs at t >= w must be unchanged
    v2 = v.at[:, 0].add(100.0)
    out2 = sdpa(q, k, v2, pos, pos, scale=0.25, window=w)
    np.testing.assert_allclose(out1[:, w:], out2[:, w:], atol=1e-5)
    assert not np.allclose(out1[:, 0], out2[:, 0])


def test_gqa_prefill_decode_consistency():
    """Prefill on s tokens, then decode token s; must match a full forward
    over s+1 tokens at the last position."""
    cfg = AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    d_model = 64
    key = jax.random.key(2)
    p = init_attention(key, cfg, d_model, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s + 1, d_model))
    pos_full = jnp.broadcast_to(jnp.arange(s + 1)[None], (b, s + 1))

    y_full, _ = attention_forward(
        p, x, cfg=cfg, d_model=d_model, positions=pos_full, mode="train")

    y_pre, cache = attention_forward(
        p, x[:, :s], cfg=cfg, d_model=d_model, positions=pos_full[:, :s],
        mode="prefill")
    # grow cache to s+1 and decode the last token
    cache = {kk: jnp.pad(vv, ((0, 0), (0, 1), (0, 0), (0, 0)))
             for kk, vv in cache.items()}
    y_dec, _ = attention_forward(
        p, x[:, s:], cfg=cfg, d_model=d_model,
        positions=pos_full[:, s:], mode="decode", cache=cache,
        cache_index=jnp.asarray(s, jnp.int32))
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, s], atol=1e-4)


def test_mla_prefill_decode_consistency():
    cfg = AttentionConfig(
        num_heads=4, num_kv_heads=4, use_mla=True, q_lora_rank=32,
        kv_lora_rank=16, qk_rope_head_dim=8, qk_nope_head_dim=16, v_head_dim=16)
    d_model = 64
    key = jax.random.key(3)
    p = init_attention(key, cfg, d_model, jnp.float32)
    b, s = 2, 10
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s + 1, d_model))
    pos = jnp.broadcast_to(jnp.arange(s + 1)[None], (b, s + 1))
    y_full, _ = attention_forward(p, x, cfg=cfg, d_model=d_model,
                                  positions=pos, mode="train")
    _, cache = attention_forward(p, x[:, :s], cfg=cfg, d_model=d_model,
                                 positions=pos[:, :s], mode="prefill")
    cache = {kk: jnp.pad(vv, ((0, 0), (0, 1), (0, 0))) for kk, vv in cache.items()}
    y_dec, _ = attention_forward(
        p, x[:, s:], cfg=cfg, d_model=d_model, positions=pos[:, s:],
        mode="decode", cache=cache, cache_index=jnp.asarray(s, jnp.int32))
    # absorbed decode vs direct train form
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, s], atol=1e-4)


def test_ssd_prefill_decode_consistency():
    """Chunked SSD scan then single-step decode == full scan over s+1."""
    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, conv_width=4, chunk_size=8)
    d_model = 32
    key = jax.random.key(4)
    p = init_mamba2(key, cfg, d_model, jnp.float32)
    b, s = 2, 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (b, s + 1, d_model))

    y_full, _ = mamba2_forward(p, x, cfg=cfg, d_model=d_model, mode="train")
    y_pre, cache = mamba2_forward(p, x[:, :s], cfg=cfg, d_model=d_model,
                                  mode="prefill")
    np.testing.assert_allclose(y_pre, y_full[:, :s], atol=1e-4)
    y_dec, _ = mamba2_forward(p, x[:, s:], cfg=cfg, d_model=d_model,
                              mode="decode", cache=cache)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, s], atol=1e-4)


def test_ssd_chunk_size_invariance():
    cfg8 = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=8)
    cfg32 = SSMConfig(d_state=8, head_dim=8, expand=2, chunk_size=32)
    d_model = 32
    key = jax.random.key(5)
    p = init_mamba2(key, cfg8, cfg8.d_inner(d_model) // cfg8.expand, jnp.float32)
    p = init_mamba2(key, cfg8, d_model, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, d_model))
    y8, _ = mamba2_forward(p, x, cfg=cfg8, d_model=d_model, mode="train")
    y32, _ = mamba2_forward(p, x, cfg=cfg32, d_model=d_model, mode="train")
    np.testing.assert_allclose(y8, y32, atol=1e-4)


def test_moe_gate_conservation_and_dispatch():
    cfg = MoEConfig(num_experts=4, top_k=2, expert_d_ff=32,
                    capacity_factor=4.0)  # big capacity: no drops
    d_model = 16
    key = jax.random.key(6)
    p = init_moe(key, cfg, d_model, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, d_model))
    y, aux = moe_forward(p, x, cfg=cfg)
    assert y.shape == x.shape
    assert float(aux["moe_drop_frac"]) == 0.0
    assert np.isfinite(np.asarray(y)).all()
    # load-balance loss is >= 1 (equality at perfect uniformity)
    lb = float(aux["moe_lb_loss"]) / cfg.aux_loss_weight
    assert lb >= 0.99


def test_moe_capacity_drops():
    cfg = MoEConfig(num_experts=4, top_k=1, expert_d_ff=16,
                    capacity_factor=0.26)
    d_model = 8
    key = jax.random.key(7)
    p = init_moe(key, cfg, d_model, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, d_model))
    y, aux = moe_forward(p, x, cfg=cfg)
    assert float(aux["moe_drop_frac"]) > 0.0  # over-capacity tokens dropped
    assert np.isfinite(np.asarray(y)).all()


@pytest.mark.parametrize("arch", ["qwen2-vl-72b"])
def test_mrope_text_equals_positions(arch):
    """For pure text (t=h=w), M-RoPE must be a valid rotary embedding:
    relative-position property holds."""
    from repro.models.layers import apply_mrope, text_mrope_positions

    cfg = MODEL_CONFIGS[arch].smoke().attention
    dh = 64
    key = jax.random.key(8)
    q = jax.random.normal(key, (1, 4, 2, dh))
    pos = jnp.arange(4)[None]
    out = apply_mrope(q, text_mrope_positions(pos), cfg.rope_theta, cfg.mrope_sections)
    assert out.shape == q.shape
    # norm preservation (rotations)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(q), axis=-1), rtol=1e-5)


def test_sdpa_flash_kernel_backend_matches_jnp():
    """The Pallas flash-attention backend must match the chunked jnp path."""
    from repro.models.attention import sdpa

    key = jax.random.key(9)
    b, s, h, hk, dh = 2, 256, 4, 2, 64
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hk, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hk, dh))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    scale = 1.0 / dh**0.5
    o_jnp = sdpa(q, k, v, pos, pos, scale=scale, q_chunk=64)
    o_flash = sdpa(q, k, v, pos, pos, scale=scale, use_flash_kernel=True)
    np.testing.assert_allclose(o_jnp, o_flash, atol=2e-5)
