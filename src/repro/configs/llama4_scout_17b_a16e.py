"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + shared expert, early
fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

"Early fusion" refers to interleaved multimodal tokens; text-token dry-run
shapes are used here (vision tower is out of assigned scope for this entry).
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E model card",
    num_layers=48,
    d_model=5120,
    d_ff=8192,                   # shared-expert / dense width
    vocab_size=202048,
    attention=AttentionConfig(
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        rope_theta=500_000.0,
        sliding_window=0,        # full attn baseline; long_500k uses window
    ),
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        num_shared_experts=1,
        expert_d_ff=8192,
        capacity_factor=1.25,
    ),
    norm="rmsnorm",
    act="silu",
    microbatch=4,
    optimizer="adamw",
    long_context_mode="sliding_window",
)
