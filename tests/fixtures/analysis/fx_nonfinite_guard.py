"""Golden fixture: trips nonfinite-guard and nothing else.

A serve-layer helper that materializes a computed (device) score batch
on host and returns it with no isfinite/isnan check — exactly the hole
the rule exists to catch: a poisoned coefficient row would sail through
this return straight into a response.
"""
import numpy as np

from repro.serve.store import PathStore  # noqa: F401  (marks serve scope)


def serve_scores(scorer, batch, lam_idx, snap):
    return np.asarray(scorer.dispatch(batch, lam_idx, snap))[: batch.n_live]
