"""The ``Design`` protocol: one abstraction over every data layout.

Four PRs of scaling work left the solvers with three incompatible design-
matrix layouts (dense ``X``, by-feature ``(row_idx, values)`` slabs,
nnz-bucketed :class:`~repro.data.byfeature.SlabBuckets`) and the layout
branching hardcoded into every driver. This module absorbs that branching:
a *design* is anything that can answer the five questions the d-GLMNET
machinery ever asks of the data —

* ``margins(beta)``      — the O(n) state, X @ beta;
* ``correlation(v)``     — the gradient pass, X^T v (screening, lambda_max);
* ``gram_tile(w, r, start, width)`` — weighted Gram tile + correlation for
  a feature window (the subproblem's statistics; the per-layout oracle the
  fused solver programs are tested against);
* ``gather``/``scatter`` — the active-set restriction and its inverse;
* ``shape``/``layout``   — what the strategy resolver dispatches on.

All public methods speak the **original feature axis**: masks, ``beta``
and ``correlation`` outputs are ordered 0..p-1 regardless of any internal
bucket permutation or mesh padding (the work-axis bookkeeping that used to
leak into ``core/regpath.py`` is private to the designs).

Implementations:

* :class:`DenseDesign`        — (n, p) dense array.
* :class:`SlabDesign`         — by-feature (p, DP, K) slabs, local row
  indices with sentinel ``n_loc`` (DP = 1 is the single-shard form).
* :class:`BucketedSlabDesign` — nnz-bucketed capacity classes
  (:class:`~repro.data.byfeature.SlabBuckets`).
* :class:`ShardedDesign`      — any of the above wrapped onto a JAX mesh:
  margins/correlation become shard_map slab streams (psum over the data
  axes), gather becomes the feature-axis reshard into a capacity-bucketed
  P(model) layout. No dense (n, p) X ever materializes for slab layouts.

``as_design`` coerces the historical entry-point operands (arrays,
``ByFeature``, raw slab tuples, ``SlabBuckets``) into designs so the
legacy API can delegate.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import ClassVar, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.screening import gather_columns, scatter_columns
from repro.sharding.collect import concat_replicated
from repro.data.byfeature import (
    ByFeature,
    SlabBuckets,
    gather_features,
    gather_features_buckets,
    scatter_features,
    take_buckets_iter,
    take_features_buckets,
    to_slabs,
)
from repro.data.residency import BucketResidencyManager


@runtime_checkable
class Design(Protocol):
    """What every data layout must answer; see the module docstring."""

    layout: str

    @property
    def shape(self) -> Tuple[int, int]: ...          # (n, p)

    def margins(self, beta): ...                     # X @ beta -> (n,)

    def correlation(self, v): ...                    # X^T v   -> (p,)

    def gram_tile(self, w, r, start: int, width: int): ...  # (G, c)

    def gather(self, beta, mask, cap: int, *, k_cap: Optional[int] = None):
        ...                                          # (sub Design, beta_sub, idx)

    def scatter(self, beta_sub, idx): ...            # -> full beta (p,)


# ---------------------------------------------------------------------------
# DenseDesign
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class DenseDesign:
    """Dense (n, p) design matrix — the paper's epsilon/gisette regime."""

    X: jnp.ndarray
    layout: ClassVar[str] = "dense"

    @property
    def shape(self) -> Tuple[int, int]:
        return (int(self.X.shape[0]), int(self.X.shape[1]))

    def margins(self, beta):
        return self.X @ beta

    def correlation(self, v):
        return self.X.T @ v

    def gram_tile(self, w, r, start: int, width: int):
        n = self.X.shape[0]
        Xf = jax.lax.dynamic_slice(self.X, (0, start), (n, width))
        wXf = w[:, None] * Xf
        return Xf.T @ wXf, wXf.T @ r

    def gather(self, beta, mask, cap: int, *, k_cap: Optional[int] = None):
        X_sub, beta_sub, idx = gather_columns(self.X, beta, mask, cap)
        return DenseDesign(X_sub), beta_sub, idx

    def scatter(self, beta_sub, idx):
        return scatter_columns(beta_sub, idx, self.shape[1])


# ---------------------------------------------------------------------------
# SlabDesign
# ---------------------------------------------------------------------------

def _slab_front_packed(row_idx, n_loc: int) -> bool:
    """Whether every slab's K axis is front-packed (live slots first).
    Only front-packed slabs are eligible for the positional K-capacity
    trim (``gather_features(..., k_cap)``)."""
    valid = row_idx < n_loc
    return bool(jnp.all(valid[..., 1:] <= valid[..., :-1]))


@dataclass(eq=False)
class SlabDesign:
    """By-feature (p, DP, K) slabs with *local* row indices (sentinel
    ``n_loc``) — the paper's Table-1 layout keyed for DP data shards.
    DP = 1 is the plain single-process by-feature form."""

    row_idx: jnp.ndarray         # (p, DP, K) int32
    values: jnp.ndarray          # (p, DP, K) float32
    n: int                       # global example count (= DP * n_loc)
    front_packed: bool = True
    layout: ClassVar[str] = "slab"

    @classmethod
    def from_by_feature(cls, bf: ByFeature, dp: int = 1) -> "SlabDesign":
        row_idx, values, _ = to_slabs(bf, dp)
        return cls(row_idx, values, bf.n, front_packed=True)

    @classmethod
    def from_dense(cls, X, dp: int = 1) -> "SlabDesign":
        from repro.data.byfeature import to_by_feature

        return cls.from_by_feature(to_by_feature(X), dp)

    @property
    def dp(self) -> int:
        return int(self.row_idx.shape[1])

    @property
    def n_loc(self) -> int:
        return self.n // max(self.dp, 1)

    @property
    def k(self) -> int:
        return int(self.row_idx.shape[2])

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, int(self.row_idx.shape[0]))

    def _shard(self, v, s: int):
        return jax.lax.dynamic_slice(v, (s * self.n_loc,), (self.n_loc,))

    def margins(self, beta):
        from repro.kernels.ops import slab_spmv

        parts = [
            slab_spmv(self.row_idx[:, s], self.values[:, s], beta,
                      n_loc=self.n_loc)
            for s in range(self.dp)
        ]
        # allow[sharded-concat]: single-process slab path — per-shard pieces are local unsharded arrays; the mesh path routes through core.distributed's shard_map
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def correlation(self, v):
        from repro.kernels.ops import slab_corr

        g = None
        for s in range(self.dp):
            gs = slab_corr(self.row_idx[:, s], self.values[:, s],
                           self._shard(v, s))
            g = gs if g is None else g + gs
        return g

    def gram_tile(self, w, r, start: int, width: int):
        from repro.kernels.ops import slab_gram

        G = c = None
        for s in range(self.dp):
            rows = jax.lax.dynamic_slice(
                self.row_idx, (start, s, 0), (width, 1, self.k))[:, 0]
            vals = jax.lax.dynamic_slice(
                self.values, (start, s, 0), (width, 1, self.k))[:, 0]
            Gs, cs = slab_gram(rows, vals, self._shard(w, s),
                               self._shard(r, s))
            G = Gs if G is None else G + Gs
            c = cs if c is None else c + cs
        return G, c

    def gather(self, beta, mask, cap: int, *, k_cap: Optional[int] = None):
        rows_sub, vals_sub, beta_sub, idx = gather_features(
            self.row_idx, self.values, beta, mask, cap,
            sentinel=self.n_loc, k_cap=k_cap,
        )
        sub = SlabDesign(rows_sub, vals_sub, self.n,
                         front_packed=self.front_packed)
        return sub, beta_sub, idx

    def scatter(self, beta_sub, idx):
        return scatter_features(beta_sub, idx, self.shape[1])

    def k_per_feature(self) -> np.ndarray:
        """Host (p,) max live slots per feature over shards — the K-class
        selector for restricted solves (front-packed slabs only)."""
        return np.asarray(
            (np.asarray(self.row_idx) < self.n_loc).sum(axis=-1).max(axis=-1))

    def densify(self):
        """Dense (n, p) oracle/fallback — per data shard, the kernel
        layer's reference scatter (``kernels.ref._densify_slab``, the one
        definition of the sentinel/duplicate-row semantics), rows stacked
        in shard order. Cached: local solves (and screen=False paths)
        reuse one materialization per design."""
        dense = getattr(self, "_dense_cache", None)
        if dense is None:
            from repro.kernels.ref import _densify_slab

            parts = [
                _densify_slab(self.row_idx[:, s], self.values[:, s],
                              self.n_loc)
                for s in range(self.dp)
            ]
            # allow[sharded-concat]: single-process densify oracle — local per-shard dense blocks, never mesh-sharded values
            dense = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
            object.__setattr__(self, "_dense_cache", dense)
        return dense


# ---------------------------------------------------------------------------
# BucketedSlabDesign
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class BucketedSlabDesign:
    """nnz-bucketed slab layout (:class:`SlabBuckets`): features grouped
    into power-of-two K classes so storage is ~O(nnz). Public methods
    speak the original feature order; the concatenated-bucket permutation
    is private."""

    slabs: SlabBuckets
    n: int
    front_packed: bool = True
    layout: ClassVar[str] = "bucketed"

    @classmethod
    def from_by_feature(cls, bf: ByFeature, dp: int = 1,
                        **kw) -> "BucketedSlabDesign":
        from repro.data.byfeature import to_slab_buckets

        return cls(to_slab_buckets(bf, dp, **kw), bf.n, front_packed=True)

    @property
    def dp(self) -> int:
        return int(self.slabs.buckets[0][0].shape[1])

    @property
    def n_loc(self) -> int:
        return self.slabs.n_loc

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.n, self.slabs.p)

    @property
    def feat_order(self) -> np.ndarray:
        order = getattr(self, "_feat_order", None)
        if order is None:
            order = self.slabs.feat_order
            object.__setattr__(self, "_feat_order", order)
        return order

    @property
    def inv_perm(self) -> np.ndarray:
        inv = getattr(self, "_inv_perm", None)
        if inv is None:
            inv = np.empty(self.slabs.p, np.int64)
            inv[self.feat_order] = np.arange(self.slabs.p)
            object.__setattr__(self, "_inv_perm", inv)
        return inv

    def _flat(self) -> SlabDesign:
        """Work-order flat slab view at the max K class (take, not copy,
        when there is a single bucket)."""
        flat = getattr(self, "_flat_cache", None)
        if flat is None:
            if len(self.slabs.buckets) == 1:
                r_b, v_b, _ = self.slabs.buckets[0]
            else:
                k_max = max(self.slabs.k_classes)
                idx = jnp.arange(self.slabs.p)
                r_b, v_b = take_features_buckets(self.slabs, idx, k_max)
            flat = SlabDesign(r_b, v_b, self.n,
                              front_packed=self.front_packed)
            object.__setattr__(self, "_flat_cache", flat)
        return flat

    def margins(self, beta):
        beta_work = jnp.take(beta, jnp.asarray(self.feat_order))
        return self._flat().margins(beta_work)

    def correlation(self, v):
        g_work = self._flat().correlation(v)
        return jnp.take(g_work, jnp.asarray(self.inv_perm))

    def gram_tile(self, w, r, start: int, width: int):
        k_max = max(self.slabs.k_classes)
        idx = jnp.asarray(self.inv_perm)[start: start + width]
        rows, vals = take_features_buckets(self.slabs, idx, k_max)
        return SlabDesign(rows, vals, self.n).gram_tile(w, r, 0, width)

    def gather(self, beta, mask, cap: int, *, k_cap: Optional[int] = None):
        order = jnp.asarray(self.feat_order)
        mask_work = jnp.take(mask, order)
        beta_work = jnp.take(beta, order)
        if k_cap is None:
            k_cap = max(self.slabs.k_classes)
        rows_sub, vals_sub, beta_sub, idx = gather_features_buckets(
            self.slabs, beta_work, mask_work, cap, k_cap)
        sub = SlabDesign(rows_sub, vals_sub, self.n,
                         front_packed=self.front_packed)
        return sub, beta_sub, idx

    def scatter(self, beta_sub, idx):
        work_full = scatter_features(beta_sub, idx, self.slabs.p)
        return jnp.take(work_full, jnp.asarray(self.inv_perm))

    def k_per_feature(self) -> np.ndarray:
        """Host (p,) per-feature max live slots, in *work* (bucket) order —
        pairs with work-order masks inside :class:`ShardedDesign`."""
        parts = [
            np.asarray((np.asarray(r_b) < self.n_loc).sum(-1).max(-1))
            for r_b, _, _ in self.slabs.buckets
        ]
        return np.concatenate(parts) if parts else np.zeros(0, np.int64)

    def densify(self):
        # flat view is work (bucket) order; column j of the original
        # matrix sits at work position inv_perm[j]; cached like the
        # SlabDesign densify (local solves call this once per lambda)
        dense = getattr(self, "_dense_cache", None)
        if dense is None:
            dense = self._flat().densify()[:, jnp.asarray(self.inv_perm)]
            object.__setattr__(self, "_dense_cache", dense)
        return dense


# ---------------------------------------------------------------------------
# ShardedDesign
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class _MeshSlabState:
    """Per-(design, tile) mesh residency: the padded work buckets behind
    a :class:`~repro.data.residency.BucketResidencyManager` plus the
    work-axis bookkeeping the estimator's screened path consumes. Built
    once, cached on the owning :class:`ShardedDesign`. Every per-bucket
    pass goes through :meth:`iter_buckets`, so resident and streamed
    residency run the same op sequence in the same bucket order."""

    residency: BucketResidencyManager
    feat_map: jnp.ndarray        # (p_work,) original id per work pos, sentinel p
    k_arr: jnp.ndarray           # (p_work,) per-feature max live slots
    k_max: int
    p_work: int
    n_loc: int
    cap_tile: int

    def iter_buckets(self):
        """(row_idx, values, feat_idx) device buckets in work order —
        streamed mode prefetches bucket t+1 behind bucket t's compute."""
        return self.residency.iter_buckets()


@dataclass(eq=False)
class ShardedDesign:
    """Any design wrapped onto a JAX mesh (axes ``model`` x data axes).

    Slab layouts stream every margins/correlation pass under ``shard_map``
    (``core.screening.make_sparse_corr`` / ``core.distributed
    .make_slab_margins``) with a psum over the data axes, so no dense
    (n, p) X — and for margins not even a replicated beta gather — ever
    exists off the mesh. ``gather`` is the active-set feature reshard into
    a capacity-bucketed P(model) layout. ``gram_tile`` delegates to the
    wrapped design (it is the testing oracle; mesh execution uses the
    fused solver programs the strategy resolver picks).

    ``tile`` aligns the internal feature padding with the solver's Gram
    tile (``DGLMNETOptions.tile``); results are tile-invariant, so the
    default only matters for program-shape reuse.

    ``device_budget_bytes`` caps how many padded slab-bucket bytes may be
    device-resident at once: below :meth:`slab_nbytes`, the residency
    manager streams buckets host->device through every pass instead of
    keeping them all resident (bit-identical results, epoch-style
    copies). Set it before the first residency build (`_mesh_state`).
    """

    inner: Design
    mesh: object                 # jax.sharding.Mesh
    tile: int = 128
    device_budget_bytes: Optional[int] = None
    _states: dict = field(default_factory=dict, init=False, repr=False)

    def __post_init__(self):
        if isinstance(self.inner, ShardedDesign):
            raise TypeError("cannot wrap a ShardedDesign in a ShardedDesign")
        if "model" not in self.mesh.axis_names:
            raise ValueError(
                f"mesh axes {self.mesh.axis_names} lack the 'model' axis the "
                f"feature blocks map onto — build meshes via repro.launch.mesh"
            )

    @property
    def layout(self) -> str:
        return self.inner.layout

    @property
    def shape(self) -> Tuple[int, int]:
        return self.inner.shape

    @property
    def daxes(self):
        from repro.core.distributed import _data_axes

        return _data_axes(self.mesh)

    @property
    def ddim(self) -> int:
        from repro.core.distributed import _data_extent

        return _data_extent(self.mesh)

    @property
    def mdim(self) -> int:
        return self.mesh.shape["model"]

    def vsharding(self):
        """The example-axis sharding (P over the data axes) for y/m."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        return NamedSharding(self.mesh, P(self.daxes))

    # -- mesh residency (slab layouts) ------------------------------------

    def _as_buckets(self) -> SlabBuckets:
        n = self.shape[0]
        if isinstance(self.inner, SlabDesign):
            # a flat slab pair is exactly a one-bucket layout; wrapping it
            # keeps a single screened sparse driver (full validation runs
            # in the per-bucket loop below)
            p = self.inner.shape[1]
            return SlabBuckets(
                buckets=((self.inner.row_idx, self.inner.values,
                          np.arange(p, dtype=np.int64)),),
                n_loc=n // max(self.ddim, 1), p=p)
        if isinstance(self.inner, BucketedSlabDesign):
            return self.inner.slabs
        raise TypeError(f"no slab form for layout {self.layout!r}")

    def _mesh_state(self, tile: Optional[int] = None) -> _MeshSlabState:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core.distributed import check_slab_shapes

        if tile is None:
            # public methods don't care which alignment serves them (all
            # results are tile-invariant, and gather/scatter consistently
            # see the same first state) — reuse whatever residency exists
            # rather than building a second O(nnz) copy of the slabs
            if self._states:
                return next(iter(self._states.values()))
            tile = self.tile
        st = self._states.get(tile)
        if st is not None:
            return st
        n, p = self.shape
        cap_tile = self.mdim * tile
        slabs = self._as_buckets()
        n_loc = slabs.n_loc
        slab_sharding = NamedSharding(self.mesh, P("model", self.daxes, None))
        budget = self.device_budget_bytes
        padded_buckets = []
        feat_map_parts = []
        k_arr_parts = []
        for r_b, v_b, fid in slabs.buckets:
            if check_slab_shapes(r_b, v_b, self.mesh, n) != n_loc:
                raise ValueError("bucket n_loc inconsistent with mesh/n")
            if budget is not None:
                # streaming intent: the manager's source copies must be
                # host-side, or "evicted" buckets would stay device-
                # resident on the default device anyway
                r_b, v_b = np.asarray(r_b), np.asarray(v_b)
            xp = np if isinstance(r_b, np.ndarray) else jnp
            # pad each bucket's feature axis so the streaming screen's
            # tile walk and every capacity bucket stay mesh-aligned;
            # all-sentinel slabs have zero gradient and are never admitted
            pad_b = (-r_b.shape[0]) % cap_tile
            if pad_b:
                r_b = xp.pad(r_b, ((0, pad_b), (0, 0), (0, 0)),
                             constant_values=n_loc)
                v_b = xp.pad(v_b, ((0, pad_b), (0, 0), (0, 0)))
            # k per feature on host *before* the slabs land sharded
            k_arr_parts.append(
                np.asarray((r_b < n_loc).sum(axis=-1).max(axis=-1)))
            padded_buckets.append((r_b, v_b, fid))
            feat_map_parts.append(np.concatenate([
                np.asarray(fid, np.int32),
                np.full(pad_b, p, np.int32)]))
        st = _MeshSlabState(
            residency=BucketResidencyManager(
                tuple(padded_buckets), sharding=slab_sharding,
                budget_bytes=budget),
            feat_map=jnp.asarray(np.concatenate(feat_map_parts)),
            k_arr=jnp.asarray(np.concatenate(k_arr_parts)),
            k_max=max(b[0].shape[-1] for b in padded_buckets),
            p_work=sum(b[0].shape[0] for b in padded_buckets),
            n_loc=n_loc,
            cap_tile=cap_tile,
        )
        # mirror the manager's counters onto an active metrics registry
        # (lazy callback; residency_stats() stays the source of truth)
        st.residency.register_metrics(name=f"residency.tile{st.cap_tile}")
        self._states[tile] = st
        return st

    def slab_bucket_nbytes(self, tile: Optional[int] = None) -> Tuple[int, ...]:
        """Per-bucket *padded* device bytes at ``tile`` alignment — the
        exact sizes the residency manager will account, computed host-side
        from shapes alone (no device work, safe for the strategy resolver
        to call before any residency exists)."""
        cap_tile = self.mdim * (self.tile if tile is None else tile)
        out = []
        for r_b, v_b, _ in self._as_buckets().buckets:
            p_b, dp, k_b = r_b.shape
            p_pad = p_b + (-p_b) % cap_tile
            out.append(p_pad * dp * k_b
                       * (r_b.dtype.itemsize + v_b.dtype.itemsize))
        return tuple(out)

    def slab_nbytes(self, tile: Optional[int] = None) -> int:
        """Total padded slab bytes (sum of :meth:`slab_bucket_nbytes`);
        a ``device_budget_bytes`` below this streams the path solve."""
        return sum(self.slab_bucket_nbytes(tile))

    def residency_stats(self) -> dict:
        """Per-tile residency telemetry (hit/miss/eviction/bytes-moved
        counters) for every built mesh state."""
        return {t: st.residency.stats() for t, st in self._states.items()}

    # -- Design protocol ---------------------------------------------------

    def margins(self, beta):
        if self.layout == "dense":
            return self.inner.margins(beta)
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core.distributed import make_slab_margins

        st = self._mesh_state()
        beta_work = jnp.take(jnp.asarray(beta, jnp.float32), st.feat_map,
                             mode="fill", fill_value=0.0)
        bsharding = NamedSharding(self.mesh, P("model"))
        m = None
        off = 0
        for r_b, v_b, _ in st.iter_buckets():
            p_b = r_b.shape[0]
            beta_b = jax.device_put(
                jax.lax.dynamic_slice(beta_work, (off,), (p_b,)), bsharding)
            m_b = make_slab_margins(self.mesh, st.n_loc)(r_b, v_b, beta_b)
            m = m_b if m is None else m + m_b
            off += p_b
        return m                     # example-sharded P(data axes)

    def correlation(self, v):
        if self.layout == "dense":
            return self.inner.correlation(v)
        from repro.core.screening import make_sparse_corr

        st = self._mesh_state()
        tile = st.cap_tile // self.mdim
        corr = make_sparse_corr(self.mesh, st.n_loc, tile)
        # per-bucket P(model) pieces of different lengths: concatenating
        # them sharded miscompiles on current JAX — the shared
        # replicate-first guard is mandatory here (sharding/collect.py)
        g_work = concat_replicated(
            [corr(r_b, v_b, v) for r_b, v_b, _ in st.iter_buckets()],
            self.mesh)
        p = self.shape[1]
        return jnp.zeros(p, g_work.dtype).at[st.feat_map].set(
            g_work, mode="drop")

    def gram_tile(self, w, r, start: int, width: int):
        return self.inner.gram_tile(w, r, start, width)

    # -- work-axis fast path (estimator-internal) --------------------------
    #
    # The screened path driver runs in *work* (bucket-permuted, mesh-
    # padded) order so every per-lambda pass is exactly the jitted units
    # of the pre-API driver — one shard_map screen per bucket, no eager
    # per-op dispatch on sharded arrays, no per-pass order conversion.
    # Public protocol methods stay original-order; these three are the
    # private bridge the estimator uses.

    def _screen_abs_work(self, y, m, tile: Optional[int] = None):
        """|X^T v(m, y)| in work order (p_work,): the per-bucket jitted
        sparse screen, pieces collected via the replicate-first guard.

        ``tile`` (default: the design's own) must match the state the
        caller's masks live on — the estimator threads ``opts.tile``
        through every work-axis helper so one work axis is in play even
        when ``LogisticL1.opts.tile != design.tile``.
        """
        from repro.core.screening import make_sparse_screen

        st = self._mesh_state(tile)
        screen = make_sparse_screen(self.mesh, st.n_loc,
                                    st.cap_tile // self.mdim)
        return concat_replicated(
            [screen(r_b, v_b, y, m) for r_b, v_b, _ in st.iter_buckets()],
            self.mesh)

    def _gather_work(self, beta_work, mask_work, cap: int, k_cap: int,
                     tile: Optional[int] = None):
        """Work-order active-set gather into a flat restricted design.
        The per-bucket take streams through the residency manager — same
        ops as the resident ``gather_features_buckets``, so the gathered
        working set is bit-identical either way."""
        from repro.core.screening import pack_indices

        st = self._mesh_state(tile)
        idx = pack_indices(mask_work, cap)
        beta_sub = jnp.take(beta_work, idx, mode="fill", fill_value=0.0)
        rows_sub, vals_sub = take_buckets_iter(
            st.iter_buckets(), st.n_loc, idx, k_cap)
        front = (self.inner.front_packed
                 if hasattr(self.inner, "front_packed") else True)
        sub = ShardedDesign(
            SlabDesign(rows_sub, vals_sub, self.shape[0], front_packed=front),
            self.mesh, tile=self.tile if tile is None else tile)
        return sub, beta_sub, idx

    def _work_to_original(self, beta_work, tile: Optional[int] = None):
        """Work-order coefficients -> original feature ids (mesh padding
        rows dropped via the sentinel-p scatter)."""
        st = self._mesh_state(tile)
        p = self.shape[1]
        return jnp.zeros(p, beta_work.dtype).at[st.feat_map].set(
            beta_work, mode="drop")

    def gather(self, beta, mask, cap: int, *, k_cap: Optional[int] = None):
        if self.layout == "dense":
            sub, beta_sub, idx = self.inner.gather(beta, mask, cap)
            return ShardedDesign(sub, self.mesh, tile=self.tile), beta_sub, idx
        st = self._mesh_state()
        mask_work = jnp.take(jnp.asarray(mask), st.feat_map,
                             mode="fill", fill_value=False)
        beta_work = jnp.take(jnp.asarray(beta, jnp.float32), st.feat_map,
                             mode="fill", fill_value=0.0)
        return self._gather_work(beta_work, mask_work, cap,
                                 st.k_max if k_cap is None else k_cap)

    def scatter(self, beta_sub, idx):
        if self.layout == "dense":
            return self.inner.scatter(beta_sub, idx)
        st = self._mesh_state()
        return self._work_to_original(scatter_features(beta_sub, idx,
                                                       st.p_work))


# ---------------------------------------------------------------------------
# coercion
# ---------------------------------------------------------------------------

_DESIGN_TYPES = (DenseDesign, SlabDesign, BucketedSlabDesign, ShardedDesign)


def as_design(data, *, n: Optional[int] = None, mesh=None,
              tile: int = 128,
              device_budget_bytes: Optional[int] = None) -> Design:
    """Coerce a legacy entry-point operand into a :class:`Design`.

    ``data`` may be a Design (passed through), a dense (n, p) array, a
    :class:`~repro.data.byfeature.ByFeature`, a raw ``(row_idx, values)``
    slab pair (front-packing is *detected* — user-built slabs may
    interleave sentinel and live slots, which disables the positional
    K-capacity trim instead of silently dropping live entries), or a
    :class:`~repro.data.byfeature.SlabBuckets`. ``n`` is required for slab
    forms that don't carry it. With ``mesh``, the result is wrapped in a
    :class:`ShardedDesign`; ``device_budget_bytes`` (mesh wrapping only)
    is the residency budget that selects streamed slab passes when it is
    below the padded slab byte total.
    """
    if isinstance(data, _DESIGN_TYPES):
        d = data
    elif isinstance(data, ByFeature):
        if n is not None and data.n != n:
            raise ValueError(f"ByFeature has n={data.n} but len(y)={n}")
        dp = 1
        if mesh is not None:
            from repro.core.distributed import _data_extent

            dp = _data_extent(mesh)
        d = SlabDesign.from_by_feature(data, dp)
    elif isinstance(data, SlabBuckets):
        dp = int(data.buckets[0][0].shape[1]) if data.buckets else 1
        d = BucketedSlabDesign(data, n=data.n_loc * dp, front_packed=True)
    elif isinstance(data, tuple) and len(data) == 2:
        row_idx, values = data
        if n is None:
            raise ValueError("raw (row_idx, values) slabs need n= (len(y))")
        if mesh is not None:
            from repro.core.distributed import _data_extent

            n_loc = n // max(_data_extent(mesh), 1)
        else:
            dp = int(row_idx.shape[1]) if row_idx.ndim == 3 else 1
            n_loc = n // max(dp, 1)
        if row_idx.ndim == 2:
            row_idx = row_idx[:, None, :]
            values = values[:, None, :]
        d = SlabDesign(row_idx, values, n,
                       front_packed=_slab_front_packed(row_idx, n_loc))
    elif hasattr(data, "ndim") and data.ndim == 2:
        d = DenseDesign(data)
    else:
        raise TypeError(
            f"cannot build a Design from {type(data).__name__}: expected a "
            f"dense (n, p) array, ByFeature, (row_idx, values) slabs, "
            f"SlabBuckets, or a Design"
        )
    if mesh is not None and not isinstance(d, ShardedDesign):
        d = ShardedDesign(d, mesh, tile=tile,
                          device_budget_bytes=device_budget_bytes)
    return d
