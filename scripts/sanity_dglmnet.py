"""Dev sanity for the paper core: convergence, method equivalence, paths."""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import GLMConfig
from repro.core import (
    DGLMNETOptions,
    fit,
    lambda_max,
    margins,
    objective,
    regularization_path,
)
from repro.core.truncated_gradient import TGOptions, truncated_gradient_fit
from repro.data.synthetic import make_glm_dataset

cfg = GLMConfig(name="dev", num_examples=4096, num_features=256, density=1.0)
ds = make_glm_dataset(cfg, jax.random.key(0))
X, y = ds.X_train, ds.y_train
lmax = float(lambda_max(X, y))
lam = lmax / 32.0
print(f"n={X.shape[0]} p={X.shape[1]} lambda_max={lmax:.2f} lambda={lam:.2f}")

# proximal-gradient oracle (slow but sure)
def prox_fit(X, y, lam, iters=8000, lr=None):
    n = X.shape[0]
    L = 0.25 * jnp.linalg.norm(X, ord=2) ** 2  # Lipschitz of grad NLL
    lr = lr or float(1.0 / L)
    beta = jnp.zeros(X.shape[1])

    @jax.jit
    def step(beta):
        m = X @ beta
        g = X.T @ (jax.nn.sigmoid(m) - (y + 1) * 0.5)
        b = beta - lr * g
        return jnp.sign(b) * jnp.maximum(jnp.abs(b) - lr * lam, 0.0)

    for _ in range(iters):
        beta = step(beta)
    return beta

t0 = time.time()
beta_star = prox_fit(X, y, lam)
f_star = float(objective(margins(X, beta_star), y, beta_star, lam))
print(f"oracle  f*={f_star:.4f} nnz={int((jnp.abs(beta_star)>0).sum())} ({time.time()-t0:.1f}s)")

for method, m_blocks in [("residual", 1), ("gram", 1), ("gram", 4), ("gram", 16)]:
    opts = DGLMNETOptions(num_blocks=m_blocks, method=method, tile=64, max_iters=60)
    t0 = time.time()
    res = fit(X, y, lam, opts=opts)
    gap = (res.f - f_star) / abs(f_star)
    print(
        f"{method:9s} M={m_blocks:2d} f={res.f:.4f} gap={gap:.2e} nnz={res.nnz} "
        f"iters={res.n_iters} unit%={res.unit_step_frac:.2f} ({time.time()-t0:.1f}s)"
    )
    assert gap < 1e-3, f"not converged: {method} M={m_blocks}"

# residual vs gram single-iteration equivalence
from repro.core import dglmnet_iteration

beta0 = jnp.zeros(X.shape[1])
m0 = margins(X, beta0)
d1, dm1, _ = dglmnet_iteration(X, y, beta0, m0, lam, DGLMNETOptions(num_blocks=4, method="residual"))
d2, dm2, _ = dglmnet_iteration(X, y, beta0, m0, lam, DGLMNETOptions(num_blocks=4, method="gram", tile=32))
print("gram==residual iterate: max|diff| =", float(jnp.max(jnp.abs(d1 - d2))))
assert jnp.allclose(d1, d2, atol=1e-4), "gram and residual iterates diverge"

# truncated-gradient baseline runs
snaps = truncated_gradient_fit(X, y, lam, opts=TGOptions(num_machines=8, passes=5), key=jax.random.key(1))
print("TG baseline final pass beta nnz:", int((jnp.abs(snaps[-1][1]) > 1e-8).sum()))
print("ALL OK")
