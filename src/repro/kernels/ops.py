"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (CPU validation per the assignment);
on a real TPU backend the kernels compile natively.
"""
from __future__ import annotations

import jax

from repro.kernels.gram_cd import gram_cd_pallas
from repro.kernels.logistic_stats import logistic_stats_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def gram_cd(G, c, beta, dbeta0, lam, nu=1e-6):
    """One CD cycle on a Gram tile; returns the within-cycle delta d."""
    return gram_cd_pallas(G, c, beta, dbeta0, lam, nu, interpret=not _on_tpu())


def logistic_stats(m, y, *, block: int = 4096):
    """Fused (w, z, nll) from margins."""
    return logistic_stats_pallas(m, y, block=block, interpret=not _on_tpu())


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Blocked online-softmax attention (forward)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=not _on_tpu())
