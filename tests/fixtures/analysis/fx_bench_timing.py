"""Golden fixture: trips bench-timing and nothing else.

A ``perf_counter`` delta around an (async-dispatched) JAX call without a
``block_until_ready`` times the enqueue, not the work.
"""
import time

import jax  # noqa: F401  (the rule only inspects JAX-importing modules)


def time_fit(fn, x):
    t0 = time.perf_counter()
    y = fn(x)
    return y, time.perf_counter() - t0
