from repro.core.dglmnet import (  # noqa: F401
    DGLMNETOptions,
    FitResult,
    dglmnet_iteration,
    fit,
    fit_python_loop,
)
from repro.core.distributed import fit_distributed, make_dglmnet_step  # noqa: F401
from repro.core.engine import SolverState, make_solver, make_step  # noqa: F401
from repro.core.linesearch import LineSearchResult, line_search  # noqa: F401
from repro.core.objective import (  # noqa: F401
    lambda_max,
    margins,
    neg_log_likelihood,
    objective,
    soft_threshold,
    working_stats,
)
from repro.core.regpath import PathPoint, regularization_path  # noqa: F401
from repro.core.screening import (  # noqa: F401
    kkt_violations,
    strong_rule_mask,
)
from repro.core.subproblem import (  # noqa: F401
    cd_cycle_gram,
    cd_cycle_gram_tile,
    cd_cycle_residual,
    solve_subproblem,
)
from repro.core.truncated_gradient import TGOptions, truncated_gradient_fit  # noqa: F401
