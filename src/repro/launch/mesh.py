"""Production meshes. Functions, not module-level constants: importing this
module never touches jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import).

v5e hardware constants for the roofline (EXPERIMENTS §Roofline) live here
so benchmarks and the dry-run agree on them.
"""
from __future__ import annotations

import jax

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW_PER_LINK = 50e9          # B/s per link (~ per-direction per link)


def make_production_mesh(*, multi_pod: bool = False):
    from repro.compat import make_mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_dev_mesh(data: int = 2, model: int = 4):
    """Small mesh for CPU tests (8 fake devices)."""
    from repro.compat import make_mesh

    return make_mesh((data, model), ("data", "model"))


def parse_mesh(spec: str):
    """CLI mesh spec: ``prod``, ``prod-multipod``, or ``DxM``/``PxDxM``."""
    if spec == "prod":
        return make_production_mesh()
    if spec == "prod-multipod":
        return make_production_mesh(multi_pod=True)
    dims = tuple(int(x) for x in spec.split("x"))
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    from repro.compat import make_mesh

    return make_mesh(dims, names)


def num_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
