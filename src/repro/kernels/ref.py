"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objective import P_EPS, W_MIN
from repro.core.subproblem import cd_cycle_gram_tile


def gram_cd_ref(G, c, beta, dbeta0, lam, nu):
    """Oracle for kernels.gram_cd: the core solver's own sequential cycle."""
    return cd_cycle_gram_tile(
        G.astype(jnp.float32), c.astype(jnp.float32),
        beta.astype(jnp.float32), dbeta0.astype(jnp.float32),
        lam, nu,
    )


def logistic_stats_ref(m, y):
    """Oracle for kernels.logistic_stats."""
    m = m.astype(jnp.float32)
    y = y.astype(jnp.float32)
    p = jax.nn.sigmoid(m)
    p = jnp.clip(p, P_EPS, 1.0 - P_EPS)
    w = jnp.maximum(p * (1.0 - p), W_MIN)
    z = ((y + 1.0) * 0.5 - p) / w
    nll = jnp.sum(jax.nn.softplus(-y * m))
    return w, z, nll


def flash_attention_ref(q, k, v, *, causal=True):
    """Oracle for kernels.flash_attention: plain softmax attention."""
    b, s, h, d = q.shape
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(q.dtype)
