"""repro.serve — batched online scoring of the certified reg path.

The d-GLMNET training side hands over a typed ``PathResult`` (the whole
certified regularization path); this package serves it:

* :class:`PathStore` — the ``(L, p)`` coefficient stack device-resident
  (replicated locally, P(model)-feature-sharded on a mesh), versioned,
  hot-swappable without dropping in-flight batches;
* :mod:`~repro.serve.ingest` — deterministic hashed sparse-feature
  ingestion packing request batches into the training kernels' by-feature
  slab layout;
* :class:`RequestBatcher` — accumulate/drain batching with power-of-two
  shape classes;
* :class:`PathScorer` — one jitted ``slab_path_spmv`` dispatch per batch,
  each request row picking its own lambda operating point on device;
  scores bit-identical to ``LogisticL1.decision_function``.

Entry point: ``python -m repro.launch.serve_glm``.
"""
from repro.serve.batcher import RequestBatcher, batch_capacity  # noqa: F401
from repro.serve.ingest import (  # noqa: F401
    PackedBatch,
    encode_request,
    hash_token,
    k_capacity,
    pack_requests,
)
from repro.serve.scoring import PathScorer, make_path_margins  # noqa: F401
from repro.serve.store import PathStore, StoreSnapshot  # noqa: F401
