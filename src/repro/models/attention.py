"""Attention: GQA (optional QKV bias), MLA (DeepSeek-V3), sliding-window,
cross-attention, and KV-cache plumbing for prefill/decode.

Memory discipline (the part that makes 32k prefill / 512-device dry-runs
fit): full-sequence attention never materializes an (S, S) score tensor or
mask. Queries are processed in chunks (``lax.map`` over a checkpointed
body): live memory is O(S * chunk) and the backward pass recomputes each
chunk's scores instead of storing them. Masks are computed per chunk from
position vectors. Head activations are sharded over `model` via
``constrain`` (divisibility-guarded).

Modes
-----
``mode="train"/"prefill"``: full-sequence causal attention; prefill returns
the populated cache. ``mode="decode"``: one new token against a cache of
``cache_len`` entries.

MLA decode uses the *absorbed* form (w_kv_b folded into the query/output) so
the per-step cost is O(S * (kv_lora + rope_dim)) per head instead of
reconstructing per-token K/V; the latent cache is what makes deepseek-v3
decode shapes fit.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.layers import (
    apply_mrope,
    apply_norm,
    apply_rope,
    dense_init,
    init_norm,
    text_mrope_positions,
)
from repro.sharding.ctx import constrain, flash_decode_enabled, unroll_enabled

NEG_INF = -1e30
Q_CHUNK = 1024          # query-chunk length for full-sequence attention


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: AttentionConfig, d_model: int, dtype):
    if cfg.use_mla:
        return _init_mla(key, cfg, d_model, dtype)
    dh = cfg.resolved_head_dim(d_model)
    h, hk = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, h * dh, dtype),
        "wk": dense_init(ks[1], d_model, hk * dh, dtype),
        "wv": dense_init(ks[2], d_model, hk * dh, dtype),
        "wo": dense_init(ks[3], h * dh, d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), dtype)
        p["bk"] = jnp.zeros((hk * dh,), dtype)
        p["bv"] = jnp.zeros((hk * dh,), dtype)
    return p


def _init_mla(key, cfg: AttentionConfig, d_model: int, dtype):
    h = cfg.num_heads
    dq, dkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d_model, dq, dtype),
        "q_norm": init_norm(dq, dtype),
        "wq_b": dense_init(ks[1], dq, h * (dn + dr), dtype),
        # kv_a projects to latent + the shared rope key
        "wkv_a": dense_init(ks[2], d_model, dkv + dr, dtype),
        "kv_norm": init_norm(dkv, dtype),
        "wkv_b": dense_init(ks[3], dkv, h * (dn + dv), dtype),
        "wo": dense_init(ks[4], h * dv, d_model, dtype),
    }


def init_cross_attention(key, cfg: AttentionConfig, d_model: int, dtype):
    # same projection structure as GQA self-attention (kv from memory)
    return init_attention(key, cfg, d_model, dtype)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: AttentionConfig, d_model: int, batch: int, cache_len: int, dtype):
    if cfg.use_mla:
        return {
            "latent": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_head_dim), dtype),
        }
    dh = cfg.resolved_head_dim(d_model)
    hk = cfg.num_kv_heads
    return {
        "k": jnp.zeros((batch, cache_len, hk, dh), dtype),
        "v": jnp.zeros((batch, cache_len, hk, dh), dtype),
    }


def _cache_write(buf, new, index):
    """Write (B, s, ...) new entries at position `index` along axis 1."""
    zeros = (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, new.astype(buf.dtype), (0, index) + zeros)


# ---------------------------------------------------------------------------
# chunked scaled-dot-product attention (no (S,S) materialization)
# ---------------------------------------------------------------------------

def _mask_chunk(q_pos, k_pos, *, causal, window, kv_limit):
    """(B, C, Sk) boolean mask for one query chunk."""
    m = jnp.ones(q_pos.shape + (k_pos.shape[-1],), bool)
    if causal:
        m = jnp.logical_and(m, q_pos[..., :, None] >= k_pos[..., None, :])
    if window:
        m = jnp.logical_and(m, q_pos[..., :, None] - k_pos[..., None, :] < window)
    if kv_limit is not None:
        m = jnp.logical_and(m, (k_pos <= kv_limit)[..., None, :])
    return m


def _sdpa_block(q, k, v, mask, *, scale):
    """q: (B,C,H,Dh); k/v: (B,Sk,H,Dh) (already head-expanded);
    mask (B,C,Sk) or None. Scores stay (B,H,C,Sk) — shardable on H."""
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if mask is not None:
        logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(v.dtype)


def sdpa(q, k, v, q_pos, k_pos, *, scale, causal=True, window=0, kv_limit=None,
         q_chunk: int = Q_CHUNK, use_flash_kernel: bool = False):
    """``use_flash_kernel`` routes plain causal/bidirectional self-attention
    through the Pallas blocked online-softmax kernel (kernels/flash_attention)
    when the shape qualifies (no window/limit, S | 128); falls back to the
    chunked jnp path otherwise. Equality tested in test_models.py."""
    if (use_flash_kernel and window == 0 and kv_limit is None
            and q.shape[1] == k.shape[1] and q.shape[1] % 128 == 0
            and q.shape[-1] == v.shape[-1]):
        from repro.kernels.ops import flash_attention

        h, hk = q.shape[2], k.shape[2]
        if hk != h:
            k = jnp.repeat(k, h // hk, axis=2)
            v = jnp.repeat(v, h // hk, axis=2)
        return flash_attention(q, k, v, causal=causal)
    return _sdpa_jnp(q, k, v, q_pos, k_pos, scale=scale, causal=causal,
                     window=window, kv_limit=kv_limit, q_chunk=q_chunk)


def _sdpa_jnp(q, k, v, q_pos, k_pos, *, scale, causal=True, window=0,
              kv_limit=None, q_chunk: int = Q_CHUNK):
    """Full attention with query chunking. q (B,Sq,H,Dh); k/v (B,Sk,Hk,Dh);
    q_pos (B,Sq); k_pos (B,Sk). Never builds an (Sq,Sk) global tensor.

    GQA: K/V are expanded to the full head count so the score tensor keeps a
    single flat head dim that shards cleanly over `model` (a grouped
    (Hk, G) layout would need one mesh axis across two dims). The expansion
    itself propagates the head sharding, so each device materializes only
    its local heads."""
    b, sq, h, dh = q.shape
    hk = k.shape[2]
    if hk != h:
        g = h // hk
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)

    if sq <= q_chunk or sq % q_chunk != 0:
        mask = _mask_chunk(q_pos, k_pos, causal=causal, window=window, kv_limit=kv_limit)
        return _sdpa_block(q, k, v, mask, scale=scale)

    nc = sq // q_chunk
    qc = q.reshape(b, nc, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(b, nc, q_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(args):
        qi, pi = args
        mask = _mask_chunk(pi, k_pos, causal=causal, window=window, kv_limit=kv_limit)
        return _sdpa_block(qi, k, v, mask, scale=scale)

    if unroll_enabled():
        # dry-run cost pass: loop bodies visible to HloCostAnalysis
        outs = [body((qc[i], pc[i])) for i in range(nc)]
        out = jnp.stack(outs)
    else:
        out = jax.lax.map(body, (qc, pc))                # (nc, B, C, H, Dv)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, v.shape[-1])


# ---------------------------------------------------------------------------
# sequence-parallel flash-decode
# ---------------------------------------------------------------------------

def flash_decode_seq_sharded(q, ck, cv, cache_index, *, scale, window=0,
                             model_axis: str = "model"):
    """Decode attention against a cache whose SEQ dim is sharded over
    `model`: each shard computes a partial softmax over its local keys and
    the results combine with psum'd (max, denom, weighted-value) statistics
    — O(B*H*Dv) collective traffic instead of all-gathering the cache
    (which is ~20 GB/step for a 32k GQA cache with indivisible kv heads).

    q: (B,1,H,Dh) replicated; ck/cv: (B,S,H,Dh) seq-sharded (pre-expanded
    to full heads); returns (B,1,H,Dv) replicated.
    """
    from repro.sharding.ctx import current_mesh

    mesh = current_mesh()
    if mesh is None or model_axis not in mesh.axis_names:
        return None  # caller falls back to the gather path
    from jax.sharding import PartitionSpec as P
    from functools import partial as _partial

    from repro.compat import shard_map as _shard_map

    b, _, h, dh = q.shape
    s = ck.shape[1]
    shards = mesh.shape[model_axis]
    if s % shards:
        return None
    # keep the batch dim sharded over the data axes (replicating it would
    # all-gather the whole cache over `data` — measured 8x worse, see §Perf)
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsz = 1
    for a in daxes:
        dsz *= mesh.shape[a]
    bax = daxes if (daxes and b % dsz == 0) else None

    @_partial(
        _shard_map, mesh=mesh,
        in_specs=(P(bax), P(bax, model_axis, None, None),
                  P(bax, model_axis, None, None), P(), P()),
        out_specs=P(bax),
    )
    def fd(qr, k_loc, v_loc, cache_idx, start_idx):
        # grouped GQA inside the explicit kernel: the cache is read once at
        # Hk heads (expanding to H first re-reads it H/Hk times — measured
        # 8x on the memory term for kv=2, see §Perf)
        s_loc, hk = k_loc.shape[1], k_loc.shape[2]
        g = qr.shape[2] // hk
        qg = qr.reshape(qr.shape[0], 1, hk, g, qr.shape[3])
        shard = jax.lax.axis_index(model_axis)
        k_pos = start_idx + shard * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k_loc.astype(jnp.float32)) * scale   # (B,Hk,G,1,S)
        mask = (k_pos <= cache_idx)[None, None, None, None, :]
        if window:
            mask = jnp.logical_and(
                mask, (cache_idx - k_pos < window)[None, None, None, None, :])
        logits = jnp.where(mask, logits, NEG_INF)
        m_loc = jnp.max(logits, axis=-1, keepdims=True)
        m_glb = jax.lax.pmax(m_loc, model_axis)
        w = jnp.exp(logits - m_glb)
        w = jnp.where(mask, w, 0.0)
        denom = jax.lax.psum(jnp.sum(w, axis=-1, keepdims=True), model_axis)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_loc.astype(jnp.float32))
        o = jax.lax.psum(o, model_axis)                           # (B,1,Hk,G,D)
        denom = denom.transpose(0, 3, 1, 2, 4)                    # -> (B,1,Hk,G,1)
        out = o / jnp.maximum(denom, 1e-30)
        b_, _, _, _, dv = out.shape
        return out.reshape(b_, 1, hk * g, dv).astype(v_loc.dtype)

    return fd(q, ck, cv, jnp.asarray(cache_index, jnp.int32),
              jnp.asarray(0, jnp.int32))


# ---------------------------------------------------------------------------
# GQA forward
# ---------------------------------------------------------------------------

def attention_forward(
    p,
    x: jnp.ndarray,                      # (B, S, D)
    *,
    cfg: AttentionConfig,
    d_model: int,
    positions: jnp.ndarray,              # (B, S) int32
    mode: str = "train",                 # train | prefill | decode
    cache: Optional[dict] = None,
    cache_index: Optional[jnp.ndarray] = None,   # scalar: #tokens already cached
    window: int = 0,                     # 0 = full causal
    window_slice: bool = False,          # decode: gather only the window from cache
    causal: bool = True,                 # False: bidirectional (encoder)
    seq_parallel_decode: bool = False,   # flash-decode over seq-sharded cache
):
    if cfg.use_mla:
        return _mla_forward(
            p, x, cfg=cfg, positions=positions, mode=mode, cache=cache,
            cache_index=cache_index, window=window, causal=causal,
        )
    b, s, _ = x.shape
    dh = cfg.resolved_head_dim(d_model)
    h, hk = cfg.num_heads, cfg.num_kv_heads

    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hk, dh)
    v = v.reshape(b, s, hk, dh)

    if cfg.use_mrope:
        pos3 = text_mrope_positions(positions)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    # shard head activations over `model` (falls back if indivisible)
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)

    scale = 1.0 / (dh ** 0.5)

    if mode in ("train", "prefill"):
        out = sdpa(q, k, v, positions, positions, scale=scale, causal=causal,
                   window=window)
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
        y = out.reshape(b, s, h * dh) @ p["wo"]
        return y, new_cache

    # ---- decode: s == 1 ----
    assert cache is not None and cache_index is not None
    cache_len = cache["k"].shape[1]
    ck = _cache_write(cache["k"], k, cache_index)
    cv = _cache_write(cache["v"], v, cache_index)

    if (seq_parallel_decode or flash_decode_enabled()) and not (window and window_slice):
        out = flash_decode_seq_sharded(q, ck, cv, cache_index, scale=scale,
                                       window=window)
        if out is not None:
            y = out.reshape(b, s, h * out.shape[-1]) @ p["wo"]
            return y, {"k": _cache_write(cache["k"], k, cache_index),
                       "v": _cache_write(cache["v"], v, cache_index)}
        # fall through to the gather path outside a mesh context

    if window and window_slice and cache_len > 2 * window:
        # long_500k: gather only the last `window` entries; the dead prefix
        # of the cache is never read.
        start = jnp.maximum(cache_index + 1 - window, 0)
        ck_r = jax.lax.dynamic_slice_in_dim(ck, start, window, axis=1)
        cv_r = jax.lax.dynamic_slice_in_dim(cv, start, window, axis=1)
        k_pos_r = start + jnp.arange(window, dtype=jnp.int32)[None, :]
        out = sdpa(q, ck_r, cv_r, positions, k_pos_r, scale=scale, causal=True,
                   window=window, kv_limit=cache_index)
    else:
        k_pos = jnp.arange(cache_len, dtype=jnp.int32)[None, :]
        out = sdpa(q, ck, cv, positions, k_pos, scale=scale, causal=True,
                   window=window, kv_limit=cache_index)

    y = out.reshape(b, s, h * dh) @ p["wo"]
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA forward (DeepSeek-V3)
# ---------------------------------------------------------------------------

def _mla_forward(p, x, *, cfg, positions, mode, cache, cache_index, window,
                 causal=True):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dkv = cfg.kv_lora_rank

    q_lat = apply_norm(p["q_norm"], x @ p["wq_a"])
    q = (q_lat @ p["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]                                 # (B,S,dkv+dr)
    latent = apply_norm(p["kv_norm"], kv_a[..., :dkv])    # (B,S,dkv)
    k_rope = apply_rope(kv_a[..., dkv:], positions, cfg.rope_theta)  # shared

    scale = 1.0 / ((dn + dr) ** 0.5)
    wkv_b = p["wkv_b"].reshape(dkv, h, dn + dv)
    wk_b, wv_b = wkv_b[..., :dn], wkv_b[..., dn:]         # (dkv,H,dn), (dkv,H,dv)

    if mode in ("train", "prefill"):
        k_nope = jnp.einsum("bsk,khd->bshd", latent, wk_b)
        v = jnp.einsum("bsk,khd->bshd", latent, wv_b)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1
        )
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        qf = constrain(qf, "batch", None, "model", None)
        k = constrain(k, "batch", None, "model", None)
        v = constrain(v, "batch", None, "model", None)
        out = sdpa(qf, k, v, positions, positions, scale=scale, causal=causal,
                   window=window)
        y = out.reshape(b, s, h * dv) @ p["wo"]
        new_cache = {"latent": latent, "k_rope": k_rope} if mode == "prefill" else None
        return y, new_cache

    # ---- absorbed decode ----
    assert cache is not None and cache_index is not None
    lat_c = _cache_write(cache["latent"], latent, cache_index)   # (B,Sc,dkv)
    kr_c = _cache_write(cache["k_rope"], k_rope, cache_index)    # (B,Sc,dr)
    cache_len = lat_c.shape[1]
    k_pos = jnp.arange(cache_len, dtype=jnp.int32)[None, :]

    # absorb wk_b into the query: q_abs (B,1,H,dkv)
    q_abs = jnp.einsum("bshd,khd->bshk", q_nope, wk_b)
    logits = (
        jnp.einsum("bshk,bck->bhsc", q_abs.astype(jnp.float32), lat_c.astype(jnp.float32))
        + jnp.einsum("bshd,bcd->bhsc", q_rope.astype(jnp.float32), kr_c.astype(jnp.float32))
    ) * scale                                              # (B,H,1,Sc)
    mask = jnp.logical_and(
        k_pos[..., None, :] <= cache_index,
        positions[..., :, None] >= k_pos[..., None, :],
    )
    if window:
        mask = jnp.logical_and(mask, positions[..., :, None] - k_pos[..., None, :] < window)
    logits = jnp.where(mask[:, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhsc,bck->bshk", w, lat_c.astype(jnp.float32))  # (B,1,H,dkv)
    out = jnp.einsum("bshk,khd->bshd", o_lat, wv_b.astype(jnp.float32))  # (B,1,H,dv)
    y = out.reshape(b, s, h * dv).astype(x.dtype) @ p["wo"]
    return y, {"latent": lat_c, "k_rope": kr_c}


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------

def cross_attention_forward(p, x, memory, *, cfg: AttentionConfig, d_model: int):
    """x: (B,Sq,D) decoder states; memory: (B,Sk,D) encoder output."""
    b, sq, _ = x.shape
    sk = memory.shape[1]
    dh = cfg.resolved_head_dim(d_model)
    h, hk = cfg.num_heads, cfg.num_kv_heads
    q = (x @ p["wq"]).reshape(b, sq, h, dh)
    k = (memory @ p["wk"]).reshape(b, sk, hk, dh)
    v = (memory @ p["wv"]).reshape(b, sk, hk, dh)
    if "bq" in p:
        q = q + p["bq"].reshape(h, dh)
        k = k + p["bk"].reshape(hk, dh)
        v = v + p["bv"].reshape(hk, dh)
    q_pos = jnp.zeros((b, sq), jnp.int32)
    k_pos = jnp.zeros((b, sk), jnp.int32)
    out = sdpa(q, k, v, q_pos, k_pos, scale=1.0 / (dh ** 0.5), causal=False)
    return out.reshape(b, sq, h * dh) @ p["wo"]
