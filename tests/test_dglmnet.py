"""Core algorithm tests: convergence to the true optimum, equivalence of the
paper-literal (residual) and TPU-native (Gram) inner solvers, block-diagonal
behaviour, sparsity safeguards, lambda_max."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    DGLMNETOptions,
    dglmnet_iteration,
    fit,
    lambda_max,
    margins,
    objective,
)


@pytest.mark.parametrize("method,num_blocks", [
    ("residual", 1), ("gram", 1), ("gram", 4), ("gram", 16), ("residual", 4),
])
def test_converges_to_optimum(small_glm, glm_opt, method, num_blocks):
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 32
    beta_star = glm_opt(X, y, lam)
    f_star = float(objective(margins(X, beta_star), y, beta_star, lam))

    opts = DGLMNETOptions(num_blocks=num_blocks, method=method, tile=32,
                          max_iters=80)
    res = fit(X, y, lam, opts=opts)
    gap = (res.f - f_star) / abs(f_star)
    assert gap < 1e-3, f"gap {gap} too large ({method}, M={num_blocks})"


def test_gram_equals_residual_iterate(small_glm):
    """The Gram-tile reformulation must produce the *same iterates* as the
    paper-literal residual sweep (same math, different order of FLOPs)."""
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 16
    beta = jnp.zeros(X.shape[1])
    m = margins(X, beta)
    d1, dm1, g1 = dglmnet_iteration(
        X, y, beta, m, lam, DGLMNETOptions(num_blocks=4, method="residual"))
    d2, dm2, g2 = dglmnet_iteration(
        X, y, beta, m, lam, DGLMNETOptions(num_blocks=4, method="gram", tile=16))
    assert jnp.allclose(d1, d2, atol=2e-4), float(jnp.max(jnp.abs(d1 - d2)))
    assert jnp.allclose(dm1, dm2, atol=2e-3)
    assert jnp.allclose(g1, g2, rtol=1e-3, atol=1e-3)


def test_lambda_max_gives_zero(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lmax = float(lambda_max(X, y))
    res = fit(X, y, lmax * 1.01, opts=DGLMNETOptions(max_iters=5))
    assert res.nnz == 0
    # just below lambda_max at least one coordinate activates
    res2 = fit(X, y, lmax * 0.5, opts=DGLMNETOptions(max_iters=20))
    assert res2.nnz >= 1


def test_objective_monotone_decrease(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 8
    res = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=8, max_iters=30))
    h = res.objective_history
    assert all(h[i + 1] <= h[i] + 1e-4 * abs(h[i]) for i in range(len(h) - 1)), h


def test_sparsity_vs_lambda_monotone(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lmax = float(lambda_max(X, y))
    nnzs = []
    beta = None
    for div in (2, 8, 32, 128):
        res = fit(X, y, lmax / div, beta0=beta, opts=DGLMNETOptions(max_iters=40))
        beta = res.beta
        nnzs.append(res.nnz)
    assert nnzs == sorted(nnzs), f"nnz not monotone along path: {nnzs}"


def test_warmstart_fewer_iters(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 64
    cold = fit(X, y, lam, opts=DGLMNETOptions(max_iters=100))
    warm_beta = fit(X, y, lam * 2, opts=DGLMNETOptions(max_iters=100)).beta
    warm = fit(X, y, lam, beta0=warm_beta, opts=DGLMNETOptions(max_iters=100))
    assert warm.n_iters <= cold.n_iters


def test_sparse_data(sparse_glm, glm_opt):
    X, y = sparse_glm.X_train, sparse_glm.y_train
    lam = float(lambda_max(X, y)) / 16
    beta_star = glm_opt(X, y, lam)
    f_star = float(objective(margins(X, beta_star), y, beta_star, lam))
    res = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=4, tile=64, max_iters=80))
    assert (res.f - f_star) / abs(f_star) < 1e-3


def test_jacobi_ablation_converges_uncorrelated(small_glm):
    """Shotgun-style parallel updates (ablation path) still converge under
    the paper's line search on weakly-correlated data."""
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 32
    res = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=4, method="jacobi",
                                             max_iters=80))
    ref = fit(X, y, lam, opts=DGLMNETOptions(num_blocks=4, method="gram",
                                             tile=32, max_iters=80))
    assert (res.f - ref.f) / abs(ref.f) < 1e-3
