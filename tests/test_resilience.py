"""PR 8 robustness layer: fault injection, typed status, degradation,
resume, bounded serving.

Fast tests run the full stack on a small local problem; the mesh drill
(`chaos_glm --smoke --mesh 2x4`) is a slow subprocess test, mirroring
tests/test_distributed.py's isolation rule (this process sees 1 device).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import LogisticL1, PathResult
from repro.checkpoint import CheckpointCorruption
from repro.configs.base import GLMConfig
from repro.core import engine
from repro.data.synthetic import make_glm_dataset
from repro.resilience import (
    EngineFault,
    FaultPlan,
    InjectedFault,
    InjectedKill,
    PathProgress,
    RetriesExhausted,
    active_plan,
    corrupt_checkpoint,
    inject_faults,
    retry_call,
)
from repro.serve import (
    InvalidRequest,
    NonFiniteScores,
    Overloaded,
    PathScorer,
    PathStore,
    RequestBatcher,
    batch_capacity,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LAM = 0.05


@pytest.fixture(scope="module")
def tiny_glm():
    cfg = GLMConfig(name="resilience", num_examples=256, num_features=64,
                    density=0.1)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    return ds.X_train, ds.y_train


# ---------------------------------------------------------------------------
# fault plan plumbing
# ---------------------------------------------------------------------------

def test_engine_fault_validation():
    with pytest.raises(ValueError):
        EngineFault("margins", at_iter=0)
    with pytest.raises(ValueError):
        EngineFault("gradients", at_iter=1)
    with pytest.raises(ValueError):
        EngineFault("margins", at_iter=1, mode="zero")


def test_inject_faults_rejects_nesting():
    with inject_faults(FaultPlan()):
        with pytest.raises(RuntimeError):
            with inject_faults(FaultPlan()):
                pass
    assert active_plan() is None


def test_retry_call_backoff_and_exhaustion():
    calls, delays = [], []
    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"
    assert retry_call(flaky, attempts=3, sleep=delays.append) == "ok"
    assert len(calls) == 3 and len(delays) == 2
    assert delays[1] == 2 * delays[0]        # exponential

    def always():
        raise RuntimeError("permanent")
    with pytest.raises(RetriesExhausted) as ei:
        retry_call(always, attempts=2, sleep=lambda s: None)
    assert isinstance(ei.value.__cause__, RuntimeError)
    with pytest.raises(ValueError):           # not in retry_on: no retry
        retry_call(lambda: (_ for _ in ()).throw(ValueError("x")),
                   attempts=3, sleep=lambda s: None)


# ---------------------------------------------------------------------------
# engine guardrails (tentpole b)
# ---------------------------------------------------------------------------

def test_nan_margins_trips_typed_status(tiny_glm):
    X, y = tiny_glm
    est = LogisticL1()
    base = est.fit(X, y, LAM)
    assert base.ok and base.status_name == "OK" and base.status == 0

    plan = FaultPlan(engine=EngineFault("margins", at_iter=3),
                     engine_fires=1)
    with inject_faults(plan):
        res = est.fit(X, y, LAM)
    assert res.status == engine.STATUS_NONFINITE_OBJECTIVE
    assert res.status_name == "NONFINITE_OBJECTIVE" and not res.ok
    # last certified iterate: 2 real iterations, all-finite history that
    # is an exact prefix of the healthy trajectory
    assert res.n_iters == 2
    assert np.all(np.isfinite(np.asarray(res.beta)))
    assert all(np.isfinite(res.objective_history))
    k = len(res.objective_history)
    assert res.objective_history == base.objective_history[:k]

    # the healthy compiled-solver cache was never poisoned
    again = est.fit(X, y, LAM)
    assert again.ok
    assert np.array_equal(np.asarray(again.beta), np.asarray(base.beta))
    assert again.objective_history == base.objective_history


def test_stats_poison_at_first_iter_returns_warm_start(tiny_glm):
    X, y = tiny_glm
    plan = FaultPlan(engine=EngineFault("stats", at_iter=1, mode="inf"),
                     engine_fires=1)
    with inject_faults(plan):
        res = LogisticL1().fit(X, y, LAM)
    assert res.status == engine.STATUS_NONFINITE_OBJECTIVE
    assert res.n_iters == 0
    assert np.array_equal(np.asarray(res.beta),
                          np.zeros_like(np.asarray(res.beta)))


def test_forced_linesearch_stall_trips(tiny_glm):
    X, y = tiny_glm
    plan = FaultPlan(engine=EngineFault("linesearch", at_iter=2),
                     engine_fires=1)
    with inject_faults(plan):
        res = LogisticL1().fit(X, y, LAM)
    assert res.status == engine.STATUS_LINESEARCH_STALLED
    assert res.status_name == "LINESEARCH_STALLED"
    assert res.n_iters == 1
    assert np.all(np.isfinite(np.asarray(res.beta)))


def test_fetch_rejects_ok_status_with_poisoned_history():
    z = np.zeros(2, np.float32)
    mk = lambda status: engine.SolverState(
        beta=z, m=z, f=np.float32(1.0), it=np.int32(1), done=np.bool_(True),
        converged=np.bool_(True), dbeta=z, dm=z, alpha=np.float32(1.0),
        f_new=np.float32(1.0),
        f_hist=np.array([1.0, np.nan, 0.0], np.float32),
        a_hist=np.array([1.0, 0.0], np.float32),
        unit_steps=np.int32(1), status=np.int32(status))
    with pytest.raises(RuntimeError, match="invariant"):
        engine.fetch(mk(engine.STATUS_OK))
    # a tripped solve trims the poisoned tail instead of raising
    host, f_hist, a_hist = engine.fetch(
        mk(engine.STATUS_NONFINITE_OBJECTIVE))
    assert f_hist == [1.0] and a_hist == []


# ---------------------------------------------------------------------------
# path degradation ladder + resume (tentpole b/c)
# ---------------------------------------------------------------------------

def test_path_recovers_transient_fault_bit_identically(tiny_glm):
    X, y = tiny_glm
    est = LogisticL1()
    healthy = est.path(X, y, path_len=3)
    assert healthy.all_ok

    plan = FaultPlan(engine=EngineFault("margins", at_iter=1),
                     engine_fires=1)
    with inject_faults(plan):
        recovered = est.path(X, y, path_len=3)
    # the one poisoned solve was retried down the ladder; the certified
    # output is bit-identical to the healthy run
    assert recovered.all_ok
    assert np.array_equal(np.asarray(recovered.betas),
                          np.asarray(healthy.betas))
    assert any("degraded" in s for s in recovered.screen)


def test_path_persistent_fault_skips_and_marks(tiny_glm):
    X, y = tiny_glm
    plan = FaultPlan(engine=EngineFault("margins", at_iter=1),
                     engine_fires=10 ** 9)
    with inject_faults(plan):
        res = LogisticL1().path(X, y, path_len=3)
    assert not res.all_ok
    assert np.all(res.statuses == engine.STATUS_NONFINITE_OBJECTIVE)
    assert all(s.get("skipped") and s.get("degraded") == "skipped"
               for s in res.screen)
    assert np.all(np.isfinite(np.asarray(res.betas)))
    assert np.all(res.n_iters == 0)


def test_killed_path_resumes_bit_identically(tiny_glm, tmp_path):
    X, y = tiny_glm
    est = LogisticL1()
    full = est.path(X, y, path_len=3)

    d = str(tmp_path / "progress")
    with pytest.raises(InjectedKill):
        with inject_faults(FaultPlan(kill_after_points=2)):
            est.path(X, y, path_len=3, checkpoint_every=1, resume_from=d)
    resumed = est.path(X, y, path_len=3, checkpoint_every=1, resume_from=d)
    assert np.array_equal(np.asarray(resumed.betas), np.asarray(full.betas))
    assert np.array_equal(resumed.lambdas, full.lambdas)
    assert np.array_equal(resumed.f, full.f)
    assert np.array_equal(resumed.nnz, full.nnz)
    assert np.array_equal(resumed.statuses, full.statuses)


def test_path_resume_validates_grid(tiny_glm, tmp_path):
    X, y = tiny_glm
    est = LogisticL1()
    d = str(tmp_path / "progress")
    with pytest.raises(InjectedKill):
        with inject_faults(FaultPlan(kill_after_points=1)):
            est.path(X, y, path_len=3, checkpoint_every=1, resume_from=d)
    with pytest.raises(ValueError, match="different path"):
        est.path(X, y, path_len=4, checkpoint_every=1, resume_from=d)
    with pytest.raises(ValueError, match="requires resume_from"):
        est.path(X, y, path_len=3, checkpoint_every=1)


def test_progress_rolls_back_over_corrupted_slot(tmp_path):
    prog = PathProgress(str(tmp_path), keep=2)
    for i in range(2):
        prog.save(i, {"beta": jnp.arange(3, dtype=jnp.float32) + i},
                  {"kind": "PathProgress", "next_index": i + 1})
    assert prog.pointer() == 1
    corrupt_checkpoint(prog.slot(1), "bitflip")
    idx, arrays, meta = prog.load_latest()
    assert idx == 0 and meta["next_index"] == 1
    assert np.array_equal(arrays["beta"], np.arange(3, dtype=np.float32))


# ---------------------------------------------------------------------------
# bounded serve loop (tentpole d + satellite 1)
# ---------------------------------------------------------------------------

def _path_result(p=16, seed=0):
    rng = np.random.default_rng(seed)
    return PathResult(
        lambdas=np.asarray([1.0, 0.5]),
        betas=jnp.asarray(rng.normal(size=(2, p)), jnp.float32),
        nnz=np.asarray([3, 5]), f=np.asarray([1.0, 0.9]),
        n_iters=np.asarray([2, 3]))


def test_batch_capacity_rejects_non_pow2():
    assert batch_capacity(5) == 8 and batch_capacity(65) == 128
    with pytest.raises(ValueError, match="power of two"):
        batch_capacity(5, b_min=10)
    with pytest.raises(ValueError, match="power of two"):
        batch_capacity(5, b_max=100)
    with pytest.raises(ValueError, match="exceeds"):
        batch_capacity(5, b_min=64, b_max=32)
    with pytest.raises(ValueError, match="power of two"):
        RequestBatcher(16, max_batch=100)


def test_batcher_bounded_queue_and_deadlines():
    t = [0.0]
    b = RequestBatcher(16, max_batch=8, max_pending=3,
                       default_ttl_s=10.0, clock=lambda: t[0])
    b.submit({"a": 1.0}, 0.1, deadline_s=1.0)
    b.submit({"b": 2.0}, 0.1)                    # default ttl 10s
    b.submit({"c": 3.0}, 0.1, deadline_s=5.0)
    with pytest.raises(Overloaded):
        b.submit({"d": 4.0}, 0.1)
    with pytest.raises(InvalidRequest):          # rejected, not queued
        b.submit({"e": float("nan")}, 0.1)
    assert len(b) == 3
    t[0] = 2.0                                    # "a" expires
    batch, lams = b.drain()
    assert batch.n_live == 2 and len(lams) == 2
    assert b.stats == {"submitted": 3, "rejected_overload": 1,
                       "rejected_invalid": 1, "shed_expired": 1,
                       "drained": 2}
    # empty queue drains to an all-padding batch
    batch, lams = b.drain()
    assert batch.n_live == 0 and lams.size == 0


def test_swap_retries_injected_failures():
    with inject_faults(FaultPlan(fail_swaps=2)):
        store = PathStore(_path_result())       # attempts 1+2 fail, 3 lands
    assert store.version == 1
    with inject_faults(FaultPlan(fail_swaps=3)):
        with pytest.raises(RetriesExhausted) as ei:
            store.swap(_path_result(), attempts=2)
    assert isinstance(ei.value.__cause__, InjectedFault)
    assert store.version == 1                   # still serving last-good
    assert store.snapshot.version == 1


def test_nonfinite_scores_pin_store_to_last_good():
    p = 16
    good = _path_result(p)
    bad_b = np.full((2, p), np.nan, np.float32)
    bad = PathResult(lambdas=good.lambdas, betas=jnp.asarray(bad_b),
                     nnz=good.nnz, f=good.f, n_iters=good.n_iters)
    store = PathStore(good)
    scorer = PathScorer(store)
    b = RequestBatcher(p, max_batch=8)
    b.submit({"tok3": 1.5}, 0.5)
    batch, lams = b.drain()
    ref, v1 = scorer.score(batch, lams)
    assert v1 == 1 and np.all(np.isfinite(ref))

    store.swap(bad)
    assert store.snapshot.version == 2
    scores, ver = scorer.score(batch, lams)     # quarantines v2, rescores
    assert ver == 1 and np.array_equal(scores, ref)
    assert store.quarantined == [2]
    assert store.snapshot.version == 1

    # no last-good to fall back to -> typed error, never NaN out
    with pytest.raises(NonFiniteScores):
        PathScorer(PathStore(bad)).score(batch, lams)


def test_from_checkpoint_retries_and_surfaces_corruption(tmp_path):
    d = str(tmp_path / "path")
    good = _path_result()
    good.save(d)
    with inject_faults(FaultPlan(fail_loads=1)):
        store = PathStore.from_checkpoint(d)
    assert store.version == 1
    corrupt_checkpoint(d, "bitflip")
    with pytest.raises(RetriesExhausted) as ei:
        PathStore.from_checkpoint(d, attempts=2)
    assert isinstance(ei.value.__cause__, CheckpointCorruption)


def test_serve_latency_injection_is_scoped():
    import time

    store = PathStore(_path_result())
    scorer = PathScorer(store)
    b = RequestBatcher(16, max_batch=8)
    b.submit({"x": 1.0}, 1.0)
    batch, lams = b.drain()
    scorer.score(batch, lams)                    # warm the program
    with inject_faults(FaultPlan(serve_latency_s=0.05)):
        t0 = time.perf_counter()
        scorer.score(batch, lams)
        # allow[bench-timing]: times an injected host-side sleep floor; score() materializes to numpy before returning, so the section is host-synchronous
        slowed = time.perf_counter() - t0
    assert slowed >= 0.05                        # injected floor applies


# ---------------------------------------------------------------------------
# the chaos drill end-to-end on a 2x4 mesh (the CI chaos-smoke lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_chaos_smoke_on_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.chaos_glm", "--smoke",
         "--mesh", "2x4"],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "CHAOS SMOKE OK" in r.stdout
