"""Perf hillclimb on the paper's own workload: one distributed d-GLMNET
outer iteration at Table-2 scale (glm-dna: n=45M, and glm-epsilon).

Variants lower + compile on 256 fake devices; roofline terms from the
compiled artifact (tile loop unrolled for exact HloCostAnalysis). Results
append to results/hillclimb_glm.json; narrative goes to EXPERIMENTS §Perf.

    PYTHONPATH=src python scripts/hillclimb_glm.py [--variant NAME]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=256"

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.dglmnet import DGLMNETOptions  # noqa: E402
from repro.core.distributed import make_dglmnet_step  # noqa: E402
from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16  # noqa: E402
from repro.launch.roofline import analyze  # noqa: E402
from repro.sharding.ctx import unroll_context  # noqa: E402

N_DNA = 45_000_000
P_DNA = 800
N_EPS = 400_000
P_EPS = 2000


def mesh_of(data, model):
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def lower_variant(*, name, n, p, mesh, tile, dtype=jnp.float32, unroll=True,
                  verbose=True):
    mdim = mesh.shape["model"]
    ddim = mesh.shape["data"]
    n -= n % ddim
    p_pad = ((p + mdim * tile - 1) // (mdim * tile)) * (mdim * tile)
    opts = DGLMNETOptions(tile=tile, method="gram")
    step = make_dglmnet_step(mesh, opts)
    sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    args = (sds((n, p_pad), dtype), sds((n,), jnp.float32),
            sds((p_pad,), jnp.float32), sds((n,), jnp.float32),
            sds((), jnp.float32))
    t0 = time.time()
    with unroll_context(unroll):
        compiled = jax.jit(step).lower(*args).compile()
    # allow[bench-timing]: times lower().compile() — a host-synchronous call, nothing async to block on
    dt_c = time.time() - t0
    chips = ddim * mdim
    # useful flops for one outer iteration (Gram form, unpadded p):
    # G tiles n*tile*p + c/r updates ~ 2*n*p  => ~ n*p*(tile+4) MACs
    mf = 2.0 * n * p * (tile + 4)
    roof = analyze(compiled, arch=name, shape="dglmnet_step",
                   mesh_name=f"{ddim}x{mdim}", chips=chips, model_flops=mf)
    mem = compiled.memory_analysis()
    out = roof.to_dict()
    out.update(compile_s=dt_c, temp_bytes=int(mem.temp_size_in_bytes),
               arg_bytes=int(mem.argument_size_in_bytes), tile=tile,
               dtype=str(dtype.__name__ if hasattr(dtype, '__name__') else dtype),
               n=n, p=p, p_pad=p_pad)
    if verbose:
        print(f"{name:32s} t_comp={roof.t_compute*1e3:8.2f}ms "
              f"t_mem={roof.t_memory*1e3:8.2f}ms "
              f"t_coll={roof.t_collective*1e3:8.2f}ms "
              f"bottleneck={roof.bottleneck:10s} "
              f"temp={mem.temp_size_in_bytes/1e9:6.2f}GB "
              f"args={mem.argument_size_in_bytes/1e9:6.2f}GB "
              f"(compile {dt_c:.0f}s)")
    return out


VARIANTS = {
    # paper-faithful: features-only split (each machine holds all examples)
    "dna.paper-1d-m256.t128": lambda: lower_variant(
        name="dna.paper-1d-m256.t128", n=N_DNA, p=P_DNA,
        mesh=mesh_of(1, 256), tile=128),
    # beyond-paper 2-D: examples x features
    "dna.2d-16x16.t128": lambda: lower_variant(
        name="dna.2d-16x16.t128", n=N_DNA, p=P_DNA,
        mesh=mesh_of(16, 16), tile=128),
    # tile-size sweep on the 2-D layout
    "dna.2d-16x16.t64": lambda: lower_variant(
        name="dna.2d-16x16.t64", n=N_DNA, p=P_DNA,
        mesh=mesh_of(16, 16), tile=64),
    "dna.2d-16x16.t256": lambda: lower_variant(
        name="dna.2d-16x16.t256", n=N_DNA, p=P_DNA,
        mesh=mesh_of(16, 16), tile=256),
    # bf16 design-matrix storage (Gram math still f32 via upcast)
    "dna.2d-16x16.t64.bf16X": lambda: lower_variant(
        name="dna.2d-16x16.t64.bf16X", n=N_DNA, p=P_DNA,
        mesh=mesh_of(16, 16), tile=64, dtype=jnp.bfloat16),
    # wider data axis (examples dominate dna): 64 x 4
    "dna.2d-64x4.t64": lambda: lower_variant(
        name="dna.2d-64x4.t64", n=N_DNA, p=P_DNA,
        mesh=mesh_of(64, 4), tile=64),
    "eps.paper-1d-m256.t128": lambda: lower_variant(
        name="eps.paper-1d-m256.t128", n=N_EPS, p=P_EPS,
        mesh=mesh_of(1, 256), tile=128),
    "eps.2d-16x16.t128": lambda: lower_variant(
        name="eps.2d-16x16.t128", n=N_EPS, p=P_EPS,
        mesh=mesh_of(16, 16), tile=128),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default=None, choices=list(VARIANTS))
    ap.add_argument("--out", default="results/hillclimb_glm.json")
    args = ap.parse_args()
    names = [args.variant] if args.variant else list(VARIANTS)
    results = []
    for nm in names:
        try:
            results.append(VARIANTS[nm]())
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            results.append({"arch": nm, "status": "error", "error": repr(e)})
    prev = []
    if os.path.exists(args.out):
        prev = json.load(open(args.out))
    json.dump(prev + results, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
