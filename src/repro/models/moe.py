"""Mixture-of-Experts layer: top-k routing, capacity-bounded dispatch,
shared expert(s), load-balance + router-z auxiliary losses.

Dispatch is *grouped* (GShard/Switch "group_size" semantics): tokens are
split into DP groups matching the data-parallel extent of the ambient mesh,
each group routes into a per-group capacity slice, and all gathers/scatters
are group-local — so under SPMD partitioning they are pointwise over the
sharded group axis and never become global gathers (which XLA partitions
catastrophically at deepseek scale). The expert einsum contracts a
(G, E, C, D) buffer sharded (batch, model, -, -) against weights gathered
from their FSDP shards — the cross-device token movement is the dispatch
all-to-all implied by (batch) -> (model) resharding.

Rank-within-expert uses a stable sort (O(Tk log Tk)) rather than the
classic (Tk, E) one-hot cumsum (O(Tk*E)) — the latter dominates the whole
step at T ~ 1e6, E = 256.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init
from repro.sharding.ctx import constrain, current_mesh


def init_moe(key, cfg: MoEConfig, d_model: int, dtype):
    e, f = cfg.num_experts, cfg.expert_d_ff
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], d_model, e, jnp.float32)}  # router kept f32
    # per-expert weights, stacked on a leading E axis
    p["w_gate"] = _stack_init(ks[1], e, d_model, f, dtype)
    p["w_up"] = _stack_init(ks[2], e, d_model, f, dtype)
    p["w_down"] = _stack_init(ks[3], e, f, d_model, dtype)
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], d_model, fs, dtype),
            "w_up": dense_init(kk[1], d_model, fs, dtype),
            "w_down": dense_init(kk[2], fs, d_model, dtype),
        }
    return p


def _stack_init(key, e, d_in, d_out, dtype):
    return dense_init(key, d_in, e * d_out, dtype).reshape(d_in, e, d_out).transpose(1, 0, 2)


def capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)  # pad to multiple of 8


def _num_groups(tokens: int) -> int:
    """DP groups = data-parallel extent of the ambient mesh (1 without)."""
    mesh = current_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            g *= mesh.shape[a]
    return g if tokens % g == 0 else 1


def _route_group(xg, router, cfg: MoEConfig, cap: int):
    """Group-local routing. xg: (Tg, D). Returns dispatch/combine indices."""
    tg = xg.shape[0]
    e, k = cfg.num_experts, cfg.top_k
    logits = xg.astype(jnp.float32) @ router                     # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(-1)                              # (Tg*k,)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(tg * k, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((tg * k,), jnp.int32).at[order].set(pos_sorted).reshape(tg, k)
    keep = pos < cap

    tok_ids = jnp.broadcast_to(jnp.arange(tg)[:, None], (tg, k))
    scat_e = jnp.where(keep, expert_idx, e)                      # e = sentinel row
    scat_c = jnp.where(keep, pos, 0)
    buf_idx = jnp.full((e + 1, cap), tg, jnp.int32).at[
        scat_e.reshape(-1), scat_c.reshape(-1)
    ].set(tok_ids.reshape(-1), mode="drop")[:e]                  # (E, C)

    return logits, probs, gate_vals, expert_idx, pos, keep, buf_idx


def moe_forward(p, x: jnp.ndarray, *, cfg: MoEConfig, deterministic: bool = True,
                rng=None) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (B, S, D), aux dict with load-balance losses."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    groups = _num_groups(t)
    tg = t // groups
    cap = capacity(tg, cfg)

    xf = x.reshape(groups, tg, d)
    xf = constrain(xf, "batch", None, None)

    route = jax.vmap(lambda xg: _route_group(xg, p["router"], cfg, cap))
    logits, probs, gate_vals, expert_idx, pos, keep, buf_idx = route(xf)

    # group-local dispatch gather: (G, Tg+1, D)[g, buf_idx[g]] -> (G,E,C,D)
    xpad = jnp.concatenate([xf, jnp.zeros((groups, 1, d), xf.dtype)], axis=1)
    expert_in = jax.vmap(lambda xp, bi: jnp.take(xp, bi.reshape(-1), axis=0))(
        xpad, buf_idx
    ).reshape(groups, e, cap, d)
    expert_in = constrain(expert_in, "batch", "model", None, None)

    # re-gather FSDP weight shards so the expert einsum is conflict-free
    wg = constrain(p["w_gate"], "model", None, None)
    wu = constrain(p["w_up"], "model", None, None)
    wd = constrain(p["w_down"], "model", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, wg)) * jnp.einsum(
        "gecd,edf->gecf", expert_in, wu
    )
    h = constrain(h, "batch", "model", None, None)
    expert_out = jnp.einsum("gecf,efd->gecd", h, wd)             # (G, E, C, D)
    expert_out = constrain(expert_out, "batch", "model", None, None)

    # group-local combine gather
    flat_slot = (expert_idx * cap + pos).reshape(groups, tg * k)  # (G, Tg*k)
    eo = expert_out.reshape(groups, e * cap, d)
    gathered = jnp.take_along_axis(
        eo, jnp.where(keep.reshape(groups, tg * k), flat_slot, 0)[:, :, None], axis=1
    ).reshape(groups, tg, k, d)
    gathered = constrain(gathered, "batch", None, None, None)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    y = jnp.einsum("gtkd,gtk->gtd", gathered, gate_vals.astype(gathered.dtype))
    y = y.reshape(t, d)

    if "shared" in p:
        sp = p["shared"]
        xt = x.reshape(t, d)
        hs = jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        y = y + (hs @ sp["w_down"]).astype(y.dtype)

    # aux losses (Switch-style load balance + router z-loss), global means
    me = probs.reshape(t, e).mean(0)                             # (E,)
    ce = jax.nn.one_hot(expert_idx.reshape(t, k)[:, 0], e).mean(0)
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits.reshape(t, e), axis=-1) ** 2)
    aux = {
        "moe_lb_loss": cfg.aux_loss_weight * lb_loss,
        "moe_z_loss": cfg.router_z_loss_weight * z_loss,
        "moe_drop_frac": 1.0 - keep.mean(),
    }
    return y.reshape(b, s, d).astype(x.dtype), aux
