"""Per-lambda progress store behind ``LogisticL1.path(checkpoint_every=)``.

Layout under one progress directory::

    <dir>/point-00004/   repro.checkpoint dir (manifest + CRC'd payload)
    <dir>/point-00009/   ... rotated, newest ``keep`` slots retained ...
    <dir>/LATEST         atomic pointer file: index of the newest slot

Each slot is a full :func:`repro.checkpoint.save_pytree` checkpoint
(atomic publish + CRC-32 payload integrity), written *after* the path
point it names was emitted; the ``LATEST`` pointer is replaced atomically
after the slot lands, so a crash at any instant leaves either the old or
the new pointer — never a pointer to a half-written slot. On load, a slot
that fails its integrity check (:class:`repro.checkpoint.
CheckpointCorruption`) is skipped and the next-older retained slot is
used — corruption costs re-solving a few lambdas, not the whole path.

JAX is imported lazily (inside methods, via ``repro.checkpoint``) so this
module — like the rest of ``repro.resilience`` — imports anywhere.
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import numpy as np

_SLOT_RE = re.compile(r"^point-(\d{5})$")
_POINTER = "LATEST"


def _leaf_name(path_str: str) -> str:
    """``jax.tree_util.keystr`` of a flat-dict key, back to the key."""
    if path_str.startswith("['") and path_str.endswith("']"):
        return path_str[2:-2]
    return path_str


class PathProgress:
    """Rotated, integrity-checked per-point checkpoints of a path solve.

    ``keep`` >= 2 so the newest slot can be corrupted (torn write, disk
    fault) and resume still has a certified fallback.
    """

    def __init__(self, directory: str, *, keep: int = 2):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- paths -------------------------------------------------------------

    def slot(self, idx: int) -> str:
        return os.path.join(self.directory, f"point-{idx:05d}")

    def slots(self):
        """Indices of the retained slots, oldest first."""
        out = []
        for name in os.listdir(self.directory):
            match = _SLOT_RE.match(name)
            if match:
                out.append(int(match.group(1)))
        return sorted(out)

    # -- write -------------------------------------------------------------

    def save(self, idx: int, tree: Dict[str, Any], meta: dict) -> str:
        """Checkpoint ``tree`` (a flat dict of arrays) + ``meta`` as slot
        ``idx``, publish the pointer, prune old slots. Returns the slot
        directory."""
        from repro import checkpoint

        directory = checkpoint.save_pytree(tree, self.slot(idx), step=idx,
                                           meta=meta)
        self._publish(idx)
        self._prune(idx)
        return directory

    def _publish(self, idx: int) -> None:
        pointer = os.path.join(self.directory, _POINTER)
        tmp = f"{pointer}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                f.write(f"{idx}\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, pointer)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def _prune(self, newest: int) -> None:
        for idx in self.slots():
            if idx <= newest - self.keep:
                shutil.rmtree(self.slot(idx), ignore_errors=True)

    # -- read --------------------------------------------------------------

    def pointer(self) -> Optional[int]:
        """The raw LATEST pointer value, or None when never published."""
        try:
            with open(os.path.join(self.directory, _POINTER)) as f:
                return int(f.read().strip())
        except (FileNotFoundError, ValueError):
            return None

    def load(self, idx: int) -> Tuple[Dict[str, np.ndarray], dict]:
        """Arrays + meta of slot ``idx``; raises ``CheckpointCorruption``
        when the slot fails its integrity contract."""
        from repro.checkpoint import CheckpointCorruption
        from repro.checkpoint.checkpointer import _read_manifest, verify_payload

        directory = self.slot(idx)
        manifest = _read_manifest(directory)
        verify_payload(directory)
        try:
            data = np.load(os.path.join(directory, "arrays.npz"))
        except (OSError, ValueError) as err:
            raise CheckpointCorruption(
                f"unreadable payload in {directory}: {err}")
        arrays = {_leaf_name(e["path"]): np.asarray(data[e["key"]])
                  for e in manifest["leaves"]}
        meta = manifest.get("meta")
        if meta is None:
            raise CheckpointCorruption(
                f"slot {directory} has no meta side channel — cannot "
                f"rebuild path state from arrays alone")
        return arrays, meta

    def load_latest(self) -> Optional[Tuple[int, Dict[str, np.ndarray], dict]]:
        """Newest loadable state: ``(idx, arrays, meta)``, walking back
        over corrupted slots; None when nothing usable remains."""
        from repro.checkpoint import CheckpointCorruption

        ptr = self.pointer()
        candidates = self.slots()
        # pointer first (it is the committed one), then newest-to-oldest
        order = ([ptr] if ptr in candidates else []) + \
            [i for i in sorted(candidates, reverse=True) if i != ptr]
        for idx in order:
            try:
                arrays, meta = self.load(idx)
                return idx, arrays, meta
            except CheckpointCorruption:
                continue
        return None

    def describe(self) -> str:
        ptr = self.pointer()
        return (f"PathProgress({self.directory!r}: pointer={ptr}, "
                f"slots={self.slots()}, keep={self.keep})")
