"""Quadratic subproblem solver (paper Algorithm 2).

Minimize, over the machine's feature block S_m,

    L_q(beta, dbeta) + lam * ||beta + dbeta||_1
    = 1/2 sum_i w_i (z_i - dbeta^T x_i)^2 + lam * ||beta + dbeta||_1 + C

with ONE cycle of cyclic coordinate descent (the paper found one cycle
sufficient; ``n_cycles`` is configurable). Damping: h_j += nu (paper's
H~ + nu*I with nu = 1e-6).

Two mathematically identical implementations:

* ``cd_cycle_residual`` — the paper-literal form: sequential sweep with the
  per-example residual r_i = z_i - dbeta^T x_i updated after each coordinate.
  O(n * p_b) streaming work; the reference/oracle.
* ``cd_cycle_gram`` — the TPU-native form (DESIGN.md §2.3): per feature tile
  compute G = X_F^T diag(w) X_F and c = X_F^T (w*r) with MXU matmuls, run the
  sequential cycle on the F x F Gram tile (Pallas kernel `gram_cd`), then
  reconstruct the residual update with one more matmul. Identical iterates.

Plus the *semi-parallel* tile cycle this sequence does not need to be:

* ``cd_cycle_blocked_tile`` — partition the F-wide tile into B-wide blocks
  and update all B coordinates of a block Jacobi-style from a shared
  gradient snapshot (one masked matvec per block instead of B dependent
  scalar steps); blocks are applied sequentially via ``s += G[:, blk] @
  d_blk``. Shotgun (Bradley et al., 1105.5379) licenses the concurrent
  within-block update when the coordinates are weakly coupled; the paper's
  Theorem-1 rate only needs the block-separable model plus the global line
  search, so an inexact within-tile cycle is admissible (Mahajan et al.,
  1405.4544). A per-block Gershgorin dominance check
  (:func:`blocked_cycle_modes`) halves B and finally falls back to the
  sequential scalar chain for pathologically correlated blocks — and every
  outer step stays monotone regardless because the engine's line search
  safeguards the combined direction. With B=1 the blocked cycle *is* the
  sequential chain, bit for bit.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.objective import soft_threshold

NU = 1e-6
# Strict within-block diagonal dominance (row-sum Gershgorin ratio < 1)
# makes the proximal-Jacobi block update a contraction; 0.9 leaves margin
# for the soft-threshold kinks. Above it, halve; above it at B/2, go
# sequential. The global line search makes any choice safe — the safeguard
# is about not *wasting* outer iterations on conflicted updates.
DOM_TOL = 0.9


# ---------------------------------------------------------------------------
# paper-literal residual-update CD
# ---------------------------------------------------------------------------

def cd_cycle_residual(
    X: jnp.ndarray,          # (n, p_b) the machine's feature block
    w: jnp.ndarray,          # (n,)
    r: jnp.ndarray,          # (n,) residual z - dbeta^T x (block-local)
    beta: jnp.ndarray,       # (p_b,) current weights for this block
    dbeta: jnp.ndarray,      # (p_b,) accumulated update for this block
    lam: float,
    nu: float = NU,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One cycle over all features in the block. Returns (dbeta, r)."""

    h_all = (w[:, None] * X * X).sum(axis=0) + nu   # (p_b,) curvature per coord

    def body(j, carry):
        dbeta, r = carry
        xj = jax.lax.dynamic_slice_in_dim(X, j, 1, axis=1)[:, 0]
        g = jnp.dot(w * xj, r)                      # sum_i w x_ij r_i
        h = h_all[j]
        b_old = beta[j] + dbeta[j]
        b_new = soft_threshold(g + b_old * h, lam) / h
        delta = b_new - b_old
        r = r - delta * xj
        dbeta = dbeta.at[j].add(delta)
        return dbeta, r

    dbeta, r = jax.lax.fori_loop(0, X.shape[1], body, (dbeta, r))
    return dbeta, r


# ---------------------------------------------------------------------------
# Gram-tile CD (TPU-native; same iterates)
# ---------------------------------------------------------------------------

def cd_cycle_jacobi_tile(
    G: jnp.ndarray,
    c: jnp.ndarray,
    beta: jnp.ndarray,
    dbeta0: jnp.ndarray,
    lam: float,
    nu: float = NU,
) -> jnp.ndarray:
    """Shotgun-style ablation (Bradley et al. 2011, paper §1): ALL
    coordinates updated in parallel from the same residual (Jacobi), no
    within-tile sequencing. Fully parallel but updates conflict when
    features correlate — the paper's motivation for sequential cycles within
    blocks + a global line search. Used by the ablation benchmark only."""
    diag = jnp.diagonal(G) + nu
    b_old = beta + dbeta0
    u = c + b_old * diag
    b_new = soft_threshold(u, lam) / diag
    return b_new - b_old


def cd_cycle_gram_tile(
    G: jnp.ndarray,          # (F, F) = X_F^T diag(w) X_F
    c: jnp.ndarray,          # (F,)   = X_F^T (w * r) at tile entry
    beta: jnp.ndarray,       # (F,)
    dbeta0: jnp.ndarray,     # (F,) accumulated update at tile entry
    lam: float,
    nu: float = NU,
) -> jnp.ndarray:
    """Sequential CD cycle on a Gram tile; returns the *delta within this
    cycle* d (so dbeta becomes dbeta0 + d). Pure-jnp oracle for the Pallas
    kernel ``gram_cd``.

    Maintains s = G @ d so that  g_j = c_j - s_j  equals  sum w x_j r  with
    r the live residual.
    """
    f = G.shape[0]
    diag = jnp.diagonal(G) + nu

    def body(j, carry):
        d, s = carry
        g = c[j] - s[j]
        h = diag[j]
        b_old = beta[j] + dbeta0[j] + d[j]
        b_new = soft_threshold(g + b_old * h, lam) / h
        delta = b_new - b_old
        s = s + delta * G[:, j]
        d = d.at[j].add(delta)
        return d, s

    # zeros_like(c) keeps shard_map varying-axis metadata consistent
    d, _ = jax.lax.fori_loop(0, f, body, (jnp.zeros_like(c), jnp.zeros_like(c)))
    return d


def _block_dominance(G: jnp.ndarray, width: int, nu: float) -> jnp.ndarray:
    """Per-block Gershgorin row ratio: for each ``width``-wide diagonal
    block of G, ``max_j sum_{k != j, same block} |G_jk| / (G_jj + nu)``.
    Ratio < 1 is strict within-block diagonal dominance — the proximal
    Jacobi update on the block is a contraction. The same-block mask is a
    compile-time constant, so this is one fused elementwise pass over G
    (no gathers — it must stay cheap under vmap and inside scans)."""
    f = G.shape[0]
    nb = f // width
    blk = jnp.arange(f) // width
    same = (blk[:, None] == blk[None, :]).astype(G.dtype)   # static (F, F)
    adiag = jnp.abs(jnp.diagonal(G))
    offsum = (jnp.abs(G) * same).sum(axis=1) - adiag
    rho = offsum / (jnp.diagonal(G) + nu)
    return rho.reshape(nb, width).max(axis=1)


def blocked_cycle_modes(G: jnp.ndarray, block: int, nu: float = NU,
                        dom_tol: float = DOM_TOL) -> jnp.ndarray:
    """Per-block safeguard decision for the blocked cycle, from G alone
    (iterate-independent, so it is computed once per tile and shared by the
    oracle and the Pallas kernel):

    * 0 — full-B proximal-Jacobi step (block passes the dominance check)
    * 1 — two sequential B/2-wide Jacobi sub-steps (only the halves pass)
    * 2 — sequential scalar chain over the block (pathological correlation)
    """
    f = G.shape[0]
    nb = f // block
    if block <= 1:
        return jnp.zeros(nb, jnp.int32)
    rho_full = _block_dominance(G, block, nu)
    if block % 2:
        return jnp.where(rho_full <= dom_tol, 0, 2).astype(jnp.int32)
    rho_half = _block_dominance(G, block // 2, nu).reshape(nb, 2).max(axis=1)
    return jnp.where(
        rho_full <= dom_tol, 0, jnp.where(rho_half <= dom_tol, 1, 2)
    ).astype(jnp.int32)


def cd_cycle_blocked_tile(
    G: jnp.ndarray,          # (F, F) = X_F^T diag(w) X_F
    c: jnp.ndarray,          # (F,)   = X_F^T (w * r) at tile entry
    beta: jnp.ndarray,       # (F,)
    dbeta0: jnp.ndarray,     # (F,) accumulated update at tile entry
    lam: float,
    nu: float = NU,
    *,
    block: int = 16,
    dom_tol: float = DOM_TOL,
) -> jnp.ndarray:
    """Blocked semi-parallel CD cycle on a Gram tile: B coordinates at a
    time update Jacobi-style from the shared snapshot ``g = c - s``, then
    ``s += G[:, blk] @ d_blk`` applies the block before the next one — F/B
    dependent steps instead of F. Per-block safeguard via
    :func:`blocked_cycle_modes`. Pure-jnp oracle for the Pallas kernel
    ``blocked_cd``; with ``block=1`` the iterates are bit-identical to
    :func:`cd_cycle_gram_tile`."""
    f = G.shape[0]
    if f % block:
        raise ValueError(f"block={block} must divide the tile width F={f}")
    nb = f // block
    diag = jnp.diagonal(G) + nu
    base = beta + dbeta0
    modes = blocked_cycle_modes(G, block, nu=nu, dom_tol=dom_tol)

    def jacobi(carry, start, width):
        """One proximal-Jacobi step on coords [start, start+width)."""
        d, s = carry
        sl = lambda v: jax.lax.dynamic_slice(v, (start,), (width,))
        g = sl(c) - sl(s)
        h = sl(diag)
        d_blk = sl(d)
        b_old = sl(base) + d_blk
        b_new = soft_threshold(g + b_old * h, lam) / h
        delta = b_new - b_old
        cols = jax.lax.dynamic_slice(G, (0, start), (f, width))
        s = s + (cols * delta[None, :]).sum(axis=1)   # s += G[:, blk] @ d_blk
        d = jax.lax.dynamic_update_slice(d, d_blk + delta, (start,))
        return d, s

    def seq_chain(carry, start):
        """The sequential scalar fallback, restricted to one block."""
        def body(i, carry):
            d, s = carry
            j = start + i
            g = c[j] - s[j]
            h = diag[j]
            b_old = base[j] + d[j]
            b_new = soft_threshold(g + b_old * h, lam) / h
            delta = b_new - b_old
            s = s + delta * G[:, j]
            d = d.at[j].add(delta)
            return d, s

        return jax.lax.fori_loop(0, block, body, carry)

    def block_step(b, carry):
        start = b * block
        if block == 1:
            # a 1-wide block is exactly one sequential step; no safeguard
            # branches to trace (and B/2 = 0 must never be traced)
            return jacobi(carry, start, 1)
        return jax.lax.switch(
            modes[b],
            (
                lambda cr: jacobi(cr, start, block),
                lambda cr: jacobi(jacobi(cr, start, block // 2),
                                  start + block // 2, block // 2),
                lambda cr: seq_chain(cr, start),
            ),
            carry,
        )

    d, _ = jax.lax.fori_loop(
        0, nb, block_step, (jnp.zeros_like(c), jnp.zeros_like(c))
    )
    return d


def make_tile_solver(*, cycle_mode: str = "sequential", tile: int,
                     block: int = 16, use_kernel: bool = False,
                     dom_tol: float = DOM_TOL):
    """Resolve the per-tile CD cycle implementation every hot path shares
    (``cd_cycle_gram``, the distributed dense/sparse subproblems).

    ``cycle_mode``: "sequential" (the exact chain), "blocked" (semi-parallel
    blocked cycle), or "auto" (the kernel layer's tile-size heuristic
    ``prefer_blocked_cd`` picks). ``use_kernel`` swaps in the Pallas kernels
    (native on TPU, interpret-mode elsewhere). The returned callable has the
    tile-solver signature ``(G, c, beta, dbeta0, lam, nu) -> d``.
    """
    if cycle_mode == "auto":
        from repro.kernels.ops import prefer_blocked_cd

        cycle_mode = ("blocked" if prefer_blocked_cd(tile, block)
                      else "sequential")
    if cycle_mode == "blocked":
        if use_kernel:
            from repro.kernels.ops import blocked_cd

            return partial(blocked_cd, block=block, dom_tol=dom_tol)
        return partial(cd_cycle_blocked_tile, block=block, dom_tol=dom_tol)
    if cycle_mode != "sequential":
        raise ValueError(f"unknown cycle_mode {cycle_mode!r}")
    if use_kernel:
        from repro.kernels.ops import gram_cd

        return gram_cd
    return cd_cycle_gram_tile


def cd_cycle_gram(
    X: jnp.ndarray,
    w: jnp.ndarray,
    r: jnp.ndarray,
    beta: jnp.ndarray,
    dbeta: jnp.ndarray,
    lam: float,
    *,
    tile: int = 256,
    nu: float = NU,
    use_kernel: bool = False,
    cycle_mode: str = "sequential",
    block: int = 16,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One full CD cycle over the block via Gram tiles (exact, tiled).

    Residual is updated *between* tiles with a dense matmul, so with the
    sequential cycle the iterates are identical to ``cd_cycle_residual``;
    ``cycle_mode="blocked"`` swaps each tile's chain for the semi-parallel
    blocked cycle (``cd_cycle_blocked_tile``).
    """
    n, p_b = X.shape
    pad = (-p_b) % tile
    if pad:
        X = jnp.pad(X, ((0, 0), (0, pad)))
        beta = jnp.pad(beta, (0, pad))
        dbeta = jnp.pad(dbeta, (0, pad))
    pt = X.shape[1]
    nt = pt // tile
    Xt = X.reshape(n, nt, tile)

    tile_solver = make_tile_solver(cycle_mode=cycle_mode, tile=tile,
                                   block=block, use_kernel=use_kernel)

    def tile_step(carry, idx):
        r, dbeta_f = carry
        Xf = Xt[:, idx, :]                           # (n, F)
        wX = w[:, None] * Xf
        G = Xf.T @ wX                                # (F, F) MXU
        c = wX.T @ r                                 # (F,)
        b_f = jax.lax.dynamic_slice(beta, (idx * tile,), (tile,))
        db_f = jax.lax.dynamic_slice(dbeta_f, (idx * tile,), (tile,))
        d = tile_solver(G, c, b_f, db_f, lam, nu)
        r = r - Xf @ d                               # residual to next tile
        dbeta_f = jax.lax.dynamic_update_slice(dbeta_f, db_f + d, (idx * tile,))
        return (r, dbeta_f), None

    (r, dbeta), _ = jax.lax.scan(tile_step, (r, dbeta), jnp.arange(nt))
    return dbeta[:p_b], r


def solve_subproblem(
    X: jnp.ndarray,
    w: jnp.ndarray,
    z: jnp.ndarray,
    beta: jnp.ndarray,
    lam: float,
    *,
    method: str = "gram",        # "gram" | "blocked" | "residual" | "jacobi"
    n_cycles: int = 1,
    tile: int = 256,
    use_kernel: bool = False,
    cycle_mode: str = "sequential",   # "sequential" | "blocked" | "auto"
    block: int = 16,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Algorithm 2 on one feature block.

    Returns (dbeta, dmargin) where dmargin = X @ dbeta (the per-example
    update the paper all-reduces alongside dbeta). ``method="blocked"`` is
    shorthand for the Gram-tile path with ``cycle_mode="blocked"`` (the
    semi-parallel within-tile cycle); ``cycle_mode`` applies whenever the
    Gram path runs.
    """
    dbeta = jnp.zeros_like(beta)
    r = z                                            # dbeta = 0 initially

    if method == "blocked":
        method, cycle_mode = "gram", "blocked"
    for _ in range(n_cycles):
        if method == "residual":
            dbeta, r = cd_cycle_residual(X, w, r, beta, dbeta, lam)
        elif method == "gram":
            dbeta, r = cd_cycle_gram(
                X, w, r, beta, dbeta, lam, tile=tile, use_kernel=use_kernel,
                cycle_mode=cycle_mode, block=block,
            )
        elif method == "jacobi":
            # Shotgun-style ablation: fully parallel updates, no sequencing
            wX = w[:, None] * X
            G = X.T @ wX
            c = wX.T @ r
            d = cd_cycle_jacobi_tile(G, c, beta, dbeta, lam)
            dbeta = dbeta + d
            r = r - X @ d
        else:
            raise ValueError(method)

    return dbeta, X @ dbeta
