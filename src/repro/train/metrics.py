"""Evaluation metrics. AUPRC (area under the Precision-Recall curve) is the
paper's Figure-1 metric; implemented as average precision over the ranked
scores (no sklearn in this environment)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def auprc(scores, labels) -> float:
    """Average precision. labels in {-1,+1} (or {0,1}); scores any real."""
    s = np.asarray(scores, np.float64)
    y = np.asarray(labels)
    y = (y > 0).astype(np.float64)
    order = np.argsort(-s, kind="stable")
    y = y[order]
    tp = np.cumsum(y)
    k = np.arange(1, len(y) + 1)
    precision = tp / k
    n_pos = y.sum()
    if n_pos == 0:
        return 0.0
    # AP = mean of precision at each positive
    return float((precision * y).sum() / n_pos)


def accuracy(scores, labels) -> float:
    s = np.asarray(scores)
    y = np.asarray(labels) > 0
    return float(((s > 0) == y).mean())


def log_loss(scores, labels) -> float:
    m = jnp.asarray(scores)
    y = jnp.where(jnp.asarray(labels) > 0, 1.0, -1.0)
    return float(jnp.mean(jnp.logaddexp(0.0, -y * m)))


def metrics_from_scores(scores, labels) -> dict:
    """The paper's Figure-1 metric set from precomputed scores — shared by
    the host-matrix ``glm_eval_fn`` and the design-streaming
    ``repro.api.make_design_eval`` (which computes the scores on the mesh
    and ships only the (n_test,) vector to host)."""
    return {
        "auprc": auprc(scores, labels),
        "accuracy": accuracy(scores, labels),
        "logloss": log_loss(scores, labels),
    }


def glm_eval_fn(X_test, y_test):
    """eval_fn for the regularization path: test AUPRC + accuracy from a
    host-resident test matrix."""

    def fn(beta):
        return metrics_from_scores(X_test @ beta, y_test)

    return fn
