"""Mesh collection helpers shared by the solver drivers.

``jnp.concatenate`` of P(model)-sharded pieces of different lengths
miscompiles on the JAX pinned in this environment (the partitioner emits a
wrong-extent dynamic-update window, observed as garbage tails in the
concatenated screen output — first hit by the per-bucket screened path in
PR 3). The guard is simple: reshard every piece to replicated *before* the
concatenate. The pieces this repo concatenates are O(p) feature-axis
vectors the drivers' elementwise mask math wants replicated anyway, so the
reshard costs one allgather that the subsequent host sync would have paid
regardless.

This module is the single home of that workaround; call sites must not
inline their own ``device_put``-then-concat dance (a second inline copy is
how the bug comes back when one site gets fixed and the other doesn't).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def replicate(x, mesh: Mesh):
    """Reshard ``x`` to fully replicated on ``mesh`` (P() over all axes).

    The building block for feature-axis collection and for handing sharded
    vectors to host-side consumers (metrics, numpy) without relying on
    ``device_get`` semantics for partially-addressable layouts.
    """
    return jax.device_put(x, NamedSharding(mesh, P()))


def concat_replicated(pieces: Sequence, mesh: Mesh, axis: int = 0):
    """Concatenate mesh arrays along ``axis`` via the replicate-first guard.

    Use this instead of ``jnp.concatenate`` whenever any piece may carry a
    P(model) (or otherwise sharded) layout — concatenating sharded pieces
    of unequal length miscompiles on current JAX (see module docstring).
    """
    pieces = [replicate(piece, mesh) for piece in pieces]
    if len(pieces) == 1:
        return pieces[0]
    return jnp.concatenate(pieces, axis=axis)
