"""Runtime sanitizers for the device-resident contract.

Two context managers back the static rules with teeth at test time:

``transfer_sanitizer``
    Pins the engine's one-transfer-per-solve contract. On CPU,
    ``jax.transfer_guard`` is a no-op (host and "device" share memory, so
    JAX never records a transfer), so this patches the implicit
    device→host conversion points directly:

    * ``ArrayImpl._value`` — the materialization property behind
      ``float()``, ``int()``, ``bool()``, ``.tolist()``, ``str()`` and
      ``jax.device_get``;
    * ``ArrayImpl.item()``.

    The ONE sanctioned fetch door is ``repro.core.engine.device_get``
    (the module-level indirection the engine's ``fetch`` epilogue calls);
    it is wrapped to count fetches against ``max_fetches``. Anything else
    that drags a device value to host inside the context raises
    :class:`HostTransferError` at the offending line.

    Known gap: ``np.asarray(x)`` reaches the buffer through the C++
    ``__array__`` slot and cannot be intercepted from Python — the static
    ``host-sync-in-jit`` rule is the cover for that spelling.

    On real accelerators the context *additionally* arms
    ``jax.transfer_guard_device_to_host("disallow")``, so explicit-copy
    paths that bypass ``_value`` still fault.

``compile_sanitizer``
    A compile-count budget: arms ``jax_log_compiles`` and counts
    "Finished XLA compilation" records. ``compile_sanitizer(0)`` around
    the warm leg of a warm-started regularization path is the
    zero-retrace certificate — if any per-lambda solve retraces, the
    context raises :class:`CompileBudgetExceeded` naming the recompiled
    computations.
"""
from __future__ import annotations

import logging
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import List


class HostTransferError(RuntimeError):
    """A device value was materialized on host outside the sanctioned
    ``repro.core.engine.device_get`` door."""


class FetchBudgetExceeded(HostTransferError):
    """More sanctioned fetches than the contract allows."""


class CompileBudgetExceeded(RuntimeError):
    """More XLA compilations than the budget allows."""


@dataclass
class TransferStats:
    """What the transfer sanitizer saw: sanctioned fetches only (anything
    unsanctioned raised instead of being recorded)."""

    max_fetches: int
    fetches: int = 0


@dataclass
class CompileStats:
    max_compiles: int
    compiles: List[str] = field(default_factory=list)

    @property
    def count(self) -> int:
        return len(self.compiles)


@contextmanager
def transfer_sanitizer(max_fetches: int = 1):
    """Forbid device→host materialization except through
    ``repro.core.engine.device_get``, and allow at most ``max_fetches``
    of those. Yields a :class:`TransferStats`."""
    import jax
    from jax._src import array as _array_mod

    from repro.core import engine as _engine

    stats = TransferStats(max_fetches=max_fetches)
    # Re-entrancy latch: engine.device_get flips it while delegating to
    # the real jax.device_get, whose implementation goes through the
    # patched ``_value`` property.
    state = {"sanctioned": False}

    orig_value = _array_mod.ArrayImpl._value
    orig_item = _array_mod.ArrayImpl.item
    orig_fetch = _engine.device_get

    def guarded_value(self):
        if not state["sanctioned"]:
            raise HostTransferError(
                "device value materialized on host (float()/int()/bool()/"
                "tolist()/device_get) outside repro.core.engine.device_get "
                "— the engine contract is one sanctioned fetch per solve"
            )
        if isinstance(orig_value, property):
            return orig_value.fget(self)
        return orig_value.__get__(self)()

    def guarded_item(self, *a, **k):
        if not state["sanctioned"]:
            raise HostTransferError(
                ".item() on a device value outside "
                "repro.core.engine.device_get"
            )
        return orig_item(self, *a, **k)

    def sanctioned_fetch(tree):
        stats.fetches += 1  # allow[metric-discipline]: the sanitizer IS the counted-fetch meter — it enforces the contract and must work with repro.obs disabled
        if stats.fetches > stats.max_fetches:
            raise FetchBudgetExceeded(
                f"sanctioned fetch #{stats.fetches} exceeds the budget of "
                f"{stats.max_fetches} — the engine contract is "
                f"{stats.max_fetches} host transfer(s) in this scope"
            )
        state["sanctioned"] = True
        try:
            return jax.device_get(tree)
        finally:
            state["sanctioned"] = False

    _array_mod.ArrayImpl._value = property(guarded_value)
    _array_mod.ArrayImpl.item = guarded_item
    _engine.device_get = sanctioned_fetch
    try:
        if jax.default_backend() != "cpu":  # pragma: no cover - CPU CI
            with jax.transfer_guard_device_to_host("disallow"):
                yield stats
        else:
            yield stats
    finally:
        _array_mod.ArrayImpl._value = orig_value
        _array_mod.ArrayImpl.item = orig_item
        _engine.device_get = orig_fetch


class _CompileCounter(logging.Handler):
    _FINISHED = "Finished XLA compilation of "

    def __init__(self, stats: CompileStats):
        super().__init__(level=logging.DEBUG)
        self.stats = stats

    def emit(self, record: logging.LogRecord) -> None:
        msg = record.getMessage()
        if self._FINISHED in msg:
            name = msg.split(self._FINISHED, 1)[1].split(" in ")[0]
            self.stats.compiles.append(name)


#: loggers that announce XLA compilations (jit and pjit/shard_map paths)
_COMPILE_LOGGERS = ("jax._src.dispatch", "jax._src.interpreters.pxla")


@contextmanager
def compile_sanitizer(max_compiles: int = 0):
    """Budget the number of XLA compilations inside the context; 0 is the
    zero-retrace certificate for warm code. Raises
    :class:`CompileBudgetExceeded` on exit, naming each compiled
    computation. Yields a :class:`CompileStats`."""
    import jax

    stats = CompileStats(max_compiles=max_compiles)
    handler = _CompileCounter(stats)

    prev_flag = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    loggers = [logging.getLogger(n) for n in _COMPILE_LOGGERS]
    prev_levels = [lg.level for lg in loggers]
    prev_propagate = [lg.propagate for lg in loggers]
    for lg in loggers:
        lg.addHandler(handler)
        lg.propagate = False        # count quietly; restore on exit
        if lg.level > logging.WARNING or lg.level == logging.NOTSET:
            lg.setLevel(logging.WARNING)
    try:
        yield stats
    finally:
        for lg, lv, pr in zip(loggers, prev_levels, prev_propagate):
            lg.removeHandler(handler)
            lg.setLevel(lv)
            lg.propagate = pr
        jax.config.update("jax_log_compiles", prev_flag)
    if stats.count > max_compiles:
        raise CompileBudgetExceeded(
            f"{stats.count} XLA compilation(s) inside a budget of "
            f"{max_compiles}: {', '.join(stats.compiles)}"
        )
