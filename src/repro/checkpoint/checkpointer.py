"""Dependency-free pytree checkpointer (no orbax in this environment).

Layout: <dir>/manifest.json  (treedef + leaf paths + dtypes/shapes + CRC)
        <dir>/arrays.npz     (leaf arrays keyed by sanitized path)

Durability contract (PR 8):

* **Atomic publish.** Both files are written to a same-directory temp
  name and ``os.replace``d into place — a reader never observes a
  partially written file. The payload lands first and the manifest last,
  so the manifest acts as the commit marker: a crash between the two
  renames leaves the *previous* manifest paired with a new payload,
  which the CRC check below rejects rather than half-loads.
* **Integrity.** The manifest records ``payload_bytes`` and a CRC-32 of
  the payload; :func:`load_pytree` and :func:`verify_payload` re-hash
  before deserializing and raise :class:`CheckpointCorruption` on any
  mismatch (bit flip, truncation, torn write). Manifests written before
  this contract (no ``crc32`` key) still load, unverified.

Restore is sharding-aware: pass ``shardings`` (a matching pytree of
NamedSharding / PartitionSpec under a mesh context) to place leaves as they
load — sufficient for single-host multi-device; a multi-host variant would
stream per-shard files, noted in DESIGN.md.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_PAYLOAD = "arrays.npz"
_MANIFEST = "manifest.json"


class CheckpointCorruption(RuntimeError):
    """The checkpoint on disk fails its integrity contract (CRC or size
    mismatch, unreadable payload, missing files). Callers that keep a
    last-good checkpoint should catch this and roll back to it."""


def _keys(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(p) for p, _ in flat]
    return flat, treedef, names


_tmp_seq = itertools.count()


def _write_atomic(path: str, data: bytes) -> None:
    """Same-directory temp write + ``os.replace`` (atomic on POSIX).

    The temp name is unique per process, thread AND call, so concurrent
    writers never tear each other's staging file — each rename publishes
    one writer's complete bytes (last rename wins)."""
    tmp = (f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
           f".{next(_tmp_seq)}")
    try:
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def save_pytree(tree: Any, directory: str, *, step: Optional[int] = None,
                meta: Optional[dict] = None) -> str:
    """``meta`` is an optional JSON-serializable side channel stored in the
    manifest (read back via :func:`read_meta`) — for the non-array context
    a checkpoint consumer needs to rebuild itself (e.g. the per-lambda
    telemetry of a persisted regularization path)."""
    import io

    os.makedirs(directory, exist_ok=True)
    flat, _, names = _keys(tree)
    arrays = {}
    manifest = {"leaves": [], "step": step}
    if meta is not None:
        manifest["meta"] = meta
    for name, (_, leaf) in zip(names, flat):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # npz cannot serialize ml_dtypes
            arr = arr.astype(np.float32)
        key = f"leaf_{len(arrays)}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": name, "key": key, "dtype": dtype_name, "shape": list(arr.shape)}
        )
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    manifest["payload_bytes"] = len(payload)
    manifest["crc32"] = zlib.crc32(payload)
    # payload first, manifest last: the manifest rename is the commit.
    _write_atomic(os.path.join(directory, _PAYLOAD), payload)
    _write_atomic(os.path.join(directory, _MANIFEST),
                  json.dumps(manifest, indent=1).encode())
    return directory


def _read_manifest(directory: str) -> dict:
    path = os.path.join(directory, _MANIFEST)
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise CheckpointCorruption(f"missing manifest: {path}")
    except json.JSONDecodeError as err:
        raise CheckpointCorruption(f"unreadable manifest {path}: {err}")


def verify_payload(directory: str) -> bool:
    """Re-hash the payload against the manifest's CRC-32.

    Returns True when verified, False when the manifest predates the
    integrity contract (nothing to check against). Raises
    :class:`CheckpointCorruption` on size/CRC mismatch or a missing
    payload file.
    """
    manifest = _read_manifest(directory)
    if "crc32" not in manifest:
        return False
    path = os.path.join(directory, _PAYLOAD)
    try:
        with open(path, "rb") as f:
            payload = f.read()
    except FileNotFoundError:
        raise CheckpointCorruption(f"missing payload: {path}")
    if len(payload) != manifest.get("payload_bytes"):
        raise CheckpointCorruption(
            f"payload size mismatch in {directory}: "
            f"{len(payload)} bytes on disk vs "
            f"{manifest.get('payload_bytes')} in manifest (truncated write?)")
    crc = zlib.crc32(payload)
    if crc != manifest["crc32"]:
        raise CheckpointCorruption(
            f"payload CRC mismatch in {directory}: "
            f"{crc:#010x} on disk vs {manifest['crc32']:#010x} in manifest")
    return True


def read_meta(directory: str) -> Optional[dict]:
    """The ``meta`` dict stored by :func:`save_pytree`, or None."""
    return _read_manifest(directory).get("meta")


def load_pytree(directory: str, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (paths must match).

    Verifies payload integrity first (see :func:`verify_payload`); a
    damaged checkpoint raises :class:`CheckpointCorruption` before any
    array is deserialized.
    """
    manifest = _read_manifest(directory)
    verify_payload(directory)
    try:
        data = np.load(os.path.join(directory, _PAYLOAD))
    except (OSError, ValueError) as err:
        raise CheckpointCorruption(
            f"unreadable payload in {directory}: {err}")
    by_path = {e["path"]: data[e["key"]] for e in manifest["leaves"]}

    flat, treedef, names = _keys(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec") or hasattr(x, "_partitions")
        )[0]
    leaves = []
    for i, (name, (_, leaf)) in enumerate(zip(names, flat)):
        if name not in by_path:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_path[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {name}: {arr.shape} vs {leaf.shape}")
        out = jnp.asarray(arr, dtype=leaf.dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            out = jax.device_put(out, shard_flat[i])
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)
