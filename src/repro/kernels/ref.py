"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objective import P_EPS, W_MIN
from repro.core.subproblem import DOM_TOL, cd_cycle_blocked_tile, cd_cycle_gram_tile


def gram_cd_ref(G, c, beta, dbeta0, lam, nu):
    """Oracle for kernels.gram_cd: the core solver's own sequential cycle."""
    return cd_cycle_gram_tile(
        G.astype(jnp.float32), c.astype(jnp.float32),
        beta.astype(jnp.float32), dbeta0.astype(jnp.float32),
        lam, nu,
    )


def blocked_cd_ref(G, c, beta, dbeta0, lam, nu, *, block=16,
                   dom_tol=DOM_TOL):
    """Oracle for kernels.blocked_cd: the core solver's own blocked cycle
    (which is itself bit-identical to the sequential chain at block=1)."""
    return cd_cycle_blocked_tile(
        G.astype(jnp.float32), c.astype(jnp.float32),
        beta.astype(jnp.float32), dbeta0.astype(jnp.float32),
        lam, nu, block=block, dom_tol=dom_tol,
    )


def logistic_stats_ref(m, y):
    """Oracle for kernels.logistic_stats."""
    m = m.astype(jnp.float32)
    y = y.astype(jnp.float32)
    p = jax.nn.sigmoid(m)
    p = jnp.clip(p, P_EPS, 1.0 - P_EPS)
    w = jnp.maximum(p * (1.0 - p), W_MIN)
    z = ((y + 1.0) * 0.5 - p) / w
    nll = jnp.sum(jax.nn.softplus(-y * m))
    return w, z, nll


def _densify_slab(rows, vals, n_loc: int):
    """Slab (T, K) -> dense (n_loc, T) via the scatter the kernels kill.
    Sentinel slots (row >= n_loc) land in the swallow row and are dropped;
    duplicate rows within a feature sum, defining the oracle semantics the
    sparse kernels must match."""
    t, k = rows.shape
    out = jnp.zeros((n_loc + 1, t), jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(t)[:, None], rows.shape)
    safe = jnp.minimum(rows, n_loc)
    out = out.at[safe.reshape(-1), cols.reshape(-1)].add(
        jnp.where(rows < n_loc, vals, 0.0).reshape(-1).astype(jnp.float32))
    return out[:n_loc]


def slab_gram_ref(rows, vals, w, r):
    """Oracle for kernels.slab_gram: densify, then the dense weighted Gram
    G = X_F^T diag(w) X_F and correlation c = X_F^T (w r)."""
    xf = _densify_slab(rows, vals, w.shape[0])
    wxf = w.astype(jnp.float32)[:, None] * xf
    return xf.T @ wxf, wxf.T @ r.astype(jnp.float32)


def slab_spmv_ref(rows, vals, d, n_loc: int):
    """Oracle for kernels.slab_spmv: densify, then X_F @ d."""
    return _densify_slab(rows, vals, n_loc) @ d.astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True):
    """Oracle for kernels.flash_attention: plain softmax attention."""
    b, s, h, d = q.shape
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32)).astype(q.dtype)
