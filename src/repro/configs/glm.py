"""The paper's own workload as first-class configs: synthetic twins of the
three Pascal Large Scale Learning Challenge datasets (paper Table 2).

Full dims are exercised by the dry-run only (ShapeDtypeStruct); CPU
experiments use the ``twin()`` reductions, which preserve n:p aspect and
density so Figure-1-style curves are qualitatively comparable.
"""
from dataclasses import replace

from repro.configs.base import GLMConfig

# dataset         size   #examples(train/test)  #features   nnz      avg nnz
# epsilon         12 Gb  0.4e6 / 0.1e6          2000        8.0e8    2000 (dense)
# webspam         21 Gb  0.315e6 / 0.035e6      16.6e6      1.2e9    3727
# dna             71 Gb  45e6 / 5e6             800         9.0e9    200
GLM_EPSILON = GLMConfig(
    name="glm-epsilon",
    citation="Trofimov & Genkin 2014, Table 2 (epsilon, Pascal LSLC 2008)",
    num_examples=400_000,
    num_features=2000,
    avg_nnz_per_example=2000,
    density=1.0,
)

GLM_WEBSPAM = GLMConfig(
    name="glm-webspam",
    citation="Trofimov & Genkin 2014, Table 2 (webspam)",
    num_examples=315_000,
    num_features=16_600_000,
    avg_nnz_per_example=3727,
    density=3727 / 16_600_000,
)

GLM_DNA = GLMConfig(
    name="glm-dna",
    citation="Trofimov & Genkin 2014, Table 2 (dna)",
    num_examples=45_000_000,
    num_features=800,
    avg_nnz_per_example=200,
    density=0.25,
)

GLM_CONFIGS = {c.name: c for c in (GLM_EPSILON, GLM_WEBSPAM, GLM_DNA)}


def twin(cfg: GLMConfig, scale: float = 0.01) -> GLMConfig:
    """CPU-scale synthetic twin preserving aspect/density."""
    n = max(1024, int(cfg.num_examples * scale))
    p = max(64, min(cfg.num_features, int(cfg.num_features * max(scale, 1e-3))))
    return replace(cfg, name=cfg.name + "-twin", num_examples=n, num_features=p)
