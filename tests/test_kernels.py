"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ops import gram_cd, logistic_stats
from repro.kernels.ref import (
    gram_cd_ref,
    logistic_stats_ref,
    slab_gram_ref,
    slab_spmv_ref,
)
from repro.kernels.sparse_slab import slab_gram_pallas, slab_spmv_pallas


def make_slab(t, k, n_loc, seed, *, duplicates=False, empty_every=0,
              adversarial_pad=False):
    """Ragged random slab: per-feature nnz in [1, k], sorted local rows,
    sentinel padding; optionally duplicate rows within a feature, fully
    empty features, and garbage values parked on sentinel slots."""
    rng = np.random.default_rng(seed)
    rows = np.full((t, k), n_loc, np.int32)
    vals = np.zeros((t, k), np.float32)
    for f in range(t):
        if empty_every and f % empty_every == 0:
            continue
        kk = int(rng.integers(1, k + 1))
        rr = rng.integers(0, n_loc, size=kk)
        if not duplicates:
            rr = np.unique(rr)
            kk = len(rr)
        rows[f, :kk] = np.sort(rr)
        vals[f, :kk] = rng.standard_normal(kk)
    if adversarial_pad:
        vals[rows >= n_loc] = 99.0   # must contribute exactly zero anyway
    return jnp.asarray(rows), jnp.asarray(vals)


@pytest.mark.parametrize("f", [8, 32, 128, 256, 512])
@pytest.mark.parametrize("lam", [0.0, 0.3, 10.0])
def test_gram_cd_sweep(f, lam):
    key = jax.random.key(f * 1000 + int(lam * 10))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    A = jax.random.normal(k1, (2 * f, f))
    G = A.T @ A / f
    c = 3.0 * jax.random.normal(k2, (f,))
    beta = 0.5 * jax.random.normal(k3, (f,))
    db0 = 0.1 * jax.random.normal(k4, (f,))
    d_kernel = gram_cd(G, c, beta, db0, lam)
    d_ref = gram_cd_ref(G, c, beta, db0, lam, 1e-6)
    np.testing.assert_allclose(d_kernel, d_ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_cd_dtypes(dtype):
    key = jax.random.key(7)
    k1, k2 = jax.random.split(key)
    f = 64
    A = jax.random.normal(k1, (2 * f, f), dtype)
    G = (A.T @ A / f)
    c = jax.random.normal(k2, (f,), dtype)
    beta = jnp.zeros(f, dtype)
    db0 = jnp.zeros(f, dtype)
    d_kernel = gram_cd(G, c, beta, db0, 0.1)
    d_ref = gram_cd_ref(G, c, beta, db0, 0.1, 1e-6)
    np.testing.assert_allclose(
        np.asarray(d_kernel, np.float32), np.asarray(d_ref, np.float32),
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-5, rtol=1e-2)


def test_gram_cd_soft_threshold_zeroing():
    """Huge lambda -> every coordinate driven to -(beta+dbeta0) (exact zero
    of the total coefficient)."""
    f = 32
    G = jnp.eye(f)
    c = jnp.zeros(f)
    beta = jnp.linspace(-1, 1, f)
    db0 = jnp.zeros(f)
    d = gram_cd(G, c, beta, db0, 1e6)
    np.testing.assert_allclose(beta + db0 + d, np.zeros(f), atol=1e-6)


@pytest.mark.parametrize("n,block", [(64, 32), (1000, 256), (8192, 1024),
                                     (5000, 4096)])
def test_logistic_stats_sweep(n, block):
    from repro.kernels.logistic_stats import logistic_stats_pallas

    key = jax.random.key(n)
    k1, k2 = jax.random.split(key)
    m = 4.0 * jax.random.normal(k1, (n,))
    y = jnp.sign(jax.random.normal(k2, (n,)))
    w2, z2, nll2 = logistic_stats_ref(m, y)
    # the dispatch wrapper (fused jnp on CPU) and the Pallas kernel
    # (interpret mode) must both match the oracle
    for w1, z1, nll1 in (logistic_stats(m, y, block=block),
                         logistic_stats_pallas(m, y, block=block,
                                               interpret=True)):
        np.testing.assert_allclose(w1, w2, rtol=1e-6)
        np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(nll1, nll2, rtol=1e-5)


def test_logistic_stats_extreme_margins():
    """Clamps keep w/z finite at |m| up to 80 (exp overflow territory)."""
    m = jnp.array([-80.0, -10.0, 0.0, 10.0, 80.0])
    y = jnp.array([1.0, -1.0, 1.0, 1.0, -1.0])
    w, z, nll = logistic_stats(m, y, block=8)
    assert np.isfinite(np.asarray(w)).all()
    assert np.isfinite(np.asarray(z)).all()
    assert np.isfinite(float(nll))


# ---------------------------------------------------------------------------
# sparse slab suite
# ---------------------------------------------------------------------------

# non-128-multiple tiles, ragged nnz, duplicates, empty features, and a
# local example count smaller than the slab capacity all included
SLAB_CASES = [
    dict(t=8, k=4, n_loc=16, seed=0),
    dict(t=24, k=5, n_loc=40, seed=1, duplicates=True),
    dict(t=128, k=8, n_loc=256, seed=2, duplicates=True, empty_every=5),
    dict(t=16, k=6, n_loc=7, seed=3, duplicates=True, empty_every=4),
    dict(t=48, k=3, n_loc=100, seed=4, adversarial_pad=True),
]


@pytest.mark.parametrize("case", SLAB_CASES)
def test_slab_gram_dispatch_matches_ref(case):
    rows, vals = make_slab(**case)
    n_loc = case["n_loc"]
    key = jax.random.key(case["seed"])
    w = jnp.abs(jax.random.normal(key, (n_loc,))) * 0.2 + 0.01
    r = jax.random.normal(jax.random.fold_in(key, 1), (n_loc,))
    G_ref, c_ref = slab_gram_ref(rows, vals, w, r)
    G, c = ops.slab_gram(rows, vals, w, r)
    np.testing.assert_allclose(G, G_ref, atol=1e-4)
    np.testing.assert_allclose(c, c_ref, atol=1e-4)


@pytest.mark.parametrize("case", SLAB_CASES)
def test_slab_gram_pallas_matches_ref(case):
    rows, vals = make_slab(**case)
    n_loc = case["n_loc"]
    key = jax.random.key(case["seed"] + 100)
    w = jnp.abs(jax.random.normal(key, (n_loc,))) * 0.2 + 0.01
    r = jax.random.normal(jax.random.fold_in(key, 1), (n_loc,))
    G_ref, c_ref = slab_gram_ref(rows, vals, w, r)
    safe, va, wv, cva = ops._sentinel_zeroed(rows, vals, w, r, n_loc)
    G, c = slab_gram_pallas(safe, wv, va, cva, interpret=True)
    np.testing.assert_allclose(G, G_ref, atol=1e-4)
    np.testing.assert_allclose(c, c_ref, atol=1e-4)


@pytest.mark.parametrize("case", SLAB_CASES)
@pytest.mark.parametrize("block", [8, 64])
def test_slab_spmv_matches_ref(case, block):
    rows, vals = make_slab(**case)
    n_loc = case["n_loc"]
    d = jax.random.normal(jax.random.key(case["seed"] + 7), (case["t"],))
    out_ref = slab_spmv_ref(rows, vals, d, n_loc)
    out = ops.slab_spmv(rows, vals, d, n_loc=n_loc)
    np.testing.assert_allclose(out, out_ref, atol=1e-4)
    dv = jnp.where(rows < n_loc, vals, 0.0) * d[:, None]
    out_p = slab_spmv_pallas(jnp.minimum(rows, n_loc), dv, n_loc=n_loc,
                             block=block, interpret=True)
    np.testing.assert_allclose(out_p, out_ref, atol=1e-4)


@pytest.mark.parametrize("case", SLAB_CASES)
def test_slab_corr_matches_ref(case):
    rows, vals = make_slab(**case)
    n_loc = case["n_loc"]
    v = jax.random.normal(jax.random.key(case["seed"] + 13), (n_loc,))
    # X^T v == slab_gram's c with w = 1, r = v
    _, c_ref = slab_gram_ref(rows, vals, jnp.ones(n_loc), v)
    np.testing.assert_allclose(ops.slab_corr(rows, vals, v), c_ref,
                               atol=1e-4)


def test_slab_sentinel_ghost_weight_regression():
    """Sentinel slots must contribute *exactly* zero to G/c/SpMV for every
    slab capacity — including all-padding (empty-feature) slabs. A clamped
    gather without the validity mask would silently add row ``n_loc - 1``'s
    (or, with a one-row pad, row ``n_loc``'s) weight for every padding
    slot; park large values on the padding to make any leak visible."""
    n_loc = 6
    w = jnp.arange(1.0, n_loc + 1)          # distinctive per-row weights
    r = jnp.arange(1.0, n_loc + 1) * 10.0
    for k in (1, 2, 5, 9):                   # several capacity classes
        rows = jnp.full((4, k), n_loc, jnp.int32)   # all-padding slab
        vals = jnp.full((4, k), 123.0)               # adversarial values
        G, c = ops.slab_gram(rows, vals, w, r)
        assert float(jnp.abs(G).max()) == 0.0, k
        assert float(jnp.abs(c).max()) == 0.0, k
        out = ops.slab_spmv(rows, vals, jnp.ones(4), n_loc=n_loc)
        assert float(jnp.abs(out).max()) == 0.0, k
        assert float(jnp.abs(ops.slab_corr(rows, vals, r)).max()) == 0.0, k
    # mixed live/padding: the padded tail of a live feature leaks nothing
    rows = jnp.asarray([[2, n_loc, n_loc]], jnp.int32)
    vals = jnp.asarray([[1.5, 50.0, -50.0]])
    G, c = ops.slab_gram(rows, vals, w, r)
    np.testing.assert_allclose(G, jnp.asarray([[w[2] * 1.5 * 1.5]]),
                               rtol=1e-6)
    np.testing.assert_allclose(c, jnp.asarray([w[2] * r[2] * 1.5]),
                               rtol=1e-6)


def test_backend_probe_cached():
    """The backend probe must be evaluated at most once per process (it
    used to re-query jax.default_backend() inside traced loops)."""
    ops._on_tpu.cache_clear()
    ops.interpret_default.cache_clear()
    ops.interpret_default()
    ops.interpret_default()
    assert ops.interpret_default.cache_info().misses == 1
    assert ops._on_tpu.cache_info().misses <= 1


@pytest.mark.parametrize("shape,blocks", [
    ((1, 256, 2, 64), (128, 128)),
    ((2, 512, 4, 32), (128, 64)),
    ((1, 128, 1, 128), (64, 128)),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, blocks, causal):
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    b, s, h, d = shape
    bq, bk = blocks
    key = jax.random.key(b * s + d)
    q = jax.random.normal(key, shape)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape)
    o1 = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    o2 = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    key = jax.random.key(11)
    shape = (1, 256, 2, 64)
    q = jax.random.normal(key, shape, dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape, dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape, dtype)
    o1 = flash_attention(q, k, v, block_q=128, block_k=128)
    o2 = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)
