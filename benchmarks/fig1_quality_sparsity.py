"""Figure 1: testing quality (AUPRC) vs #nonzeros — d-GLMNET regularization
path vs distributed online learning via truncated gradient (best over a VW-
style parameter sweep, evaluating every pass snapshot, as in paper §4.3)."""
from __future__ import annotations

import jax

from benchmarks.common import TWINS, Timer, emit, load_twin
from repro.core import DGLMNETOptions, TGOptions, lambda_max, regularization_path
from repro.core.truncated_gradient import truncated_gradient_fit
from repro.train.metrics import auprc, glm_eval_fn

PATH_LEN = 10
TG_LRS = (0.1, 0.3, 0.5)
TG_PASSES = 8


def run(verbose: bool = True):
    rows = []
    for name in TWINS:
        ds = load_twin(name)
        X, y = ds.X_train, ds.y_train
        eval_fn = glm_eval_fn(ds.X_test, ds.y_test)

        with Timer() as t_d:
            pts = regularization_path(
                X, y, path_len=PATH_LEN,
                opts=DGLMNETOptions(num_blocks=16, tile=64, max_iters=50),
                eval_fn=eval_fn)
            t_d.block = pts.betas
        for p in pts:
            rows.append((name, "d-glmnet", f"{p.lam:.4g}", p.nnz,
                         p.metrics["auprc"]))

        with Timer() as t_tg:
            lmax = float(lambda_max(X, y))
            for lam_div in (16, 64, 256):
                for lr in TG_LRS:
                    snaps = truncated_gradient_fit(
                        X, y, lmax / lam_div,
                        opts=TGOptions(num_machines=16, passes=TG_PASSES,
                                       learning_rate=lr),
                        key=jax.random.key(0))
                    for pass_idx, beta in snaps:
                        import jax.numpy as jnp

                        nnz = int((jnp.abs(beta) > 1e-8).sum())
                        rows.append((name, f"tg(lr={lr})",
                                     f"{lmax/lam_div:.4g}@p{pass_idx}", nnz,
                                     auprc(ds.X_test @ beta, ds.y_test)))

        best_d = max(r[4] for r in rows if r[0] == name and r[1] == "d-glmnet")
        best_t = max(r[4] for r in rows if r[0] == name and r[1].startswith("tg"))
        emit(f"fig1.{name}.dglmnet_path", t_d.dt * 1e6 / PATH_LEN,
             f"best_auprc={best_d:.4f}")
        emit(f"fig1.{name}.tg_sweep", t_tg.dt * 1e6 / (9 * TG_PASSES),
             f"best_auprc={best_t:.4f};dglmnet_wins={best_d >= best_t - 0.02}")
        if verbose:
            print(f"# {name}: d-GLMNET best AUPRC {best_d:.4f} "
                  f"vs TG best {best_t:.4f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(c) for c in r))
