"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].

Vision frontend is a STUB per the assignment carve-out: input_specs()
provides precomputed patch embeddings (ViT output, 1280-dim) and the
framework owns only the projector + language decoder.
"""
from repro.configs.base import AttentionConfig, FrontendStub, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    arch_type="vlm",
    citation="arXiv:2409.12191 (Qwen2-VL)",
    num_layers=80,
    d_model=8192,
    d_ff=29568,
    vocab_size=152064,
    attention=AttentionConfig(
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        use_mrope=True,
        mrope_sections=(16, 24, 24),   # (temporal, height, width) rotary sections
    ),
    frontend=FrontendStub(
        kind="vision_patches",
        tokens_per_item=1024,          # dynamic-resolution: nominal patch budget
        embed_dim=1280,                # ViT output dim; projector -> d_model
    ),
    norm="rmsnorm",
    act="silu",
    microbatch=8,
    optimizer="adafactor",
    long_context_mode="sliding_window",
)
