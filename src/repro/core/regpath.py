"""Regularization path (paper Algorithm 5) — legacy shims.

The warm-started, screened path engine now lives behind the one front
door, ``repro.api.LogisticL1.path``: a layout-agnostic strong-rule/KKT
driver over the :class:`~repro.api.design.Design` protocol (dense, slab,
bucketed, mesh-sharded), with capacity-bucketed restricted solves,
blitz-style working-set carry and per-lambda metric streaming. Both
functions here delegate to it — they exist so the historical signatures
(`regularization_path(X, y, ...)`,
`regularization_path_distributed(data, y, mesh, ...)`) keep working, and
are tested bit-identical against the front door.

``regularization_path_distributed`` accepts every historical operand: a
dense (n, p) X, a :class:`~repro.data.byfeature.ByFeature`, a raw
``(row_idx, values)`` slab pair of shape (p, DP, K) with local row
indices, or an nnz-bucketed :class:`~repro.data.byfeature.SlabBuckets`
layout — ``repro.api.as_design`` performs the coercion (including the
front-packing detection that gates the slab K-capacity trim).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core.dglmnet import DGLMNETOptions

# re-export: PathPoint/PathResult moved to repro.api with the path engine
from repro.api.types import PathPoint, PathResult  # noqa: F401


def regularization_path(
    X,
    y,
    *,
    path_len: int = 20,
    opts: DGLMNETOptions = DGLMNETOptions(),
    eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
    extra_lams: Optional[List[float]] = None,
    verbose: bool = False,
    screen: bool = True,
    kkt_tol: float = 1e-3,
    max_kkt_rounds: int = 8,
    carry_working_set: bool = True,
    violation_budget: Optional[int] = 512,
) -> PathResult:
    """Single-process path: one PathPoint per lambda (decreasing),
    returned as a :class:`PathResult` (stacked betas; iterates and indexes
    like the historical list of points).
    ``eval_fn(beta)`` computes test metrics (e.g. AUPRC) per point — the
    paper's Figure 1. ``screen=False`` reproduces the seed's full-p
    warm-started loop (the oracle the screening tests compare against).

    Legacy shim over ``LogisticL1(opts).path(DenseDesign(X), y, ...)``.
    """
    from repro.api import DenseDesign, LogisticL1

    return LogisticL1(opts=opts).path(
        DenseDesign(X), y, path_len=path_len, eval_fn=eval_fn,
        extra_lams=extra_lams, verbose=verbose, screen=screen,
        kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
        carry_working_set=carry_working_set,
        violation_budget=violation_budget,
    )


def regularization_path_distributed(
    data,
    y,
    mesh,
    *,
    path_len: int = 20,
    opts: DGLMNETOptions = DGLMNETOptions(),
    eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
    extra_lams: Optional[List[float]] = None,
    verbose: bool = False,
    kkt_tol: float = 1e-3,
    max_kkt_rounds: int = 8,
    carry_working_set: bool = True,
    violation_budget: Optional[int] = 512,
) -> PathResult:
    """The screened path with every restricted solve on the mesh
    (Algorithm 5 run distributed — the paper's webspam-scale regime). In
    the sparse forms the strong-rule/KKT gradient passes stream the slabs
    under shard_map and the active-set gather/scatter operates on slabs,
    so no dense (n, p) X is ever materialized on host; restricted solves
    additionally trim the slab capacity axis to the working set's own
    power-of-two K class.

    Legacy shim over ``LogisticL1(opts).path(as_design(data, mesh=...))``.
    """
    from repro.api import LogisticL1, as_design

    design = as_design(data, n=int(y.shape[0]), mesh=mesh, tile=opts.tile)
    return LogisticL1(opts=opts).path(
        design, y, path_len=path_len, eval_fn=eval_fn,
        extra_lams=extra_lams, verbose=verbose, screen=True,
        kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
        carry_working_set=carry_working_set,
        violation_budget=violation_budget,
    )
