"""repro.analysis — invariant lint pass + runtime sanitizers.

Static side (``python -m repro.analysis src tests benchmarks``): AST
rules encoding this repo's hard-won invariants — the sharded-concat
single-home guard, psum-axis discipline, host-sync-in-jit, retrace
hazards, bench-timing sync, Pallas kernel conventions, and the dead-code
inventory. See ``repro.analysis.rules`` and README "Static analysis &
sanitizers".

Runtime side (``repro.analysis.sanitize``): a transfer sanitizer pinning
the engine's one-``device_get``-per-solve contract and a compile-counter
budget certifying the warm-started path retraces zero times per lambda.
The static import surface of this package is deliberately JAX-free so
the lint lane runs anywhere; ``sanitize`` imports JAX lazily.
"""
from repro.analysis.findings import Finding  # noqa: F401
from repro.analysis.runner import Report, run_analysis  # noqa: F401
