"""repro.serve — batched online scoring of the certified reg path.

The d-GLMNET training side hands over a typed ``PathResult`` (the whole
certified regularization path); this package serves it:

* :class:`PathStore` — the ``(L, p)`` coefficient stack device-resident
  (replicated locally, P(model)-feature-sharded on a mesh), versioned,
  hot-swappable without dropping in-flight batches;
* :mod:`~repro.serve.ingest` — deterministic hashed sparse-feature
  ingestion packing request batches into the training kernels' by-feature
  slab layout;
* :class:`RequestBatcher` — accumulate/drain batching with power-of-two
  shape classes, a bounded pending queue (:class:`Overloaded` admission
  control) and per-request deadlines shed at drain;
* :class:`PathScorer` — one jitted ``slab_path_spmv`` dispatch per batch,
  each request row picking its own lambda operating point on device;
  scores bit-identical to ``LogisticL1.decision_function``. Non-finite
  scores quarantine the published version and pin the store back to its
  last-good snapshot (:class:`NonFiniteScores` only if that fails too).

Typed failure surface: :class:`~repro.serve.ingest.InvalidRequest`
(garbage in), :class:`Overloaded` (queue full), :class:`NonFiniteScores`
(poisoned coefficients) — the serve loop counts each instead of dying.

Entry points: ``python -m repro.launch.serve_glm`` (serving),
``python -m repro.launch.chaos_glm`` (fault drills).
"""
from repro.serve.batcher import (  # noqa: F401
    Overloaded,
    RequestBatcher,
    batch_capacity,
)
from repro.serve.ingest import (  # noqa: F401
    InvalidRequest,
    PackedBatch,
    encode_request,
    hash_token,
    k_capacity,
    pack_requests,
)
from repro.serve.scoring import (  # noqa: F401
    NonFiniteScores,
    PathScorer,
    make_path_margins,
)
from repro.serve.store import PathStore, StoreSnapshot  # noqa: F401
