"""Rule registry. Each rule module defines ``RULE_ID``, ``DOC`` and
``check(project) -> Iterable[Finding]``."""
from __future__ import annotations

from repro.analysis.rules import (
    bench_timing,
    bucket_residency,
    dead_code,
    host_sync,
    metric_discipline,
    nonfinite_guard,
    pallas,
    psum_axis,
    retrace,
    sharded_concat,
)

ALL_RULES = (
    sharded_concat,
    psum_axis,
    host_sync,
    retrace,
    bench_timing,
    pallas,
    dead_code,
    nonfinite_guard,
    bucket_residency,
    metric_discipline,
)

RULES_BY_ID = {r.RULE_ID: r for r in ALL_RULES}
