"""Regularization path (paper Algorithm 5) — warm-started, screened engine.

Find lambda_max for which beta = 0, then solve with
lambda = lambda_max * 2^{-i}, i = 1..path_len, warm-starting each solve from
the previous beta.

Beyond the seed's loop-of-fits, the engine exploits the two pieces of
path-level structure the follow-up literature (Mahajan et al. 1405.4544,
Trofimov & Genkin 1611.02101) identifies as decisive for distributed L1:

* **One compiled program for the whole path** — lam is a traced operand of
  the device-resident solver (core/engine.py), so consecutive lambdas reuse
  the same jitted while_loop; restricted problems are bucketed to
  power-of-two capacities so at most O(log(p/tile)) shapes ever compile.
* **Sequential-strong-rule screening with a KKT post-check**
  (core/screening.py) — each solve only pays for the features the strong
  rule admits at that lambda (plus warm-start support); the discarded set
  is certified optimal afterwards via the full-gradient KKT condition, and
  violators (rare) re-enter and re-solve. Large-p path points cost
  O(active) instead of O(p).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core.dglmnet import DGLMNETOptions, FitResult, fit
from repro.core.objective import lambda_max, margins, objective
from repro.core.screening import (
    capacity_bucket,
    gather_columns,
    kkt_violations,
    nll_grad_abs,
    scatter_columns,
    strong_rule_mask,
)


@dataclass
class PathPoint:
    lam: float
    nnz: int
    f: float
    n_iters: int
    beta: jnp.ndarray
    metrics: dict = field(default_factory=dict)
    screen: dict = field(default_factory=dict)   # active-set telemetry


def _fit_screened(X, y, lam, lam_prev, beta, m, opts, *, kkt_tol, max_kkt_rounds):
    """One path point: strong-rule restricted solve + KKT certification.

    Returns (res, beta_full, m_full, info). Only the active-set and
    violation *counts* are synced to host (to pick the capacity bucket and
    decide termination) — the solves themselves stay device-resident.
    """
    n, p = X.shape
    g_abs = nll_grad_abs(X, y, m)                 # gradient at the warm start
    mask = strong_rule_mask(g_abs, lam, lam_prev, beta)

    res = None
    rounds = 0
    cap = 0
    for rounds in range(1, max_kkt_rounds + 1):
        count = int(mask.sum())
        if count == 0:
            # empty working set: beta stays 0 (strong rule + no support)
            beta_new, m_new = beta, m
            res = FitResult(beta=beta, f=float("nan"), n_iters=0,
                            objective_history=[], alpha_history=[])
        else:
            cap = capacity_bucket(count, p, tile=opts.tile)
            X_sub, beta_sub, idx = gather_columns(X, beta, mask, cap)
            res = fit(X_sub, y, lam, beta0=beta_sub, opts=opts)
            beta_new = scatter_columns(res.beta, idx, p)
            m_new = X_sub @ res.beta              # == X @ beta_new (pads are 0)
        g_abs = nll_grad_abs(X, y, m_new)
        viol = kkt_violations(g_abs, lam, mask, tol=kkt_tol)
        n_viol = int(viol.sum())
        if n_viol == 0:
            break
        mask = jnp.logical_or(mask, viol)         # violators re-enter
        beta, m = beta_new, m_new                 # keep this round's progress
    else:
        raise RuntimeError(
            f"KKT check failed to certify within {max_kkt_rounds} rounds "
            f"at lambda={lam} (last violation count > 0)"
        )

    info = {"active": int(mask.sum()), "capacity": cap, "kkt_rounds": rounds}
    return res, beta_new, m_new, info


def regularization_path(
    X,
    y,
    *,
    path_len: int = 20,
    opts: DGLMNETOptions = DGLMNETOptions(),
    eval_fn: Optional[Callable[[jnp.ndarray], dict]] = None,
    extra_lams: Optional[List[float]] = None,
    verbose: bool = False,
    screen: bool = True,
    kkt_tol: float = 1e-3,
    max_kkt_rounds: int = 8,
) -> List[PathPoint]:
    """Returns one PathPoint per lambda (decreasing). ``eval_fn(beta)``
    computes test metrics (e.g. AUPRC) per point — the paper's Figure 1.

    ``screen=True`` (default) runs the strong-rule/KKT engine; ``False``
    reproduces the seed's full-p warm-started loop (the oracle the
    screening tests compare against).
    """
    lmax = float(lambda_max(X, y))
    lams = [lmax * 2.0 ** (-i) for i in range(1, path_len + 1)]
    if extra_lams:
        lams = sorted(set(lams) | set(extra_lams), reverse=True)

    n, p = X.shape
    beta = jnp.zeros(p, jnp.float32)
    m = jnp.zeros(n, jnp.float32)
    lam_prev = lmax
    points: List[PathPoint] = []
    for lam in lams:
        if screen:
            res, beta, m, info = _fit_screened(
                X, y, lam, lam_prev, beta, m, opts,
                kkt_tol=kkt_tol, max_kkt_rounds=max_kkt_rounds,
            )
        else:
            res = fit(X, y, lam, beta0=beta, opts=opts)
            beta = res.beta
            m = margins(X, beta)
            info = {}
        lam_prev = lam
        nnz = int(jnp.sum(jnp.abs(beta) > 0))
        f = float(res.f) if res.n_iters else float(objective(m, y, beta, lam))
        metrics = eval_fn(beta) if eval_fn else {}
        points.append(
            PathPoint(lam=lam, nnz=nnz, f=f, n_iters=res.n_iters,
                      beta=beta, metrics=metrics, screen=info)
        )
        if verbose:
            print(
                f"lambda={lam:10.4f} nnz={nnz:6d} f={points[-1].f:12.4f} "
                f"iters={res.n_iters:3d} {info} {metrics}"
            )
    return points
