"""Device-resident solver engine (core/engine.py): the jitted while_loop
outer loop must reproduce the seed's host-driven trajectory exactly and
perform no per-iteration host synchronization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DGLMNETOptions, fit, fit_python_loop, lambda_max
from repro.core import engine
from repro.core.dglmnet import _solver_for
from repro.core.objective import margins


@pytest.mark.parametrize("opts", [
    DGLMNETOptions(num_blocks=1, method="gram", tile=32, max_iters=60),
    DGLMNETOptions(num_blocks=4, method="gram", tile=32, max_iters=60),
    DGLMNETOptions(num_blocks=4, method="residual", max_iters=60),
])
def test_fit_matches_python_loop_trajectory(small_glm, opts):
    """Engine vs seed Python loop: same objective trajectory within 1e-5,
    same iteration count, same alphas (they run the same jitted math, just
    with the loop on device)."""
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 32

    ref = fit_python_loop(X, y, lam, opts=opts)
    eng = fit(X, y, lam, opts=opts)

    assert eng.n_iters == ref.n_iters
    assert eng.converged == ref.converged
    h_ref = np.asarray(ref.objective_history)
    h_eng = np.asarray(eng.objective_history)
    assert h_ref.shape == h_eng.shape
    np.testing.assert_allclose(h_eng, h_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(eng.alpha_history), np.asarray(ref.alpha_history),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(eng.beta), np.asarray(ref.beta), rtol=1e-4, atol=1e-5)
    assert eng.nnz == ref.nnz


def test_fit_warmstart_matches_python_loop(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 16
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=60)
    warm = fit(X, y, lam * 2, opts=opts).beta
    ref = fit_python_loop(X, y, lam, beta0=warm, opts=opts)
    eng = fit(X, y, lam, beta0=warm, opts=opts)
    np.testing.assert_allclose(
        np.asarray(eng.objective_history), np.asarray(ref.objective_history),
        rtol=1e-5)


def test_fit_single_host_transfer(small_glm, monkeypatch):
    """The whole solve performs exactly one device->host transfer (the
    final ``device_get`` of the solver state) — the seed synced the
    objective every outer iteration."""
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 32
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=60)
    fit(X, y, lam, opts=opts)  # warm the compile cache

    calls = []
    real = engine.device_get
    monkeypatch.setattr(engine, "device_get", lambda x: calls.append(1) or real(x))
    res = fit(X, y, lam, opts=opts)
    assert len(calls) == 1, f"expected 1 device_get per solve, saw {len(calls)}"
    assert res.n_iters > 1  # multiple outer iterations, still one transfer


def test_solver_outer_loop_is_single_while(small_glm):
    """The solver jaxpr is one program whose outer loop is a lax.while_loop
    — no per-iteration dispatch, no callbacks to host."""
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 32
    opts = DGLMNETOptions(num_blocks=2, tile=32, max_iters=10)
    beta = jnp.zeros(X.shape[1], jnp.float32)
    m = margins(X, beta)
    solve = _solver_for(opts)
    jaxpr = jax.make_jaxpr(solve)(X, y, beta, m, lam).jaxpr
    if [e.primitive.name for e in jaxpr.eqns] == ["pjit"]:
        jaxpr = jaxpr.eqns[0].params["jaxpr"].jaxpr  # descend into the jit
    prims = [eqn.primitive.name for eqn in jaxpr.eqns]
    assert prims.count("while") == 1, prims
    assert not any("callback" in p for p in prims), prims


def test_solver_reuses_compilation_across_lambdas(small_glm):
    """lam is a traced operand: a whole regularization path hits one
    compiled executable."""
    X, y = small_glm.X_train, small_glm.y_train
    lmax = float(lambda_max(X, y))
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=20)
    solve = _solver_for(opts)
    fit(X, y, lmax / 4, opts=opts)  # compile once
    misses0 = solve._cache_size()
    for div in (8, 16, 32, 64):
        fit(X, y, lmax / div, opts=opts)
    assert solve._cache_size() == misses0


def test_engine_respects_max_iters(small_glm):
    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 64
    res = fit(X, y, lam, opts=DGLMNETOptions(max_iters=3))
    assert res.n_iters <= 3
    assert len(res.objective_history) == res.n_iters + 1
    assert len(res.alpha_history) == res.n_iters


def test_snapback_epilogue_records_applied_step(small_glm):
    """The snap-back epilogue applies alpha=1; the reported telemetry must
    describe that applied step — alpha_history ends in 1.0, the snapped
    unit step is counted, and f_hist[-1] is the objective at the returned
    beta (engine and python-loop oracle agree on all of it)."""
    from repro.core.objective import objective

    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 64
    # a huge snap_tol forces the snap on the final step regardless of the
    # line search's alpha, so the pre-fix misreport is always exercised
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=4, snap_tol=10.0)
    eng = fit(X, y, lam, opts=opts)
    ref = fit_python_loop(X, y, lam, opts=opts)

    assert eng.alpha_history[-1] == 1.0
    assert ref.alpha_history[-1] == 1.0
    np.testing.assert_allclose(eng.alpha_history, ref.alpha_history,
                               rtol=1e-5, atol=1e-6)
    assert eng.unit_step_frac == ref.unit_step_frac
    # the applied final step is a unit step, so at least one was counted
    assert round(eng.unit_step_frac * eng.n_iters) >= 1
    f_at_beta = float(objective(margins(X, eng.beta), y, eng.beta, lam))
    np.testing.assert_allclose(eng.objective_history[-1], f_at_beta,
                               rtol=1e-5)


def test_make_step_matches_manual_iteration(small_glm):
    """engine.make_step == subproblem + line search + apply, one iteration."""
    from repro.core.dglmnet import _iteration
    from repro.core import line_search
    from repro.core.dglmnet import dglmnet_iteration

    X, y = small_glm.X_train, small_glm.y_train
    lam = float(lambda_max(X, y)) / 16
    opts = DGLMNETOptions(num_blocks=4, tile=32)
    beta = jnp.zeros(X.shape[1], jnp.float32)
    m = margins(X, beta)

    step = engine.make_step(
        lambda X, y, b, mm, l, w, z: _iteration(X, y, b, mm, l, opts, w, z))
    b1, m1, f1, a1 = step(X, y, beta, m, lam)

    dbeta, dm, gd = dglmnet_iteration(X, y, beta, m, lam, opts)
    res = line_search(m, dm, y, beta, dbeta, lam, gd)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(beta + res.alpha * dbeta),
                               atol=1e-6)
    np.testing.assert_allclose(float(f1), float(res.f_new), rtol=1e-6)
