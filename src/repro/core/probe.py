"""Sparse logistic probes: the paper's technique applied to the model zoo.

Freeze a backbone (any of the 10 assigned architectures), extract pooled
hidden features, and train an L1-regularized logistic readout with
d-GLMNET — feature blocks sharded exactly like the paper's S_m. This is the
modern deployment of the paper's problem class (n large, p = d_model).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.dglmnet import DGLMNETOptions, FitResult


def extract_features(params, cfg: ModelConfig, tokens: jnp.ndarray,
                     extra_inputs: Optional[dict] = None,
                     pool: str = "mean") -> jnp.ndarray:
    """(B, S) tokens -> (B, d_model) pooled pre-logit features."""
    inputs = {"tokens": tokens, **(extra_inputs or {})}
    hidden = _hidden_features(params, inputs, cfg)
    if pool == "mean":
        return hidden.mean(axis=1)
    if pool == "last":
        return hidden[:, -1, :]
    raise ValueError(pool)


def _hidden_features(params, inputs, cfg: ModelConfig):
    """Final-norm hidden states (B, S, D)."""
    if cfg.encdec.enabled:
        from repro.models.seq2seq import seq2seq_forward

        logits, _, _ = seq2seq_forward(params, inputs, cfg, mode="train")
        # enc-dec probe: use decoder logits pre-head is not exposed; use
        # logits projected back is lossy -> use encoder memory instead
        from repro.models.seq2seq import encode

        return encode(params, inputs["frame_embeds"], cfg)
    from repro.models import transformer as tr

    cdtype = tr.dtype_of(cfg.compute_dtype)
    tokens = inputs["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdtype)
    prefix = 0
    for key_name in ("patch_embeds", "frame_embeds"):
        if key_name in inputs and inputs[key_name] is not None:
            pe = inputs[key_name].astype(cdtype) @ params["frontend_proj"].astype(cdtype)
            x = jnp.concatenate([pe, x], axis=1)
            prefix = pe.shape[1]
            break
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None, :],
                                 (b, x.shape[1]))
    segs = tr.segments_of(cfg)
    shared = params.get("shared_attn")
    for i, (kind, n) in enumerate(segs):
        x, _, _ = tr._segment_forward(
            params["segments"][i], x, cfg=cfg, kind=kind, n=n,
            positions=positions, mode="train", seg_cache=None, cache_index=None,
            window=cfg.attention.sliding_window, window_slice=False,
            shared_block=shared, deterministic=True)
    from repro.models.layers import apply_norm

    h = apply_norm(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    return h[:, prefix:, :] if prefix else h


def train_sparse_probe(
    features: jnp.ndarray,          # (n, p) frozen backbone features
    labels: jnp.ndarray,            # (n,) in {-1, +1}
    *,
    lam: Optional[float] = None,
    opts: DGLMNETOptions = DGLMNETOptions(num_blocks=8, tile=32),
) -> FitResult:
    from repro.api import DenseDesign, LogisticL1, lambda_max_design

    design = DenseDesign(features.astype(jnp.float32))
    if lam is None:
        lam = float(lambda_max_design(design, labels)) / 64
    return LogisticL1(opts=opts).fit(design, labels, lam)


def probe_path(features, labels, *, path_len=10, opts=None, eval_fn=None):
    from repro.api import DenseDesign, LogisticL1

    opts = opts or DGLMNETOptions(num_blocks=8, tile=32)
    return LogisticL1(opts=opts).path(
        DenseDesign(features.astype(jnp.float32)), labels,
        path_len=path_len, eval_fn=eval_fn)
