"""Strategy resolution: the ONE place ``(design.layout, mesh,
DGLMNETOptions)`` maps to an execution plan.

Before this module, every capability (blocked cycles, slab kernels,
densify fallbacks, screening capacities) was threaded by hand through five
entry points; a new scenario meant a sixth. Now a solve is described by a
:class:`Strategy` — where it runs (local vs mesh), which subproblem family
serves it (dense MXU vs sparse-native slab kernels, with the
``prefer_slab_gram`` densify fallback), the resolved within-tile CD cycle,
and the feature-capacity quantum restricted solves are bucketed to — and
the resolver is the single audit point for all of it.

Validation lives at the same altitude: option bundles are rejected here
(and in ``DGLMNETOptions.__post_init__``) with actionable messages instead
of surfacing as deep shard_map shape errors mid-trace.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

from repro.api.design import Design, ShardedDesign
from repro.core.dglmnet import DGLMNETOptions


@dataclass(frozen=True)
class Strategy:
    """Resolved execution plan for one solve/path."""

    execution: str                  # "local" | "mesh"
    solver: str                     # "dense" | "slab"
    opts: DGLMNETOptions            # cycle_mode resolved to a concrete mode
    cap_tile: int                   # feature-capacity quantum (screened path)
    densify: Optional[bool] = None  # slab solver: force/forbid densify-once
    residency: str = "resident"     # "resident" | "streamed" (mesh slabs)

    def use_densify(self, n_loc: int, k: int) -> bool:
        """Per-solve densify decision for the slab solver: the explicit
        override wins, else the nnz-density heuristic
        (``kernels.prefer_slab_gram``) at the solve's concrete (n_loc, K).
        """
        if self.densify is not None:
            return self.densify
        from repro.kernels.ops import prefer_slab_gram

        return not prefer_slab_gram(n_loc, k)


def _resolve_cycle(opts: DGLMNETOptions) -> DGLMNETOptions:
    """``cycle_mode="auto"`` -> concrete mode (the ``prefer_blocked_cd``
    tile-size heuristic) + eager blocked-cycle shape validation. Shared by
    :func:`resolve` and :func:`mesh_programs` so live solves and dry-run
    lowering can never resolve differently."""
    cycle_mode = opts.cycle_mode
    if cycle_mode == "auto":
        from repro.kernels.ops import prefer_blocked_cd

        cycle_mode = ("blocked" if prefer_blocked_cd(opts.tile, opts.block)
                      else "sequential")
    if cycle_mode == "blocked" and opts.tile % opts.block:
        raise ValueError(
            f"blocked cycle needs block ({opts.block}) to divide tile "
            f"({opts.tile}) — pick block in {{1, 2, 4, ...}} <= tile"
        )
    if cycle_mode != opts.cycle_mode:
        opts = replace(opts, cycle_mode=cycle_mode)
    return opts


def resolve(design: Design, opts: DGLMNETOptions, *,
            densify: Optional[bool] = None) -> Strategy:
    """Pick the execution plan for ``design`` under ``opts``.

    * local vs mesh comes from the design (:class:`ShardedDesign` or not);
    * dense vs slab subproblems from ``design.layout`` (local slab layouts
      densify once and ride the dense solver — slab streaming pays off on
      the mesh, where a dense X may not exist at all);
    * ``cycle_mode="auto"`` resolves to a concrete mode here (the
      ``prefer_blocked_cd`` tile-size heuristic), so every downstream
      consumer sees only "sequential" or "blocked";
    * ``cap_tile`` is the capacity quantum restricted solves are bucketed
      to: ``tile`` locally, ``model_dim * tile`` on a mesh (restricted
      shapes stay mesh-aligned, O(log(p/tile)) programs per path);
    * ``residency`` is "streamed" when the design's device budget is
      below its padded slab byte total (the
      :class:`~repro.data.residency.BucketResidencyManager` then double-
      buffers buckets host->device through every pass), else "resident".
      A budget on a sharded *dense* layout is rejected here: dense mesh
      solves keep the whole X resident, so the budget would silently not
      bound anything — convert to slabs (``to_slab_buckets``) to stream.
    """
    sharded = isinstance(design, ShardedDesign)
    execution = "mesh" if sharded else "local"
    solver = "slab" if (sharded and design.layout in ("slab", "bucketed")) \
        else "dense"
    opts = _resolve_cycle(opts)
    cap_tile = (design.mdim if sharded else 1) * opts.tile
    residency = "resident"
    if sharded and design.device_budget_bytes is not None:
        if solver != "slab":
            raise ValueError(
                "device_budget_bytes streams slab layouts only; a sharded "
                f"dense design keeps X fully resident — build the design "
                f"from slabs (to_by_feature / to_slab_buckets) to stream")
        if design.device_budget_bytes < design.slab_nbytes(opts.tile):
            residency = "streamed"
    return Strategy(execution=execution, solver=solver, opts=opts,
                    cap_tile=cap_tile, densify=densify, residency=residency)


def mesh_programs(mesh, opts: DGLMNETOptions, *, layout: str = "dense",
                  n_loc: Optional[int] = None):
    """The lowerable mesh programs for a layout/opts combo, resolved the
    same way live solves are — the dry-run's front door
    (``launch/dryrun.py`` lowers these at production-mesh scale without
    data).

    Returns ``(step, screen)``: ``step`` is the jitted distributed outer
    iteration for the layout (``step(X|slabs..., y, beta, m, lam)``);
    ``screen`` is the sparse strong-rule pass (slab layouts with ``n_loc``
    given; ``None`` otherwise).
    """
    from repro.core.distributed import (
        make_dglmnet_step,
        make_dglmnet_step_sparse,
    )

    if layout not in ("dense", "slab", "bucketed"):
        raise ValueError(f"unknown layout {layout!r}")
    opts = _resolve_cycle(opts)
    if layout == "dense":
        step = make_dglmnet_step(mesh, opts)
    else:
        step = make_dglmnet_step_sparse(mesh, opts)
    screen = None
    if layout != "dense" and n_loc is not None:
        from repro.core.screening import make_sparse_screen

        screen = make_sparse_screen(mesh, n_loc, opts.tile)
    return step, screen
