"""Screened regularization path (core/screening.py + core/regpath.py):
the strong-rule/KKT engine must be an exact-up-to-tolerance drop-in for the
full-p warm-started path, and the KKT post-check must catch bad screens."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GLMConfig
from repro.core import DGLMNETOptions, fit, lambda_max, regularization_path
from repro.core.objective import margins
from repro.core.screening import (
    capacity_bucket,
    gather_columns,
    kkt_violations,
    nll_grad_abs,
    scatter_columns,
    strong_rule_mask,
)
from repro.data.synthetic import make_glm_dataset


@pytest.fixture(scope="module")
def path_glm():
    cfg = GLMConfig(name="screen", num_examples=1280, num_features=192,
                    density=1.0)
    return make_glm_dataset(cfg, jax.random.key(7))


def test_screened_path_matches_unscreened(path_glm):
    """Same nnz and objective per lambda as the full-p path (both solved
    tightly, so screening tolerance artifacts vanish)."""
    X, y = path_glm.X_train, path_glm.y_train
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=150, rel_tol=1e-8)
    pts_full = regularization_path(X, y, path_len=8, opts=opts, screen=False)
    pts_scr = regularization_path(X, y, path_len=8, opts=opts, screen=True)
    assert len(pts_full) == len(pts_scr) == 8
    for pf, ps in zip(pts_full, pts_scr):
        # supports may disagree only on numerically-zero boundary
        # coordinates (a coef soft-thresholded to exactly 0 in one run and
        # ~1e-4 in the other); every confidently-nonzero feature matches
        bf = np.abs(np.asarray(pf.beta))
        bs = np.abs(np.asarray(ps.beta))
        disagree = (bf > 0) != (bs > 0)
        assert np.all(np.maximum(bf, bs)[disagree] < 1e-2), (
            ps.lam, np.maximum(bf, bs)[disagree])
        assert abs(ps.nnz - pf.nnz) <= 2, (ps.lam, ps.nnz, pf.nnz)
        rel = abs(ps.f - pf.f) / max(abs(pf.f), 1e-9)
        assert rel < 1e-4, (ps.lam, ps.f, pf.f)
        assert ps.screen["active"] <= X.shape[1]
    # screening actually restricted the problem somewhere on the path
    assert any(p.screen["active"] < X.shape[1] for p in pts_scr)


def test_screened_path_certified_by_kkt(path_glm):
    """Every path point's discarded set passes the KKT condition at the
    returned solution."""
    X, y = path_glm.X_train, path_glm.y_train
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=150, rel_tol=1e-8)
    pts = regularization_path(X, y, path_len=6, opts=opts, screen=True)
    for p in pts:
        g_abs = nll_grad_abs(X, y, margins(X, p.beta))
        inactive = p.beta == 0
        assert bool(jnp.all(g_abs[inactive] <= p.lam * (1 + 2e-3) + 1e-5)), p.lam


def test_kkt_catches_deliberately_violated_screen(path_glm):
    """Drop the strongest feature from the working set on purpose: the
    restricted solve cannot fix it, and the KKT post-check must flag it."""
    X, y = path_glm.X_train, path_glm.y_train
    n, p = X.shape
    beta0 = jnp.zeros(p, jnp.float32)
    m0 = margins(X, beta0)
    g_abs = nll_grad_abs(X, y, m0)
    top = int(jnp.argmax(g_abs))
    lam = float(lambda_max(X, y)) / 4          # top feature is active here

    mask = strong_rule_mask(g_abs, lam, float(lambda_max(X, y)), beta0)
    assert bool(mask[top])                      # sanity: screen wants it
    bad_mask = mask.at[top].set(False)          # deliberately violate it

    cap = capacity_bucket(int(bad_mask.sum()), p, tile=32)
    X_sub, beta_sub, idx = gather_columns(X, beta0, bad_mask, cap)
    res = fit(X_sub, y, lam, beta0=beta_sub,
              opts=DGLMNETOptions(num_blocks=2, tile=32, max_iters=100))
    beta_full = scatter_columns(res.beta, idx, p)
    g_after = nll_grad_abs(X, y, margins(X, beta_full))

    viol = kkt_violations(g_after, lam, bad_mask)
    assert bool(viol[top]), "KKT post-check missed the excluded feature"
    # and the certified mask (without sabotage) has no violations
    X_ok, beta_ok, idx_ok = gather_columns(X, beta0, mask, capacity_bucket(int(mask.sum()), p, tile=32))
    res_ok = fit(X_ok, y, lam, beta0=beta_ok,
                 opts=DGLMNETOptions(num_blocks=2, tile=32, max_iters=100))
    g_ok = nll_grad_abs(X, y, X_ok @ res_ok.beta)
    assert not bool(jnp.any(kkt_violations(g_ok, lam, mask)))


def test_regpath_recovers_from_violated_screen(path_glm):
    """End-to-end: even if the first working set misses active features,
    the KKT loop re-solves until certified. On this data the aggressive
    working-set threshold demonstrably under-screens at several lambdas
    (kkt_rounds >= 2 without the test forcing it) — if violators ever stop
    re-entering, the multi-round points disappear and this fails. The
    blitz-style growth knobs are pinned off: this test certifies the
    violation *machinery*, which the carried working set is designed to
    make rarer."""
    X, y = path_glm.X_train, path_glm.y_train
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=150, rel_tol=1e-8)
    pts = regularization_path(X, y, path_len=8, opts=opts, screen=True,
                              carry_working_set=False, violation_budget=None)
    assert any(p.screen["kkt_rounds"] >= 2 for p in pts), \
        [p.screen for p in pts]
    # and every multi-round point grew its working set beyond its nnz floor
    for p in pts:
        assert p.screen["active"] >= p.nnz


def test_blitz_carry_matches_reset_path(path_glm):
    """The carried/budgeted working set (default) is a pure acceleration:
    per-lambda solutions match the reset-every-lambda path, the working
    set never shrinks across the path, and no point pays more KKT rounds
    in total."""
    X, y = path_glm.X_train, path_glm.y_train
    opts = DGLMNETOptions(num_blocks=4, tile=32, max_iters=150, rel_tol=1e-8)
    reset = regularization_path(X, y, path_len=8, opts=opts, screen=True,
                                carry_working_set=False,
                                violation_budget=None)
    blitz = regularization_path(X, y, path_len=8, opts=opts, screen=True)
    actives = [p.screen["active"] for p in blitz]
    assert actives == sorted(actives), actives     # monotone growth
    assert sum(p.screen["kkt_rounds"] for p in blitz) <= \
        sum(p.screen["kkt_rounds"] for p in reset)
    for pr, pb in zip(reset, blitz):
        assert abs(pb.nnz - pr.nnz) <= 2, (pb.lam, pb.nnz, pr.nnz)
        rel = abs(pb.f - pr.f) / max(abs(pr.f), 1e-9)
        assert rel < 1e-4, (pb.lam, pb.f, pr.f)


def test_budgeted_admission_takes_top_violators():
    from repro.core.screening import budgeted_admission

    g = jnp.asarray([9.0, 1.0, 5.0, 7.0, 3.0, 8.0])
    viol = jnp.asarray([True, True, False, True, True, True])
    # budget 2: only the two strongest violators (9.0 and 8.0) enter;
    # 5.0 is not a violator and must never be admitted
    adm = budgeted_admission(viol, g, 2)
    np.testing.assert_array_equal(
        np.asarray(adm), [True, False, False, False, False, True])
    # budget >= violator count: pass-through
    adm_all = budgeted_admission(viol, g, 16)
    np.testing.assert_array_equal(np.asarray(adm_all), np.asarray(viol))
    # ties at the cutoff are all admitted (growth rate, not exact count)
    g_tie = jnp.asarray([4.0, 4.0, 4.0, 1.0])
    viol_tie = jnp.asarray([True, True, True, True])
    adm_tie = budgeted_admission(viol_tie, g_tie, 2)
    np.testing.assert_array_equal(
        np.asarray(adm_tie), [True, True, True, False])


def test_sparse_screen_matches_dense(path_glm):
    """nll_grad_abs_sparse over by-feature slabs == dense nll_grad_abs on
    the densified matrix, at zero and at a warm-start point — the screen
    never needs a dense X."""
    from repro.core.screening import nll_grad_abs_sparse
    from repro.data.byfeature import to_by_feature

    X, y = path_glm.X_train, path_glm.y_train
    Xs = X * (jax.random.uniform(jax.random.key(3), X.shape) < 0.3)
    bf = to_by_feature(Xs)
    for m in (jnp.zeros(X.shape[0]),
              margins(Xs, jax.random.normal(jax.random.key(4),
                                            (X.shape[1],)) * 0.05)):
        g_dense = nll_grad_abs(Xs, y, m)
        g_sparse = nll_grad_abs_sparse(bf.row_idx, bf.values, y, m)
        np.testing.assert_allclose(np.asarray(g_sparse), np.asarray(g_dense),
                                   rtol=1e-4, atol=1e-3)


def test_gather_scatter_roundtrip():
    key = jax.random.key(0)
    X = jax.random.normal(key, (16, 24))
    beta = jax.random.normal(jax.random.fold_in(key, 1), (24,))
    mask = jnp.arange(24) % 3 == 0
    cap = capacity_bucket(int(mask.sum()), 24, tile=4)
    X_sub, beta_sub, idx = gather_columns(X, beta, mask, cap)
    # gathered columns match, padding is zero
    sel = np.flatnonzero(np.asarray(mask))
    np.testing.assert_allclose(np.asarray(X_sub[:, :len(sel)]),
                               np.asarray(X[:, sel]))
    assert np.all(np.asarray(X_sub[:, len(sel):]) == 0)
    # scatter restores exactly the masked coefficients
    back = scatter_columns(beta_sub, idx, 24)
    np.testing.assert_allclose(np.asarray(back),
                               np.asarray(jnp.where(mask, beta, 0.0)))


def test_capacity_bucket_bounds():
    assert capacity_bucket(0, 1024, tile=128) == 128
    assert capacity_bucket(1, 1024, tile=128) == 128
    assert capacity_bucket(129, 1024, tile=128) == 256
    assert capacity_bucket(513, 1024, tile=128) == 1024
    assert capacity_bucket(1024, 1024, tile=128) == 1024
    # never exceeds p, never below count
    for count in (1, 7, 100, 500):
        cap = capacity_bucket(count, 512, tile=64)
        assert count <= cap <= 512


def test_strong_rule_keeps_support():
    g = jnp.array([0.1, 5.0, 0.2, 3.0])
    beta = jnp.array([0.0, 0.0, -1.0, 0.0])
    mask = strong_rule_mask(g, 2.0, 4.0, beta)
    assert bool(mask[1])        # |g| >= max(2*2-4, 2) = 2
    assert bool(mask[2])        # ever-active stays
    assert bool(mask[3])
    assert not bool(mask[0])    # below threshold, zero coefficient
