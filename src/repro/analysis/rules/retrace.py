"""retrace-hazard: compilation-cache poison.

The warm-started path's whole perf model assumes a handful of
compilations serve all L lambdas (capacity-bucketed shapes, lam as a
traced operand). Two accidents silently break that:

1. A jitted function keyed on Python values that should be static —
   dict/list/tuple defaults (unhashable: TypeError at best, retrace per
   call at worst) or int/bool scalar defaults used as structural knobs
   without ``static_argnames``. Every call with a new value is a fresh
   trace.

2. An *unbounded* ``functools.lru_cache`` in a JAX module. Keys and
   values live forever: a cache over meshes pins every mesh (and every
   compiled program built from it) for the life of the process, and a
   cached function that captures or returns device arrays pins device
   memory that looks like a leak (the ``serve/scoring.py`` path-margins
   cache was the live example). Bound it, scope it to the owning object,
   or justify why process-lifetime growth is really bounded.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "retrace-hazard"
DOC = ("jitted defs with non-static Python-structure/scalar defaults; "
       "unbounded lru_cache in JAX modules")


def _jit_decoration(mod: ModuleInfo, fn: ast.FunctionDef):
    """The jit decorator Call (or marker) if fn is jit-decorated."""
    for dec in fn.decorator_list:
        q = mod.qualname(dec)
        if q in ("jax.jit", "jit"):
            return dec
        if isinstance(dec, ast.Call):
            qc = mod.qualname(dec.func)
            if qc in ("jax.jit", "jit"):
                return dec
            if qc in ("functools.partial", "partial") and dec.args and \
                    mod.qualname(dec.args[0]) in ("jax.jit", "jit"):
                return dec
    return None


def _static_argnames(dec) -> Set[str]:
    if not isinstance(dec, ast.Call):
        return set()
    for kw in dec.keywords:
        if kw.arg == "static_argnames":
            return {c.value for c in ast.walk(kw.value)
                    if isinstance(c, ast.Constant)
                    and isinstance(c.value, str)}
    return set()


def _param_defaults(fn: ast.FunctionDef):
    a = fn.args
    pos = a.posonlyargs + a.args
    for p, d in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        yield p.arg, d
    for p, d in zip(a.kwonlyargs, a.kw_defaults):
        if d is not None:
            yield p.arg, d


def _check_jit_defaults(mod: ModuleInfo, fn: ast.FunctionDef,
                        dec) -> Iterable[Finding]:
    static = _static_argnames(dec)
    for name, default in _param_defaults(fn):
        if name in static:
            continue
        if isinstance(default, (ast.Dict, ast.List, ast.Set, ast.Tuple)):
            yield Finding(
                file=mod.path, line=fn.lineno, rule=RULE_ID,
                message=(
                    f"jitted {fn.name}() takes Python-structure default "
                    f"for {name!r} not in static_argnames — unhashable "
                    f"under the jit cache (or a retrace per distinct "
                    f"value); mark static or pass arrays"),
            )
        elif (isinstance(default, ast.Constant)
              and isinstance(default.value, (int, bool))
              and not isinstance(default.value, float)):
            yield Finding(
                file=mod.path, line=fn.lineno, rule=RULE_ID,
                message=(
                    f"jitted {fn.name}() takes Python scalar default "
                    f"{name}={default.value!r} absent from static_argnames "
                    f"— a structural knob traced as an operand retraces on "
                    f"first use in shape math; declare it static"),
            )


def _lru_maxsize(dec: ast.AST) -> Optional[str]:
    """'unbounded' if @lru_cache pins forever, None if bounded/not lru."""
    if isinstance(dec, ast.Name) and dec.id == "lru_cache":
        return "bare @lru_cache"
    if isinstance(dec, ast.Attribute) and dec.attr == "lru_cache":
        return "bare @lru_cache"
    if isinstance(dec, ast.Call):
        base = dec.func
        name_ok = (isinstance(base, ast.Name) and base.id == "lru_cache") \
            or (isinstance(base, ast.Attribute) and base.attr == "lru_cache")
        if not name_ok:
            return None
        if not dec.args and not dec.keywords:
            return "@lru_cache()"
        for kw in dec.keywords:
            if kw.arg == "maxsize":
                if isinstance(kw.value, ast.Constant) and \
                        kw.value.value is None:
                    return "maxsize=None"
                return None
        if dec.args:
            first = dec.args[0]
            if isinstance(first, ast.Constant) and first.value is None:
                return "maxsize=None"
        return None
    return None


def _check_lru(mod: ModuleInfo) -> Iterable[Finding]:
    for fn in mod.functions():
        for dec in fn.decorator_list:
            how = _lru_maxsize(dec)
            if how is None:
                continue
            yield Finding(
                file=mod.path, line=fn.lineno, rule=RULE_ID,
                message=(
                    f"unbounded lru_cache ({how}) on {fn.name}() in a JAX "
                    f"module — keys/values (meshes, compiled programs, "
                    f"device arrays) are pinned for the process lifetime; "
                    f"bound it, scope it to the owning object, or "
                    f"allow[{RULE_ID}] with why growth is bounded"),
            )


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not mod.imports_jax:
            continue
        for fn in mod.functions():
            dec = _jit_decoration(mod, fn)
            if dec is not None:
                out.extend(_check_jit_defaults(mod, fn, dec))
        out.extend(_check_lru(mod))
    return out
