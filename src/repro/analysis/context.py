"""Shared AST context for the lint rules.

Every rule consumes a :class:`Project` (the parsed file set) and iterates
:class:`ModuleInfo` objects. The helpers here centralize the repo's JAX
idioms so rules stay declarative:

* alias-resolved dotted names (``import jax.numpy as jnp`` makes
  ``jnp.concatenate`` resolve to ``jax.numpy.concatenate``);
* jit-context discovery — decorator forms (``@jax.jit``,
  ``@partial(jax.jit, ...)``), wrapper assignments/returns
  (``f = jax.jit(g)``, ``return jax.jit(solve)``) and control-flow bodies
  handed to ``lax.while_loop`` / ``scan`` / ``fori_loop`` / ``cond`` — all
  of which trace their function arguments;
* shard_map decoration parsing (mesh/in_specs/out_specs kwargs).

Nothing here imports JAX: the analyzer must run (and fail fast) even in an
environment where the runtime can't.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import PurePosixPath
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

#: canonical module names whose presence marks a module as mesh-aware
MESH_IMPORT_ROOTS = (
    "jax.sharding",
    "jax.experimental.shard_map",
    "repro.compat.shard_map",
    "repro.sharding",
)

#: names that, when imported, mark a module as mesh-aware
MESH_IMPORT_NAMES = {"Mesh", "NamedSharding", "PartitionSpec", "shard_map"}

#: lax control-flow entry points whose function args are traced
_TRACED_HOF = {
    "jax.lax.while_loop": (0, 1),
    "jax.lax.scan": (0,),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2, 3),
    "jax.lax.switch": None,   # every arg past the index may be a branch
    "jax.lax.map": (0,),
}


def qualname(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted name of an expression, alias-resolved to canonical roots.

    ``jnp.concatenate`` -> ``jax.numpy.concatenate`` when the module did
    ``import jax.numpy as jnp``; plain names resolve through ``from x
    import y [as z]``. Returns None for non-name expressions.
    """
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    head = aliases.get(cur.id, cur.id)
    parts.append(head)
    return ".".join(reversed(parts))


@dataclass
class ShardMapDecoration:
    """A parsed ``shard_map`` application site."""

    node: ast.Call                      # the shard_map(...) / partial(...) call
    in_specs: Optional[ast.expr]
    out_specs: Optional[ast.expr]
    line: int


@dataclass
class ModuleInfo:
    path: str                           # posix path relative to repo root
    source: str
    tree: ast.Module
    lines: List[str]
    aliases: Dict[str, str] = field(default_factory=dict)
    imported_modules: Set[str] = field(default_factory=set)
    _jit_functions: Optional[Set[ast.FunctionDef]] = None

    # -- imports ------------------------------------------------------------

    @classmethod
    def parse(cls, path: str, source: str) -> "ModuleInfo":
        tree = ast.parse(source, filename=path)
        info = cls(path=PurePosixPath(path).as_posix(), source=source,
                   tree=tree, lines=source.splitlines())
        info._collect_imports()
        return info

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
                    self.imported_modules.add(a.name)
            elif isinstance(node, ast.ImportFrom) and node.module:
                self.imported_modules.add(node.module)
                for a in node.names:
                    if a.name == "*":
                        continue
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )

    def qualname(self, node: ast.AST) -> Optional[str]:
        return qualname(node, self.aliases)

    @property
    def imports_jax(self) -> bool:
        return any(m == "jax" or m.startswith("jax.")
                   for m in self.imported_modules)

    @property
    def mesh_context(self) -> bool:
        """Mesh-aware module: imports sharding machinery (the contexts in
        which a stray ``jnp.concatenate`` can hit the P(model)-concat
        miscompile this repo guards against in ``sharding/collect.py``)."""
        for m in self.imported_modules:
            if any(m == r or m.startswith(r + ".") for r in MESH_IMPORT_ROOTS):
                return True
        resolved = set(self.aliases.values())
        return any(
            r.rsplit(".", 1)[-1] in MESH_IMPORT_NAMES and "." in r
            and r.rsplit(".", 1)[0].startswith(("jax", "repro"))
            for r in resolved
        )

    # -- function scopes ----------------------------------------------------

    def functions(self) -> Iterator[ast.FunctionDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    def jit_functions(self) -> Set[ast.FunctionDef]:
        """Function defs whose bodies run under trace: jit-decorated,
        jit-wrapped by name, or passed to lax control flow. Includes
        functions *nested inside* such functions (the whole body traces).
        """
        if self._jit_functions is not None:
            return self._jit_functions
        by_name: Dict[str, List[ast.FunctionDef]] = {}
        for fn in self.functions():
            by_name.setdefault(fn.name, []).append(fn)
        traced: Set[ast.FunctionDef] = set()

        def is_jit_call(call: ast.Call) -> bool:
            q = self.qualname(call.func)
            if q in ("jax.jit", "jit", "jax.pmap", "jax.vmap"):
                return True
            if q in ("functools.partial", "partial") and call.args:
                return self.qualname(call.args[0]) in ("jax.jit", "jit")
            return False

        for fn in self.functions():
            for dec in fn.decorator_list:
                q = self.qualname(dec)
                if q in ("jax.jit", "jit"):
                    traced.add(fn)
                elif isinstance(dec, ast.Call) and is_jit_call(dec):
                    traced.add(fn)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            q = self.qualname(node.func)
            if q in ("jax.jit", "jit"):
                for arg in node.args[:1]:
                    if isinstance(arg, ast.Name):
                        traced.update(by_name.get(arg.id, ()))
            elif q in _TRACED_HOF:
                idxs = _TRACED_HOF[q]
                args = (node.args if idxs is None
                        else [node.args[i] for i in idxs
                              if i < len(node.args)])
                for arg in args:
                    if isinstance(arg, ast.Name):
                        traced.update(by_name.get(arg.id, ()))
        # close over nesting: any def lexically inside a traced def traces
        changed = True
        while changed:
            changed = False
            for fn in list(traced):
                for inner in ast.walk(fn):
                    if (isinstance(inner, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))
                            and inner is not fn and inner not in traced):
                        traced.add(inner)
                        changed = True
        self._jit_functions = traced
        return traced

    # -- shard_map ----------------------------------------------------------

    def shard_map_decorations(
        self,
    ) -> Iterator[Tuple[ast.FunctionDef, ShardMapDecoration]]:
        """(fn, decoration) for every def decorated with shard_map —
        directly or through ``partial(shard_map, ...)``."""
        for fn in self.functions():
            for dec in fn.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                q = self.qualname(dec.func)
                target = None
                if q is not None and q.endswith("shard_map"):
                    target = dec
                elif (q in ("functools.partial", "partial") and dec.args):
                    inner_q = self.qualname(dec.args[0])
                    if inner_q is not None and inner_q.endswith("shard_map"):
                        target = dec
                if target is None:
                    continue
                kw = {k.arg: k.value for k in target.keywords if k.arg}
                yield fn, ShardMapDecoration(
                    node=target, in_specs=kw.get("in_specs"),
                    out_specs=kw.get("out_specs"), line=target.lineno,
                )

    def declared_axis_names(self) -> Set[str]:
        """Axis-name string literals declared by this module's sharding
        constructs: ``P(...)`` / ``PartitionSpec(...)`` entries, Mesh
        ``axis_names``, and defaults of ``*_axis`` parameters."""
        out: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                q = self.qualname(node.func)
                if q is None:
                    continue
                tail = q.rsplit(".", 1)[-1]
                if tail in ("PartitionSpec", "P"):
                    for arg in list(node.args) + [
                            k.value for k in node.keywords]:
                        out.update(_string_leaves(arg))
                elif tail in ("Mesh", "make_mesh", "make_dev_mesh"):
                    for k in node.keywords:
                        if k.arg == "axis_names":
                            out.update(_string_leaves(k.value))
                    for arg in node.args:
                        out.update(_string_leaves(arg))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                pos = args.posonlyargs + args.args
                defaults = list(args.defaults)
                for a, d in zip(pos[len(pos) - len(defaults):], defaults):
                    if a.arg.endswith("_axis"):
                        out.update(_string_leaves(d))
                for a, d in zip(args.kwonlyargs, args.kw_defaults):
                    if d is not None and a.arg.endswith("_axis"):
                        out.update(_string_leaves(d))
        return out


def _string_leaves(node: Optional[ast.AST]) -> Iterator[str]:
    if node is None:
        return
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            yield n.value


def positional_param_count(fn: ast.FunctionDef) -> int:
    return len(fn.args.posonlyargs) + len(fn.args.args)


def spec_tuple_len(spec: ast.expr) -> Optional[int]:
    """Length of a literal in_specs tuple/list; 1 for a single P(...);
    None when the expression is dynamic (a variable, a comprehension)."""
    if isinstance(spec, (ast.Tuple, ast.List)):
        return len(spec.elts)
    if isinstance(spec, ast.Call):
        return 1
    return None


@dataclass
class Project:
    root: str
    modules: List[ModuleInfo]

    def by_path(self, suffix: str) -> Optional[ModuleInfo]:
        for m in self.modules:
            if m.path.endswith(suffix):
                return m
        return None

    def iter_modules(
        self, under: Optional[Sequence[str]] = None
    ) -> Iterator[ModuleInfo]:
        for m in self.modules:
            if under is None or any(m.path.startswith(u) for u in under):
                yield m
