from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    make_optimizer,
    sgd,
)
from repro.optim.schedule import constant, warmup_cosine  # noqa: F401
