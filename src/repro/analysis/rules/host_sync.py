"""host-sync-in-jit: device->host synchronization inside traced code.

The engine's contract is ONE ``device_get`` per solve (core/engine.py);
everything between warm start and fetch stays on device. A
``float()``/``int()``/``bool()``/``.item()``/``np.asarray()`` on a traced
value inside a ``@jit`` function or a ``lax.while_loop``/``scan`` body
either fails at trace time on the path that runs — or worse, silently
forces a concretization error miles from the cause. Static detection
matters doubly on CPU, where ``jax.transfer_guard`` cannot catch these at
runtime (no physical transfer happens; see ``repro.analysis.sanitize``).

Shape arithmetic is exempt: ``int(x.shape[0])``, ``len(x)``, ``x.ndim``,
``x.size`` and literals are static under trace.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "host-sync-in-jit"
DOC = ("float()/int()/bool()/.item()/np.asarray on traced values inside "
       "jit-compiled functions or lax control-flow bodies")

_NP_SYNC = {"numpy.asarray", "numpy.array", "numpy.asanyarray",
            "jax.device_get"}
_CASTS = {"float", "int", "bool", "complex"}


def _is_static_expr(node: ast.expr) -> bool:
    """Exempt shape math: static under trace, no host sync involved."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id == "len":
            return True
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in (
                "shape", "ndim", "size", "dtype"):
            return True
    return False


def _check_fn(mod: ModuleInfo, fn: ast.FunctionDef) -> Iterable[Finding]:
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        # float(x) / int(x) / bool(x)
        if (isinstance(node.func, ast.Name) and node.func.id in _CASTS
                and node.args and not _is_static_expr(node.args[0])):
            yield Finding(
                file=mod.path, line=node.lineno, rule=RULE_ID,
                message=(
                    f"{node.func.id}() on a (possibly traced) value inside "
                    f"jit-compiled {fn.name}() — forces a host sync or a "
                    f"ConcretizationTypeError; keep the value on device or "
                    f"hoist out of the traced region"),
            )
            continue
        # .item()
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item" and not node.args):
            yield Finding(
                file=mod.path, line=node.lineno, rule=RULE_ID,
                message=(
                    f".item() inside jit-compiled {fn.name}() — a blocking "
                    f"device->host transfer per call; fetch once after the "
                    f"traced region instead"),
            )
            continue
        # np.asarray / np.array / jax.device_get
        q = mod.qualname(node.func)
        if q in _NP_SYNC:
            short = q.replace("numpy.", "np.")
            yield Finding(
                file=mod.path, line=node.lineno, rule=RULE_ID,
                message=(
                    f"{short}() inside jit-compiled {fn.name}() — "
                    f"materializes the operand on host under trace; use "
                    f"jnp ops or move outside the jitted function"),
            )


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not mod.imports_jax:
            continue
        for fn in mod.jit_functions():
            out.extend(_check_fn(mod, fn))
    return out
