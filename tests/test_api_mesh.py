"""Mesh flavors of the front-door equivalence suite (subprocesses with
fake CPU devices — tests themselves must see 1 device, per the dry-run
isolation rule): every legacy mesh entry point must be bit-identical to
``LogisticL1`` over the matching ``ShardedDesign``, the streamed eval must
match the host-matrix eval, and the shared reshard-to-replicated concat
guard must keep working."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def test_fit_distributed_shims_bit_identical_1x2():
    """fit_distributed and fit_distributed_sparse (slab + densify override)
    vs the front door on a 1x2 mesh: bit-identical betas and telemetry."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.api import (DenseDesign, LogisticL1, ShardedDesign,
                               SlabDesign)
        from repro.configs.base import GLMConfig
        from repro.core import (DGLMNETOptions, fit_distributed,
                                fit_distributed_sparse, lambda_max)
        from repro.data.byfeature import to_by_feature, to_slabs
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='e', num_examples=512, num_features=64,
                        density=0.2)
        ds = make_glm_dataset(cfg, jax.random.key(2))
        X, y = ds.X_train, ds.y_train
        lam = float(lambda_max(X, y)) / 16
        opts = DGLMNETOptions(num_blocks=2, tile=16, max_iters=25)
        mesh = make_dev_mesh(1, 2)

        def same(a, b):
            assert a.f == b.f and a.n_iters == b.n_iters, (a.f, b.f)
            assert bool(jnp.all(a.beta == b.beta))
            assert a.alpha_history == b.alpha_history
            assert a.unit_step_frac == b.unit_step_frac
            assert a.converged == b.converged

        legacy = fit_distributed(X, y, lam, mesh, opts=opts)
        front = LogisticL1(opts=opts).fit(
            ShardedDesign(DenseDesign(X), mesh, tile=opts.tile), y, lam)
        same(legacy, front)

        row_idx, values, _ = to_slabs(to_by_feature(X), 1)
        for densify in (None, False, True):
            legacy = fit_distributed_sparse(row_idx, values, y, lam, mesh,
                                            opts=opts, densify=densify)
            front = LogisticL1(opts=opts).fit(
                ShardedDesign(SlabDesign(row_idx, values, int(y.shape[0])),
                              mesh, tile=opts.tile),
                y, lam, densify=densify)
            same(legacy, front)
        print('OK fit shims 1x2')
    """, devices=2)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_regpath_distributed_shim_bit_identical_layouts():
    """regularization_path_distributed vs LogisticL1.path on a 2x4 mesh,
    for all three mesh layouts (dense X, flat slabs, SlabBuckets):
    bit-identical betas and identical screen telemetry per lambda."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.api import (BucketedSlabDesign, DenseDesign, LogisticL1,
                               ShardedDesign, SlabDesign, as_design)
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, regularization_path_distributed
        from repro.data.byfeature import (to_by_feature, to_slab_buckets,
                                          to_slabs)
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='e', num_examples=512, num_features=96,
                        density=0.15)
        ds = make_glm_dataset(cfg, jax.random.key(4))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        opts = DGLMNETOptions(num_blocks=2, tile=16, max_iters=30)
        mesh = make_dev_mesh(2, 4)
        bf = to_by_feature(X)
        row_idx, values, _ = to_slabs(bf, 2)
        layouts = {
            'dense': X,
            'slab': (row_idx, values),
            'bucketed': to_slab_buckets(bf, 2),
        }
        for name, data in layouts.items():
            legacy = regularization_path_distributed(
                data, y, mesh, path_len=4, opts=opts)
            design = as_design(data, n=n, mesh=mesh, tile=opts.tile)
            front = LogisticL1(opts=opts).path(design, y, path_len=4)
            for a, b in zip(legacy, front):
                assert a.lam == b.lam and a.f == b.f, (name, a.lam)
                assert a.nnz == b.nnz and a.n_iters == b.n_iters, name
                assert a.screen == b.screen, (name, a.screen, b.screen)
                assert bool(jnp.all(a.beta == b.beta)), name
        print('OK path shims all layouts')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_streamed_eval_matches_host_eval_on_mesh():
    """LogisticL1.path(ShardedDesign, eval_fn=make_design_eval(...)):
    per-lambda AUPRC/accuracy streamed through a *sharded* test design
    match glm_eval_fn on the replicated host matrix — the ROADMAP
    streamed-eval item."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.api import (LogisticL1, ShardedDesign, SlabDesign,
                               make_design_eval)
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh
        from repro.train.metrics import glm_eval_fn

        cfg = GLMConfig(name='se', num_examples=640, num_features=64,
                        density=0.2)
        ds = make_glm_dataset(cfg, jax.random.key(6))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        nt = (ds.X_test.shape[0] // 2) * 2
        Xt, yt = ds.X_test[:nt], ds.y_test[:nt]
        mesh = make_dev_mesh(2, 4)
        opts = DGLMNETOptions(num_blocks=2, tile=16, max_iters=30)

        design = ShardedDesign(SlabDesign.from_dense(X, 2), mesh, tile=16)
        streamed = make_design_eval(SlabDesign.from_dense(Xt, 2), yt,
                                    mesh=mesh, tile=16)
        pts = LogisticL1(opts=opts).path(design, y, path_len=4,
                                         eval_fn=streamed)
        host_eval = glm_eval_fn(Xt, yt)
        for pt in pts:
            ref = host_eval(pt.beta)
            for k in ref:
                assert abs(pt.metrics[k] - ref[k]) < 1e-4, (k, pt.metrics,
                                                            ref)
        assert any(pt.metrics['auprc'] > 0.5 for pt in pts)
        print('OK streamed eval')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_path_with_mismatched_design_tile():
    """Regression: LogisticL1.opts.tile != ShardedDesign.tile must not
    split the work axis between two mesh states (g_abs/mask shape
    mismatch, or silent misalignment across buckets) — the estimator
    threads opts.tile through every work-axis helper."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.api import LogisticL1, ShardedDesign, SlabDesign
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='tm', num_examples=256, num_features=40,
                        density=0.2)
        ds = make_glm_dataset(cfg, jax.random.key(3))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        mesh = make_dev_mesh(2, 2)
        opts = DGLMNETOptions(num_blocks=2, tile=4, max_iters=20)
        design16 = ShardedDesign(SlabDesign.from_dense(X, 2), mesh, tile=16)
        design4 = ShardedDesign(SlabDesign.from_dense(X, 2), mesh, tile=4)
        pts = LogisticL1(opts=opts).path(design16, y, path_len=3)
        ref = LogisticL1(opts=opts).path(design4, y, path_len=3)
        for a, b in zip(pts, ref):
            assert a.f == b.f and a.nnz == b.nnz, (a.lam, a.f, b.f)
            assert bool(jnp.all(a.beta == b.beta))
        print('OK mismatched tile')
    """, devices=4)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_concat_replicated_guard():
    """Regression for the P(model)-sharded concat miscompile: the shared
    sharding/collect helper must equal the host-side concat for unequal-
    length feature-sharded pieces (the inline workaround this replaces was
    in regpath.py)."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_dev_mesh
        from repro.sharding.collect import concat_replicated, replicate

        mesh = make_dev_mesh(2, 4)
        bshard = NamedSharding(mesh, P('model'))
        pieces_host = [np.arange(s, dtype=np.float32) + 100 * i
                       for i, s in enumerate((64, 128, 32))]
        pieces = [jax.device_put(jnp.asarray(x), bshard)
                  for x in pieces_host[:2]]
        pieces.append(jax.device_put(jnp.asarray(pieces_host[2]),
                                     NamedSharding(mesh, P())))
        out = concat_replicated(pieces, mesh)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.concatenate(pieces_host))
        # single piece: passthrough (replicated)
        one = concat_replicated([pieces[0]], mesh)
        np.testing.assert_array_equal(np.asarray(one), pieces_host[0])
        r = replicate(pieces[1], mesh)
        assert r.sharding.is_fully_replicated
        print('OK concat guard')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


@pytest.mark.slow
def test_bucketed_fit_on_mesh_matches_local():
    """LogisticL1.fit on a ShardedDesign(BucketedSlabDesign) — a combo no
    legacy entry point offered — lands on the local dense solve."""
    r = _run("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.api import BucketedSlabDesign, DenseDesign, LogisticL1, \\
            ShardedDesign
        from repro.configs.base import GLMConfig
        from repro.core import DGLMNETOptions, lambda_max
        from repro.data.byfeature import to_by_feature
        from repro.data.synthetic import make_glm_dataset
        from repro.launch.mesh import make_dev_mesh

        cfg = GLMConfig(name='bk', num_examples=512, num_features=96,
                        density=0.08)
        ds = make_glm_dataset(cfg, jax.random.key(8))
        X, y = ds.X_train, ds.y_train
        n = (X.shape[0] // 2) * 2
        X, y = X[:n], y[:n]
        lam = float(lambda_max(X, y)) / 16
        opts = DGLMNETOptions(num_blocks=2, tile=16, max_iters=40)
        mesh = make_dev_mesh(2, 4)
        inner = BucketedSlabDesign.from_by_feature(to_by_feature(X), dp=2)
        assert len(inner.slabs.buckets) >= 2
        res = LogisticL1(opts=opts).fit(
            ShardedDesign(inner, mesh, tile=16), y, lam)
        ref = LogisticL1(opts=opts).fit(DenseDesign(X), y, lam)
        assert abs(res.f - ref.f) / abs(ref.f) < 1e-4, (res.f, ref.f)
        # the bucket permutation changes the feature-block partition, so
        # individual near-zero coefficients can drift ~1e-3 while the
        # objective agrees to 1e-4
        np.testing.assert_allclose(np.asarray(res.beta), np.asarray(ref.beta),
                                   rtol=1e-2, atol=3e-3)
        print('OK bucketed mesh fit')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
