"""Golden fixture: trips psum-axis and nothing else.

The shard_map body psums over ``"feature"`` but the decoration only ever
declares ``"model"`` — the collective would fail (or silently reduce the
wrong axis after a rename) at run time.
"""
from functools import partial

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

mesh = None  # stand-in: the rule is static and never builds a Mesh


@partial(shard_map, mesh=mesh, in_specs=(P("model"),), out_specs=P("model"))
def block_sum(x):
    return jax.lax.psum(x, "feature")
