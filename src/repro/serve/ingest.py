"""Hashed sparse-feature ingestion: live requests -> by-feature slabs.

Online traffic arrives as sparse token->value maps over an unbounded
vocabulary; the fitted model lives on a fixed ``p``-dimensional feature
axis. The bridge is the classic hashing trick, made *deterministic* so a
request scores identically across processes and restarts:

* :func:`hash_token` is CRC-32 (not Python's per-process-salted ``hash``),
  so ``token -> index`` is stable across interpreter launches;
* colliding tokens have their values **summed in sorted-token order**
  (:func:`encode_request`), so the collided value is independent of the
  caller's dict insertion order;
* exact-zero values are dropped at encode time — an all-zero request packs
  identically to an empty one (both are all-sentinel slabs that score 0).

:func:`pack_requests` then packs a batch of encoded requests into the
repo's by-feature ``(p, DP, K)`` slab layout (paper Table 1, request rows
playing the example axis): the SAME layout the training kernels consume,
so batched scoring is one ``kernels.ops.slab_path_spmv`` dispatch —
locally or per mesh shard — with no densify and no per-request loop.
Shapes are quantized (power-of-two K classes, fixed batch capacity) so a
serving process compiles a handful of programs, not one per batch.
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence, Tuple, Union

import numpy as np

Request = Union[Mapping[str, float], Iterable[Tuple[str, float]]]


class InvalidRequest(ValueError):
    """A request that can never score correctly: non-finite feature
    values, or hashed indices outside the store's feature axis. Typed (a
    ``ValueError`` subclass, so pre-existing handlers still catch it) so
    the serve loop can count rejections instead of packing garbage."""


def hash_token(token: str, p: int) -> int:
    """Deterministic token -> feature index in [0, p): CRC-32 of the
    UTF-8 bytes, reduced mod ``p``. Stable across processes (unlike
    builtin ``hash``, which is salted per interpreter)."""
    return zlib.crc32(token.encode("utf-8")) % p


def encode_request(request: Request, p: int) -> Tuple[np.ndarray, np.ndarray]:
    """One request -> sorted ``(idx, val)`` arrays on the hashed axis.

    Colliding tokens sum in sorted-token order (determinism under dict
    reordering); exact-zero accumulated values are dropped so empty and
    all-zero requests encode identically (no live slots)."""
    items = request.items() if isinstance(request, Mapping) else request
    acc: dict = {}
    for token, value in sorted(items, key=lambda kv: kv[0]):
        v = float(value)
        if not math.isfinite(v):
            raise InvalidRequest(
                f"non-finite value {v!r} for token {token!r}: refusing to "
                f"encode (a single NaN would poison the whole scoring batch)"
            )
        j = hash_token(token, p)
        acc[j] = acc.get(j, 0.0) + v
    idx = np.asarray(sorted(j for j in acc if acc[j] != 0.0), np.int64)
    val = np.asarray([acc[j] for j in idx], np.float32)
    return idx, val


def k_capacity(k_need: int, *, k_min: int = 8) -> int:
    """Power-of-two slab-capacity class (the serving twin of
    ``data.byfeature.k_class``, with no global K ceiling): bounds the
    number of distinct compiled scoring shapes to O(log K)."""
    cap = max(k_min, 1)
    while cap < k_need:
        cap *= 2
    return cap


@dataclass(frozen=True)
class PackedBatch:
    """A request batch in mesh-ready slab form.

    ``row_idx``/``values`` are ``(p_pad, DP, K)`` by-feature slabs whose
    "examples" are the batch's request rows, split into ``DP`` contiguous
    shards of ``n_loc = batch_cap // DP`` local rows (sentinel ``n_loc``)
    — exactly the operand layout of ``core.distributed.make_slab_margins``
    and the serve scoring steps. Rows >= ``n_live`` are padding (all-
    sentinel; they score 0 and are trimmed before scores leave the
    scorer).
    """

    row_idx: np.ndarray          # (p_pad, DP, K) int32
    values: np.ndarray           # (p_pad, DP, K) float32
    n_live: int                  # real requests in the batch
    batch_cap: int               # padded batch extent (= DP * n_loc)
    p: int                       # original (unpadded) feature count

    @property
    def dp(self) -> int:
        return int(self.row_idx.shape[1])

    @property
    def n_loc(self) -> int:
        return self.batch_cap // max(self.dp, 1)

    @property
    def p_pad(self) -> int:
        return int(self.row_idx.shape[0])


def pack_requests(
    encoded: Sequence[Tuple[np.ndarray, np.ndarray]],
    p: int,
    *,
    batch_cap: int = None,
    dp: int = 1,
    pad_p_to: int = 1,
    k_min: int = 8,
) -> PackedBatch:
    """Pack encoded requests into a :class:`PackedBatch`.

    ``batch_cap`` (default: the batch size rounded up to ``dp``) fixes the
    padded request extent; ``pad_p_to`` rounds the feature axis up (mesh
    stores pass ``model_dim * tile`` so the slab partition lines up with
    the P(model)-sharded coefficient stack); ``k_min`` floors the
    power-of-two K class. Slabs are front-packed (live slots first, rows
    ascending within a feature) — the same invariant the training layout
    guarantees.
    """
    b = len(encoded)
    if batch_cap is None:
        batch_cap = max(b, 1)
    batch_cap += (-batch_cap) % max(dp, 1)
    if b > batch_cap:
        raise ValueError(f"{b} requests exceed batch_cap={batch_cap}")
    if batch_cap % dp:
        raise ValueError(f"dp={dp} must divide batch_cap={batch_cap}")
    n_loc = batch_cap // dp
    p_pad = p + (-p) % max(pad_p_to, 1)

    if b:
        feats = np.concatenate([idx for idx, _ in encoded])
        vals = np.concatenate([val for _, val in encoded])
        rows = np.concatenate([
            np.full(len(idx), i, np.int64) for i, (idx, _) in enumerate(encoded)
        ])
    else:
        feats = rows = np.zeros(0, np.int64)
        vals = np.zeros(0, np.float32)
    if feats.size and (feats.min() < 0 or feats.max() >= p):
        raise InvalidRequest(f"hashed index out of range [0, {p})")

    shard = rows // max(n_loc, 1)
    loc = rows - shard * n_loc
    # rank of each entry within its (feature, shard) group — the same
    # stable-sort construction as data.byfeature._regroup_slabs, so the
    # packed slabs carry the training layout's front-packing invariant
    group = feats * dp + shard
    counts = np.bincount(group, minlength=p * dp)
    order = np.argsort(group, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    rank = np.arange(order.size) - starts[group[order]]

    k = k_capacity(int(counts.max()) if counts.size else 1, k_min=k_min)
    row_idx = np.full((p_pad, dp, k), n_loc, np.int32)
    values = np.zeros((p_pad, dp, k), np.float32)
    g = group[order]
    row_idx[g // dp, g % dp, rank] = loc[order]
    values[g // dp, g % dp, rank] = vals[order]
    return PackedBatch(row_idx=row_idx, values=values, n_live=b,
                       batch_cap=batch_cap, p=p)
