"""Serving layer + PathResult API: typed path results round-trip through
checkpoints, hashed ingestion is deterministic, and batched path scoring
is bit-identical to ``LogisticL1.decision_function`` — locally and (slow
lane, subprocess fake devices) on a 2x4 mesh. Hot-swap must never mix two
path versions inside one batch."""
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import LogisticL1, PathPoint, PathResult, SlabDesign
from repro.serve import (
    PathScorer,
    PathStore,
    RequestBatcher,
    batch_capacity,
    encode_request,
    hash_token,
    k_capacity,
    pack_requests,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


def _problem(seed=0, n=64, p=24, density=0.2):
    rng = np.random.default_rng(seed)
    X = ((rng.random((n, p)) < density)
         * rng.normal(size=(n, p))).astype(np.float32)
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    return X, y


def _tokens_for(p):
    """One token per column that hashes exactly to that column."""
    toks = {}
    for j in range(p):
        t = 0
        while hash_token(f"tok{j}_{t}", p) != j:
            t += 1
        toks[j] = f"tok{j}_{t}"
    return toks


def _requests_from_rows(X, toks):
    return [{toks[j]: float(X[i, j]) for j in range(X.shape[1])
             if X[i, j] != 0.0} for i in range(X.shape[0])]


@pytest.fixture(scope="module")
def fitted():
    X, y = _problem()
    est = LogisticL1()
    path = est.path(X, y, path_len=5)
    return X, y, est, path


# ---------------------------------------------------------------------------
# PathResult typed API + back-compat
# ---------------------------------------------------------------------------

def test_pathresult_type_and_backcompat(fitted):
    _, _, _, path = fitted
    assert isinstance(path, PathResult)
    assert len(path) == 5
    assert path.betas.shape == (5, 24)
    assert path.lambdas.shape == (5,)
    # descending geometric grid
    assert np.all(np.diff(path.lambdas) < 0)
    # list-of-PathPoint protocol the pre-PathResult call sites used
    pts = list(path)
    assert len(pts) == 5 and all(isinstance(q, PathPoint) for q in pts)
    assert isinstance(path[0], PathPoint)
    assert path[-1].lam == pts[-1].lam          # negative indexing
    assert [q.lam for q in path[1:3]] == [pts[1].lam, pts[2].lam]
    with pytest.raises(IndexError):
        path[5]
    # stacked rows == per-point betas, per-point scalars == stacked arrays
    for i, q in enumerate(pts):
        assert np.array_equal(np.asarray(path.betas[i]), np.asarray(q.beta))
        assert path.nnz[i] == q.nnz
        assert path.lambdas[i] == q.lam


def test_pathresult_index_of(fitted):
    _, _, _, path = fitted
    for i, lam in enumerate(path.lambdas):
        assert path.index_of(float(lam)) == i
        # log-nearest: a point 10% off still resolves to the same index
        assert path.index_of(float(lam) * 1.1) == i
    assert path.index_of(0.0) == len(path) - 1      # clamps, no -inf blowup


def test_pathresult_save_load_roundtrip(fitted, tmp_path):
    _, _, _, path = fitted
    d = str(tmp_path / "ckpt")
    path.save(d)
    loaded = PathResult.load(d)
    assert np.array_equal(np.asarray(loaded.betas), np.asarray(path.betas))
    assert np.array_equal(loaded.lambdas, path.lambdas)
    assert np.array_equal(loaded.nnz, path.nnz)
    assert np.array_equal(loaded.f, path.f)
    assert np.array_equal(loaded.n_iters, path.n_iters)
    assert len(loaded.metrics) == len(path.metrics)
    assert len(loaded.screen) == len(path.screen)
    # screen telemetry survives the JSON manifest with its values intact
    for a, b in zip(loaded.screen, path.screen):
        assert set(a) == set(b)
        for k in a:
            assert np.isclose(float(a[k]), float(b[k]))


def test_pathstore_from_checkpoint_serves(fitted, tmp_path):
    X, _, _, path = fitted
    d = str(tmp_path / "ckpt")
    path.save(d)
    store = PathStore.from_checkpoint(d)
    assert store.snapshot.p == X.shape[1]
    assert store.version == 1


# ---------------------------------------------------------------------------
# sklearn surface
# ---------------------------------------------------------------------------

def test_sklearn_surface(fitted):
    X, y, est, path = fitted
    # allow[nonfinite-guard]: sklearn-surface oracle on a healthy fit, not served output; sign test below would fail on NaN anyway
    scores = np.asarray(est.decision_function(X))
    pred = np.asarray(est.predict(X))
    assert set(np.unique(pred)) <= {-1.0, 1.0}
    assert np.array_equal(pred, np.where(scores >= 0.0, 1.0, -1.0))
    assert np.array_equal(np.asarray(est.coef_), np.asarray(est.beta_))
    assert est.intercept_ == 0.0                 # paper model has no bias
    params = est.get_params()
    assert set(params) == {"opts", "mesh", "warm_start"}
    est2 = LogisticL1(**params)
    assert est2.get_params() == params
    est2.set_params(warm_start=False)
    assert est2.warm_start is False
    with pytest.raises(ValueError):
        est2.set_params(no_such_param=1)


# ---------------------------------------------------------------------------
# hashed ingestion
# ---------------------------------------------------------------------------

def test_hashing_deterministic_and_order_free():
    p = 97
    # CRC32 is process-stable: pin a few values so a hash change is loud
    assert hash_token("hello", p) == (0x3610A686 % p)
    i1, v1 = encode_request({"a": 1.0, "b": 2.0, "c": 3.0}, p)
    i2, v2 = encode_request([("c", 3.0), ("a", 1.0), ("b", 2.0)], p)
    assert np.array_equal(i1, i2) and np.array_equal(v1, v2)


def test_hash_collisions_sum_in_sorted_token_order():
    # find two tokens that collide at small p
    p = 3
    toks = ["t%d" % i for i in range(50)]
    by_idx = {}
    for t in toks:
        by_idx.setdefault(hash_token(t, p), []).append(t)
    idx, pair = next((j, ts) for j, ts in by_idx.items() if len(ts) >= 2)
    a, b = pair[0], pair[1]
    i1, v1 = encode_request({a: 0.25, b: 0.5}, p)
    i2, v2 = encode_request({b: 0.5, a: 0.25}, p)
    assert np.array_equal(i1, i2) and np.array_equal(v1, v2)
    assert idx in i1
    assert v1[list(i1).index(idx)] == np.float32(0.75)


def test_empty_and_all_zero_requests():
    p = 16
    ei, ev = encode_request({}, p)
    zi, zv = encode_request({"x": 0.0, "y": 0.0}, p)
    assert ei.size == 0 and zi.size == 0
    # cancelling collision -> dropped slot too
    pcol = 3
    by_idx = {}
    for t in ["t%d" % i for i in range(50)]:
        by_idx.setdefault(hash_token(t, pcol), []).append(t)
    a, b = next(ts for ts in by_idx.values() if len(ts) >= 2)[:2]
    ci, _ = encode_request({a: 1.0, b: -1.0}, pcol)
    assert ci.size == 0
    batch = pack_requests([(ei, ev), (zi, zv)], p)
    assert batch.n_live == 2
    assert np.all(batch.row_idx == batch.n_loc)      # all-sentinel slabs
    scores, _ = PathScorer(PathStore(_tiny_path(p))).score(
        batch, np.ones(2))
    assert np.array_equal(scores, np.zeros(2, np.float32))


def _tiny_path(p):
    return PathResult(
        lambdas=np.asarray([1.0, 0.5]),
        betas=jnp.asarray(np.random.default_rng(3).normal(size=(2, p)),
                          jnp.float32),
        nnz=np.asarray([p, p]), f=np.zeros(2), n_iters=np.ones(2, np.int64),
        metrics=[{}, {}], screen=[{}, {}])


def test_capacity_classes():
    assert k_capacity(0) == 8 and k_capacity(8) == 8 and k_capacity(9) == 16
    assert batch_capacity(1) == 8
    assert batch_capacity(65) == 128
    assert batch_capacity(10_000, b_max=256) == 256


def test_pack_requests_front_packed_and_bounded():
    p = 8
    rng = np.random.default_rng(7)
    encoded = []
    for _ in range(10):
        k = rng.integers(0, 5)
        idx = np.sort(rng.choice(p, size=k, replace=False)).astype(np.int64)
        encoded.append((idx, rng.normal(size=k).astype(np.float32)))
    batch = pack_requests(encoded, p, dp=2)
    assert batch.dp == 2 and batch.batch_cap % 2 == 0
    live = batch.row_idx < batch.n_loc
    # front-packed: live slots precede sentinels in every (feature, shard)
    runs = live.cumsum(axis=-1)
    assert np.all(live[..., 1:] <= live[..., :-1])
    # every nonzero lands where its request row put it
    total = sum(len(i) for i, _ in encoded)
    assert int(live.sum()) == total
    assert int(runs[..., -1].max()) <= batch.row_idx.shape[2]


# ---------------------------------------------------------------------------
# served scores == decision_function (the acceptance bit)
# ---------------------------------------------------------------------------

def test_served_scores_bit_equal_decision_function(fitted):
    X, _, est, path = fitted
    n, p = X.shape
    toks = _tokens_for(p)
    reqs = _requests_from_rows(X, toks)
    store = PathStore(path)
    scorer = PathScorer(store)
    batcher = RequestBatcher(p, max_batch=128)
    for i, r in enumerate(reqs):
        batcher.submit(r, float(path.lambdas[i % len(path)]))
    batch, lams = batcher.drain()
    assert batch.n_live == n
    design = SlabDesign(jnp.asarray(batch.row_idx),
                        jnp.asarray(batch.values), batch.batch_cap)
    for l in range(len(path)):
        got, ver = scorer.score(batch, np.full(n, path.lambdas[l]))
        # allow[nonfinite-guard]: decision_function is the reference oracle; the served side of the bit-equality IS the guarded path
        ref = np.asarray(
            est.decision_function(design, beta=path.betas[l]))[:n]
        assert np.array_equal(got, ref), f"lambda index {l}"
        assert ver == store.version
    # mixed-lambda batch: each row equals its row in the uniform run
    mixed, _ = scorer.score(batch, lams)
    for l in range(len(path)):
        uni, _ = scorer.score(batch, np.full(n, path.lambdas[l]))
        rows = [i for i in range(n) if i % len(path) == l]
        assert np.array_equal(mixed[rows], uni[rows])


def test_scorer_validates_geometry(fitted):
    X, _, _, path = fitted
    p = X.shape[1]
    scorer = PathScorer(PathStore(path))
    batch = pack_requests([encode_request({"a": 1.0}, p)], p)
    with pytest.raises(ValueError):
        scorer.score(batch, np.ones(2))          # lam count != n_live
    wrong = pack_requests([encode_request({"a": 1.0}, p + 1)], p + 1)
    with pytest.raises(ValueError):
        scorer.score(wrong, np.ones(1))          # hashed to the wrong p


def test_hot_swap_never_mixes_versions(fitted):
    """Concurrent swaps during a scoring loop: every batch's scores must
    equal ONE version's reference scores end-to-end — never a blend."""
    X, _, _, path = fitted
    n, p = X.shape
    toks = _tokens_for(p)
    batch = pack_requests(
        [encode_request(r, p) for r in _requests_from_rows(X, toks)], p)
    lams = np.full(n, float(path.lambdas[-1]))

    flip = PathResult(
        lambdas=path.lambdas, betas=-path.betas, nnz=path.nnz, f=path.f,
        n_iters=path.n_iters, metrics=path.metrics, screen=path.screen)
    store = PathStore(path)
    scorer = PathScorer(store)
    ref = {1: scorer.score(batch, lams)[0]}
    store.swap(flip)
    ref[2] = scorer.score(batch, lams)[0]
    assert not np.array_equal(ref[1], ref[2])
    versions = [path, flip]

    stop = threading.Event()

    def swapper():
        i = 0
        while not stop.is_set():
            store.swap(versions[i % 2])
            i += 1

    t = threading.Thread(target=swapper)
    t.start()
    try:
        for _ in range(40):
            got, ver = scorer.score(batch, lams)
            want = ref[1] if ver % 2 == 1 else ref[2]
            assert np.array_equal(got, want), (
                "batch blended two coefficient versions")
    finally:
        stop.set()
        t.join()


def test_swap_releases_old_coefficients(fitted):
    """Regression for the module-lifetime path-margins cache: the store
    deliberately pins ONE retired snapshot (the last-good quarantine
    fallback), so after two swaps the twice-retired snapshot and its
    device coefficient stack must be collectible — nothing (jit dispatch
    caches included) may pin them beyond that single-slot budget.
    Numpy-backed PathResults make the store own distinct device arrays,
    so the weakrefs below watch store-owned memory, not test locals."""
    import gc
    import weakref

    X, _, _, path = fitted
    p = X.shape[1]

    def np_version(sign):
        return PathResult(
            lambdas=path.lambdas, betas=np.asarray(sign * path.betas),
            nnz=path.nnz, f=path.f, n_iters=path.n_iters,
            metrics=path.metrics, screen=path.screen)

    store = PathStore(np_version(1.0))
    scorer = PathScorer(store)
    batch = pack_requests([encode_request({"a": 1.0}, p)], p)
    lams = np.full(1, float(path.lambdas[0]))
    scorer.score(batch, lams)

    s0 = store.snapshot
    refs = weakref.ref(s0), weakref.ref(s0.betas)
    store.swap(np_version(-1.0))
    gc.collect()
    assert refs[0]() is not None, "last-good snapshot dropped too early"
    store.swap(np_version(0.5))   # v1 falls off the one-deep prev slot
    scorer.score(batch, lams)     # rebinds the dispatch's last-call caches
    del s0
    gc.collect()
    assert refs[0]() is None, "retired StoreSnapshot still pinned"
    assert refs[1]() is None, "retired coefficient stack still on device"


# ---------------------------------------------------------------------------
# mesh lane (subprocess fake devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_mesh_bit_identity_and_sharded_roundtrip(tmp_path):
    """2x4 mesh: P(model)-sharded store scores bit-equal to the sharded
    decision_function; a checkpoint loaded with an explicit sharding
    serves identically."""
    d = str(tmp_path / "ckpt")
    r = _run(f"""
        import numpy as np
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.api import (LogisticL1, PathResult, ShardedDesign,
                               SlabDesign)
        from repro.launch.mesh import make_dev_mesh
        from repro.serve import (PathScorer, PathStore, RequestBatcher,
                                 hash_token)

        mesh = make_dev_mesh(2, 4)
        rng = np.random.default_rng(1)
        n, p, tile = 64, 24, 8
        X = ((rng.random((n, p)) < 0.25)
             * rng.normal(size=(n, p))).astype(np.float32)
        y = np.where(rng.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
        est = LogisticL1(mesh=mesh)
        path = est.path(X, y, path_len=4)
        path.save({d!r})

        store = PathStore(path, mesh=mesh, tile=tile)
        scorer = PathScorer(store)
        toks = {{}}
        for j in range(p):
            t = 0
            while hash_token(f't{{j}}_{{t}}', p) != j:
                t += 1
            toks[j] = f't{{j}}_{{t}}'
        b = RequestBatcher(p, max_batch=128, dp=2,
                           pad_p_to=store.pad_p_to)
        for i in range(n):
            b.submit({{toks[j]: float(X[i, j]) for j in range(p)
                      if X[i, j] != 0.0}},
                     float(path.lambdas[i % len(path)]))
        batch, lams = b.drain()
        assert batch.n_live == n and batch.dp == 2

        inner = SlabDesign(jnp.asarray(batch.row_idx),
                           jnp.asarray(batch.values), batch.batch_cap)
        sd = ShardedDesign(inner, mesh, tile=tile)
        for l in range(len(path)):
            beta = jnp.pad(path.betas[l], (0, batch.p_pad - p))
            ref = np.asarray(est.decision_function(sd, beta=beta))[:n]
            got, _ = scorer.score(batch, np.full(n, path.lambdas[l]))
            assert np.array_equal(got, ref), f'lambda {{l}}'

        # sharded checkpoint load: betas land P(None, model) and serve
        # bit-identically to the local store
        sharding = NamedSharding(mesh, P(None, 'model'))
        loaded = PathResult.load({d!r}, sharding=sharding)
        assert np.array_equal(np.asarray(loaded.betas),
                              np.asarray(path.betas))
        store2 = PathStore(loaded, mesh=mesh, tile=tile)
        s2 = PathScorer(store2)
        got1, _ = scorer.score(batch, lams)
        got2, _ = s2.score(batch, lams)
        assert np.array_equal(got1, got2)
        print('MESH-SERVE-OK')
    """)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH-SERVE-OK" in r.stdout
