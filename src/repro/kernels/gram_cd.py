"""Pallas TPU kernel: one cyclic coordinate-descent cycle on a Gram tile.

This is the sequential heart of d-GLMNET's Algorithm 2, restructured for the
TPU memory hierarchy (DESIGN.md §2.3): the caller computes
G = X_F^T diag(w) X_F and c = X_F^T (w r) with MXU matmuls; this kernel then
runs the O(F^2) sequential soft-threshold sweep entirely inside VMEM — the
serial chain never touches HBM or the examples axis.

VMEM budget at F=512, f32: G 1 MiB + 5 vectors ~10 KiB — far under the
~128 MiB/core v5e budget; F is kept 128-aligned for lane efficiency.

Target: pl.pallas_call with explicit BlockSpecs; validated on CPU with
interpret=True against ``ref.gram_cd_ref`` (= core.subproblem oracle).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import out_shape_struct


def _gram_cd_kernel(scal_ref, G_ref, c_ref, beta_ref, dbeta0_ref, d_ref, s_ref):
    """Refs: scal (1,2)=[lam,nu] SMEM; G (F,F); c/beta/dbeta0 (1,F) VMEM;
    out d (1,F); scratch s (1,F) = G @ d maintained incrementally."""
    f = G_ref.shape[0]
    lam = scal_ref[0, 0]
    nu = scal_ref[0, 1]

    d_ref[...] = jnp.zeros_like(d_ref)
    s_ref[...] = jnp.zeros_like(s_ref)

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, f), 1)

    def body(j, _):
        onehot = (lane == j).astype(jnp.float32)              # (1, F)
        # scalar reads via masked reductions (lane-friendly on TPU)
        g = jnp.sum((c_ref[...] - s_ref[...]) * onehot)
        g_row = pl.load(G_ref, (pl.ds(j, 1), slice(None)))    # (1, F)
        h = jnp.sum(g_row * onehot) + nu                      # G[j,j] + nu
        b_old = jnp.sum((beta_ref[...] + dbeta0_ref[...] + d_ref[...]) * onehot)
        u = g + b_old * h
        b_new = jnp.sign(u) * jnp.maximum(jnp.abs(u) - lam, 0.0) / h
        delta = b_new - b_old
        s_ref[...] = s_ref[...] + delta * g_row               # s += delta * G[:,j] (G symmetric)
        d_ref[...] = d_ref[...] + delta * onehot
        return 0

    jax.lax.fori_loop(0, f, body, 0)


@partial(jax.jit, static_argnames=("interpret",))
def gram_cd_pallas(G, c, beta, dbeta0, lam, nu, *, interpret: bool = True):
    """Returns d such that dbeta <- dbeta0 + d (one CD cycle on the tile)."""
    f = G.shape[0]
    assert G.shape == (f, f) and c.shape == (f,)
    scal = jnp.stack([jnp.asarray(lam, jnp.float32), jnp.asarray(nu, jnp.float32)])[None]
    # under shard_map(check_vma=True) the out_shape must carry the varying
    # mesh axes; outputs vary like (c, beta, dbeta0) jointly. Older JAX has
    # no vma typing — the compat helper degrades to a plain struct there.
    out_shape = out_shape_struct((1, f), jnp.float32, operands=(c, beta, dbeta0, G))
    out = pl.pallas_call(
        _gram_cd_kernel,
        grid=(),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # scalars
            pl.BlockSpec((f, f), lambda: (0, 0)),             # G in VMEM
            pl.BlockSpec((1, f), lambda: (0, 0)),
            pl.BlockSpec((1, f), lambda: (0, 0)),
            pl.BlockSpec((1, f), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, f), lambda: (0, 0)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, f), jnp.float32)],
        interpret=interpret,
    )(scal, G.astype(jnp.float32), c.astype(jnp.float32)[None],
      beta.astype(jnp.float32)[None], dbeta0.astype(jnp.float32)[None])
    return out[0]
