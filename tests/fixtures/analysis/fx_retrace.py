"""Golden fixture: trips retrace-hazard and nothing else.

An unbounded ``lru_cache`` in a JAX module pins its keys and values
(meshes, compiled programs, device arrays) for the process lifetime.
"""
from functools import lru_cache

import jax  # noqa: F401  (the rule only inspects JAX-importing modules)


@lru_cache(maxsize=None)
def cached_program(key):
    return key
