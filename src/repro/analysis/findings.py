"""Finding record + pragma / allowlist suppression logic."""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

#: per-line pragma: ``# allow[rule-id]: one-line justification``
#: A pragma without a justification does NOT suppress — every allowlist
#: entry must say why (the acceptance bar for the whole suite).
PRAGMA_RE = re.compile(r"#\s*allow\[(?P<rule>[a-z0-9-]+)\]\s*:\s*(?P<why>\S.*)?$")
PRAGMA_ANY_RE = re.compile(r"#\s*allow\[")


@dataclass(frozen=True)
class Finding:
    file: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line} · {self.rule} · {self.message}"

    def as_json(self) -> dict:
        return {"file": self.file, "line": self.line, "rule": self.rule,
                "message": self.message}


@dataclass
class AllowEntry:
    rule: str
    path: str            # fnmatch glob over posix-relative paths
    reason: str
    line: Optional[int] = None
    used: bool = field(default=False, compare=False)

    def matches(self, f: Finding) -> bool:
        if self.rule not in ("*", f.rule):
            return False
        if not fnmatch.fnmatch(f.file, self.path):
            return False
        return self.line is None or self.line == f.line


class Suppressions:
    """Combined per-line pragmas + file-level allowlist."""

    def __init__(self, entries: Sequence[AllowEntry] = ()):
        self.entries = list(entries)
        self.bad_pragmas: List[Finding] = []

    @staticmethod
    def load_toml(path: str) -> List[AllowEntry]:
        try:
            import tomllib  # py >= 3.11
        except ImportError:  # pragma: no cover - py3.10 container
            import tomli as tomllib
        with open(path, "rb") as fh:
            doc = tomllib.load(fh)
        entries = []
        for raw in doc.get("allow", []):
            if not raw.get("reason", "").strip():
                raise SystemExit(
                    f"{path}: allowlist entry {raw!r} has no reason — every "
                    f"entry must carry a one-line justification"
                )
            entries.append(AllowEntry(
                rule=raw.get("rule", "*"), path=raw.get("path", "*"),
                reason=raw["reason"], line=raw.get("line"),
            ))
        return entries

    def _pragma_allows(self, module_lines: List[str], f: Finding) -> bool:
        """Same-line pragma, or a standalone comment line directly above."""
        candidates = []
        if 1 <= f.line <= len(module_lines):
            candidates.append(module_lines[f.line - 1])
            if f.line >= 2 and module_lines[f.line - 2].lstrip().startswith("#"):
                candidates.append(module_lines[f.line - 2])
        for text in candidates:
            if not PRAGMA_ANY_RE.search(text):
                continue
            m = PRAGMA_RE.search(text)
            if m and m.group("rule") == f.rule:
                if m.group("why"):
                    return True
                self.bad_pragmas.append(Finding(
                    file=f.file, line=f.line, rule="bad-pragma",
                    message=(f"allow[{f.rule}] pragma without a "
                             f"justification — add one after the colon"),
                ))
        return False

    def filter(
        self, findings: Sequence[Finding],
        lines_by_file: Dict[str, List[str]],
    ) -> tuple[List[Finding], List[Finding]]:
        """-> (kept, suppressed). bad-pragma findings are appended to kept."""
        kept: List[Finding] = []
        suppressed: List[Finding] = []
        for f in findings:
            entry = next((e for e in self.entries if e.matches(f)), None)
            if entry is not None:
                entry.used = True
                suppressed.append(f)
                continue
            if self._pragma_allows(lines_by_file.get(f.file, []), f):
                suppressed.append(f)
                continue
            kept.append(f)
        kept.extend(self.bad_pragmas)
        return kept, suppressed
