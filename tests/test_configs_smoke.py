"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (<=2 layers, d_model<=512, <=4 experts) runs one forward and
one train step on CPU; output shapes asserted, no NaNs. The FULL configs are
exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, MODEL_CONFIGS
from repro.models import forward, init_cache, init_params
from repro.train import make_train_state, make_train_step

# ~45 s of LLM-config smokes, disjoint from the GLM core the fast lane
# gates on — the CI slow lane runs them on every PR.
pytestmark = pytest.mark.slow
from repro.train.train_step import IGNORE


def _batch(cfg, b=2, s=32, rng=None):
    rng = rng or np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.encdec.enabled:
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((b, 16, cfg.frontend.embed_dim)), jnp.float32)
    elif cfg.frontend.kind != "none":
        p = cfg.frontend.tokens_per_item
        key = "patch_embeds" if cfg.frontend.kind == "vision_patches" else "frame_embeds"
        batch[key] = jnp.asarray(
            rng.standard_normal((b, p, cfg.frontend.embed_dim)), jnp.float32)
        batch["labels"] = jnp.concatenate(
            [jnp.full((b, p), IGNORE, jnp.int32), labels], axis=1)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = MODEL_CONFIGS[arch].smoke()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert (not cfg.moe.enabled) or cfg.moe.num_experts <= 4
    params = init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    logits, _, _ = forward(params, batch, cfg, mode="train")
    s_total = batch["labels"].shape[1] if not cfg.encdec.enabled else batch["tokens"].shape[1]
    assert logits.shape == (2, s_total, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = MODEL_CONFIGS[arch].smoke()
    state = make_train_state(jax.random.key(0), cfg)
    step = jax.jit(make_train_step(cfg))
    state, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert int(state["step"]) == 1
    # params actually changed
    l0 = jax.tree.leaves(state["params"])[0]
    assert np.isfinite(np.asarray(l0, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = MODEL_CONFIGS[arch].smoke()
    params = init_params(jax.random.key(0), cfg)
    cache = init_cache(cfg, 2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, new_cache, _ = forward(
        params, {"tokens": tok}, cfg, mode="decode", cache=cache,
        cache_index=jnp.asarray(3, jnp.int32))
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)
