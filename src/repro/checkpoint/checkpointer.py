"""Dependency-free pytree checkpointer (no orbax in this environment).

Layout: <dir>/manifest.json  (treedef + leaf paths + dtypes/shapes)
        <dir>/arrays.npz     (leaf arrays keyed by sanitized path)

Restore is sharding-aware: pass ``shardings`` (a matching pytree of
NamedSharding / PartitionSpec under a mesh context) to place leaves as they
load — sufficient for single-host multi-device; a multi-host variant would
stream per-shard files, noted in DESIGN.md.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _keys(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(p) for p, _ in flat]
    return flat, treedef, names


def save_pytree(tree: Any, directory: str, *, step: Optional[int] = None,
                meta: Optional[dict] = None) -> str:
    """``meta`` is an optional JSON-serializable side channel stored in the
    manifest (read back via :func:`read_meta`) — for the non-array context
    a checkpoint consumer needs to rebuild itself (e.g. the per-lambda
    telemetry of a persisted regularization path)."""
    os.makedirs(directory, exist_ok=True)
    flat, _, names = _keys(tree)
    arrays = {}
    manifest = {"leaves": [], "step": step}
    if meta is not None:
        manifest["meta"] = meta
    for name, (_, leaf) in zip(names, flat):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # npz cannot serialize ml_dtypes
            arr = arr.astype(np.float32)
        key = f"leaf_{len(arrays)}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": name, "key": key, "dtype": dtype_name, "shape": list(arr.shape)}
        )
    np.savez(os.path.join(directory, "arrays.npz"), **arrays)
    with open(os.path.join(directory, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return directory


def read_meta(directory: str) -> Optional[dict]:
    """The ``meta`` dict stored by :func:`save_pytree`, or None."""
    with open(os.path.join(directory, "manifest.json")) as f:
        return json.load(f).get("meta")


def load_pytree(directory: str, like: Any, *, shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (paths must match)."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, "arrays.npz"))
    by_path = {e["path"]: data[e["key"]] for e in manifest["leaves"]}

    flat, treedef, names = _keys(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec") or hasattr(x, "_partitions")
        )[0]
    leaves = []
    for i, (name, (_, leaf)) in enumerate(zip(names, flat)):
        if name not in by_path:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = by_path[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {name}: {arr.shape} vs {leaf.shape}")
        out = jnp.asarray(arr, dtype=leaf.dtype)
        if shard_flat is not None and shard_flat[i] is not None:
            out = jax.device_put(out, shard_flat[i])
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)
