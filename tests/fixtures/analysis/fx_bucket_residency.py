"""Golden fixture: trips bucket-residency and nothing else.

A raw ``jax.device_put`` of slab arrays in a mesh-aware module bypasses
the residency budget — it must route through
``repro.data.residency.put_slab`` (or the ``BucketResidencyManager`` for
work buckets).
"""
import jax
from jax.sharding import Mesh  # noqa: F401  (marks the module mesh-aware)


def place_slab(row_idx, sharding):
    return jax.device_put(row_idx, sharding)
