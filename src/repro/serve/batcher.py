"""Request batching for the serving loop.

``RequestBatcher`` accumulates live requests (hashed-token feature maps +
a requested lambda each) and drains them as one :class:`PackedBatch` per
scoring dispatch. Two shape-bounding rules keep the compiled-program count
small over a serving process's lifetime:

* the batch extent is quantized to power-of-two capacity classes
  (:func:`batch_capacity`) up to ``max_batch``, mirroring the slab-K
  classes of :func:`~repro.serve.ingest.k_capacity`;
* hashing/encoding happens at ``submit`` time (spreading the host work
  across arrivals), packing at ``drain`` time (one vectorized pass).

The queue is *bounded*: ``max_pending`` caps admission (``submit`` raises
:class:`Overloaded` instead of growing without limit under a stalled
drainer), and each request carries an optional deadline on an injectable
monotonic clock — expired requests are shed at drain time rather than
scored late. Rejections and sheds are counted in :attr:`RequestBatcher.
stats` so the serve loop can export backpressure telemetry instead of
dying by memory or serving answers nobody is waiting for.

Observability rides the same path without changing it: every request
records its submit timestamp, and the serve loop's :meth:`RequestBatcher.
mark_scored` call (right after the scorer hands back host scores) feeds
a submit->score ``serve.latency_s`` histogram on the active ``repro.obs``
registry, with the live queue depth exported as a gauge. The legacy
:attr:`RequestBatcher.stats` dict is bit-identical with or without a
registry — it is mirrored read-only, never rewritten.

Lambdas stay raw floats until scoring: ``PathScorer`` resolves them
against the snapshot it scores with, so a hot-swap that re-grids the path
re-resolves naturally instead of serving stale indices.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.obs import registry as obs_registry
from repro.obs import trace as obs_trace
from repro.serve.ingest import InvalidRequest, PackedBatch, Request, \
    encode_request, pack_requests


class Overloaded(RuntimeError):
    """The batcher's pending queue is at ``max_pending``. Callers should
    shed the request (count it, tell the client to retry) — admission
    control is the bound that keeps a stalled drainer from turning into
    unbounded host memory growth."""


def _check_pow2(name: str, value: int) -> None:
    if value < 1 or (value & (value - 1)):
        raise ValueError(
            f"{name} must be a power of two >= 1 (capacity classes are "
            f"power-of-two so the compiled-shape count stays O(log "
            f"max_batch)), got {value}"
        )


def batch_capacity(b: int, *, b_min: int = 8, b_max: int = 4096) -> int:
    """Power-of-two batch capacity class covering ``b`` rows (clamped to
    ``[b_min, b_max]``) — bounds the distinct scoring-program batch shapes
    to O(log max_batch).

    ``b_min``/``b_max`` must themselves be powers of two: a non-pow2
    floor (say 10) would silently yield 10/20/40/... classes and defeat
    the compiled-shape bound the docstring promises.
    """
    _check_pow2("b_min", b_min)
    _check_pow2("b_max", b_max)
    if b_min > b_max:
        raise ValueError(f"b_min={b_min} exceeds b_max={b_max}")
    cap = b_min
    while cap < min(b, b_max):
        cap *= 2
    return cap


class RequestBatcher:
    """Thread-safe accumulate/drain bridge between request arrival and the
    batched scoring dispatch.

    ``dp``/``pad_p_to`` fix the packed slab geometry (pass the serving
    store's mesh data extent and ``store.pad_p_to``; the defaults are the
    local single-device geometry). ``max_batch`` caps one drain — leftover
    requests stay queued for the next.

    Bounded-queue knobs:

    * ``max_pending`` — admission cap; ``submit`` raises
      :class:`Overloaded` when the queue is full.
    * ``default_ttl_s`` — deadline applied to requests submitted without
      an explicit ``deadline_s`` (``None`` = no deadline).
    * ``clock`` — monotonic time source (injectable so tests and the
      chaos harness can expire requests deterministically).
    """

    def __init__(self, p: int, *, max_batch: int = 256, dp: int = 1,
                 pad_p_to: int = 1, k_min: int = 8,
                 max_pending: int = 4096,
                 default_ttl_s: Optional[float] = None,
                 clock=time.monotonic):
        _check_pow2("max_batch", max_batch)
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.p = p
        self.max_batch = max_batch
        self.dp = dp
        self.pad_p_to = pad_p_to
        self.k_min = k_min
        self.max_pending = max_pending
        self.default_ttl_s = default_ttl_s
        self.clock = clock
        self._lock = threading.Lock()
        # (encoded, lam, expiry-on-self.clock-or-None, submit-ts) per
        # pending request; the submit timestamp feeds the submit->score
        # latency histogram and is never part of the legacy stats surface
        self._pending: List[
            Tuple[Tuple[np.ndarray, np.ndarray], float, Optional[float],
                  float]
        ] = []
        self._stats = {"submitted": 0, "rejected_overload": 0,
                       "rejected_invalid": 0, "shed_expired": 0,
                       "drained": 0}
        # submit timestamps of the most recent drain, waiting for the
        # serve loop to confirm the batch was scored (mark_scored)
        self._last_drained_ts: List[float] = []
        self.register_metrics()

    def submit(self, request: Request, lam: float, *,
               deadline_s: Optional[float] = None) -> None:
        """Enqueue one request (hashed + encoded immediately).

        ``deadline_s`` is a time-to-live on the batcher's clock (falls
        back to ``default_ttl_s``); a request still queued past it is shed
        at the next drain. Raises :class:`~repro.serve.ingest.
        InvalidRequest` on garbage input and :class:`Overloaded` when the
        queue is at ``max_pending`` — both counted before raising.
        """
        try:
            with obs_trace.span("encode"):
                enc = encode_request(request, self.p)
            idx = enc[0]
            if idx.size and (int(idx.min()) < 0 or int(idx.max()) >= self.p):
                raise InvalidRequest(
                    f"hashed index out of range [0, {self.p})"
                )
        except InvalidRequest:
            with self._lock:
                self._stats["rejected_invalid"] += 1
            raise
        now = self.clock()
        ttl = self.default_ttl_s if deadline_s is None else deadline_s
        expiry = None if ttl is None else now + float(ttl)
        with self._lock:
            if len(self._pending) >= self.max_pending:
                self._stats["rejected_overload"] += 1
                raise Overloaded(
                    f"pending queue full ({self.max_pending} requests): "
                    f"drain is not keeping up — shed and retry with backoff"
                )
            self._pending.append((enc, float(lam), expiry, now))
            self._stats["submitted"] += 1
            depth = len(self._pending)
        obs_registry.gauge("serve.queue_depth").set(depth)

    def __len__(self) -> int:
        with self._lock:
            return len(self._pending)

    @property
    def stats(self) -> dict:
        """Counter snapshot (submitted / rejected_overload /
        rejected_invalid / shed_expired / drained) for telemetry."""
        with self._lock:
            return dict(self._stats)

    def drain(self) -> Tuple[PackedBatch, np.ndarray]:
        """Pack up to ``max_batch`` queued requests into one batch.

        Expired requests (deadline passed on the batcher's clock) are shed
        first — counted, never packed: scoring them would spend a dispatch
        on answers nobody is waiting for. Returns ``(batch, lams)``;
        ``lams[i]`` belongs to batch row ``i``. An empty queue drains to
        an all-padding batch (``n_live == 0``).
        """
        with obs_trace.span("drain") as sp:
            now = self.clock()
            with self._lock:
                live = [e for e in self._pending
                        if e[2] is None or e[2] > now]
                self._stats["shed_expired"] += len(self._pending) - len(live)
                take, self._pending = (live[:self.max_batch],
                                       live[self.max_batch:])
                self._stats["drained"] += len(take)
                self._last_drained_ts = [e[3] for e in take]
                depth = len(self._pending)
            obs_registry.gauge("serve.queue_depth").set(depth)
            encoded = [e[0] for e in take]
            lams = np.asarray([e[1] for e in take], np.float64)
            cap = batch_capacity(max(len(encoded), 1), b_max=self.max_batch)
            cap += (-cap) % max(self.dp, 1)
            batch = pack_requests(encoded, self.p, batch_cap=cap, dp=self.dp,
                                  pad_p_to=self.pad_p_to, k_min=self.k_min)
            sp.set(drained=len(take))
        return batch, lams

    def mark_scored(self) -> int:
        """Record submit->score latency for the most recently drained
        batch into the ``serve.latency_s`` histogram on the active
        metrics registry. The serve loop calls this right after the
        scorer returns host scores (the existing host sync) — the
        observation costs one clock read per request and is a no-op
        (beyond that) when no registry is active. Returns how many
        requests were marked; calling twice without a new drain is a
        harmless zero."""
        with self._lock:
            ts, self._last_drained_ts = self._last_drained_ts, []
        if not ts:
            return 0
        hist = obs_registry.histogram("serve.latency_s")
        now = self.clock()
        for t in ts:
            hist.observe(now - t)
        return len(ts)

    def register_metrics(self, registry=None) -> None:
        """Mirror the legacy :attr:`stats` dict and the live queue depth
        onto a ``repro.obs`` metrics registry as lazy read-only
        callbacks. ``_stats`` stays the single source of truth — its
        values are bit-identical whether or not a registry is active.
        Called automatically at construction (no-op when no registry is
        armed); call again to attach to a later-activated registry."""
        reg = obs_registry.get_registry() if registry is None else registry
        if reg is None:
            return
        reg.register_callback("serve.batcher", lambda: self.stats)
        reg.register_callback("serve.queue",
                              lambda: {"depth": len(self)})
