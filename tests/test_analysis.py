"""Tier-1 tests for the ``repro.analysis`` lint suite.

Golden fixtures under ``tests/fixtures/analysis/`` each trip exactly one
rule (``clean.py`` trips none); pragma and TOML suppression semantics
are exercised on temp files; and the merged tree itself must scan clean
with the checked-in allowlist — the same invocation CI's lint lane runs.
"""
import json
import os
import re

import pytest

from repro.analysis import run_analysis
from repro.analysis import runner
from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Suppressions
from repro.analysis.rules import (ALL_RULES, dead_code, metric_discipline,
                                  nonfinite_guard)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = "tests/fixtures/analysis"

#: fixture -> the ONE rule it must trip
GOLDEN = {
    "fx_sharded_concat.py": "sharded-concat",
    "fx_psum_axis.py": "psum-axis",
    "fx_host_sync.py": "host-sync-in-jit",
    "fx_retrace.py": "retrace-hazard",
    "fx_bench_timing.py": "bench-timing",
    "fx_pallas.py": "pallas-conventions",
    "fx_nonfinite_guard.py": "nonfinite-guard",
    "fx_bucket_residency.py": "bucket-residency",
}


def _scan(relpath, **kw):
    kw.setdefault("root", REPO)
    kw.setdefault("excludes", ())      # fixtures are excluded by default
    kw.setdefault("allowlist", None)
    return run_analysis([relpath], **kw)


# ---------------------------------------------------------------------------
# golden fixtures
# ---------------------------------------------------------------------------

def test_rule_registry_covers_the_suite():
    ids = [r.RULE_ID for r in ALL_RULES]
    assert len(ids) == len(set(ids))
    for required in ("sharded-concat", "psum-axis", "host-sync-in-jit",
                     "retrace-hazard", "bench-timing", "pallas-conventions",
                     "dead-code", "nonfinite-guard", "bucket-residency",
                     "metric-discipline"):
        assert required in ids


@pytest.mark.parametrize("fname,rule", sorted(GOLDEN.items()))
def test_fixture_trips_exactly_one_rule(fname, rule):
    rep = _scan(f"{FIXTURES}/{fname}")
    assert [f.rule for f in rep.findings] == [rule], \
        [f.render() for f in rep.findings]
    f = rep.findings[0]
    assert f.file == f"{FIXTURES}/{fname}" and f.line >= 1


def test_clean_fixture_trips_nothing():
    rep = _scan(f"{FIXTURES}/clean.py")
    assert rep.ok and rep.findings == [] and rep.n_files == 1


def test_dead_code_fixture_under_synthetic_src_path():
    # dead-code only inventories src/ modules, so the fixture is re-parsed
    # under a src/ path; where it actually lives it must stay inert
    with open(os.path.join(REPO, FIXTURES, "fx_dead_code.py")) as fh:
        source = fh.read()
    mod = ModuleInfo.parse("src/repro/orphan_scaffold.py", source)
    findings = list(dead_code.check(Project(root=REPO, modules=[mod])))
    assert [f.rule for f in findings] == ["dead-code"]
    assert "repro.orphan_scaffold" in findings[0].message
    assert _scan(f"{FIXTURES}/fx_dead_code.py").ok


def test_metric_discipline_fixture_under_synthetic_src_path():
    # metric-discipline is layer-scoped to src/repro/ (outside repro/obs),
    # so the fixture is re-parsed under a src/ path; where it actually
    # lives it must stay inert
    with open(os.path.join(REPO, FIXTURES, "fx_metric_discipline.py")) as fh:
        source = fh.read()
    mod = ModuleInfo.parse("src/repro/adhoc_timing.py", source)
    findings = list(metric_discipline.check(
        Project(root=REPO, modules=[mod])))
    # both clock reads of the timing pair trip; the legacy-adapter
    # increment (class defines register_metrics) must NOT
    assert [f.rule for f in findings] == ["metric-discipline"] * 2
    assert all("perf_counter" in f.message for f in findings)
    assert _scan(f"{FIXTURES}/fx_metric_discipline.py").ok


def test_metric_discipline_flags_counter_dicts_without_adapter():
    src = ("class T:\n"
           "    def __init__(self):\n"
           "        self._stats = {'n': 0}\n"
           "    def hit(self):\n"
           "        self._stats['n'] += 1\n")
    mod = ModuleInfo.parse("src/repro/serve/newmod.py", src)
    findings = list(metric_discipline.check(
        Project(root=REPO, modules=[mod])))
    assert [f.rule for f in findings] == ["metric-discipline"]
    assert "register_metrics" in findings[0].message
    # the same class with a register_metrics adapter is the sanctioned
    # legacy shape — inert
    mod2 = ModuleInfo.parse(
        "src/repro/serve/newmod.py",
        src + "    def register_metrics(self, registry=None):\n"
              "        pass\n")
    assert list(metric_discipline.check(
        Project(root=REPO, modules=[mod2]))) == []
    # and repro/obs itself is the implementation — out of scope
    mod3 = ModuleInfo.parse("src/repro/obs/newmod.py", src)
    assert list(metric_discipline.check(
        Project(root=REPO, modules=[mod3]))) == []


def test_nonfinite_guard_scopes_to_serve_paths():
    # the rule is layer-scoped: the same unguarded host-crossing trips
    # inside src/repro/serve/ but stays inert elsewhere in the tree
    src = ("import numpy as np\n\n\ndef f(scorer, x):\n"
           "    return np.asarray(scorer.dispatch(x))\n")
    mod = ModuleInfo.parse("src/repro/serve/newmod.py", src)
    findings = list(nonfinite_guard.check(Project(root=REPO, modules=[mod])))
    assert [f.rule for f in findings] == ["nonfinite-guard"]
    mod2 = ModuleInfo.parse("src/repro/data/other.py", src)
    assert list(nonfinite_guard.check(
        Project(root=REPO, modules=[mod2]))) == []


def test_finding_render_format():
    rep = _scan(f"{FIXTURES}/fx_retrace.py")
    assert re.fullmatch(
        rf"{FIXTURES}/fx_retrace\.py:\d+ · retrace-hazard · .+",
        rep.findings[0].render())


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------

_TRIPPING = (
    "import jax.numpy as jnp\n"
    "from jax.sharding import Mesh  # noqa: F401\n"
    "\n"
    "\n"
    "def f(xs):\n"
    "{pragma}"
    "    return jnp.concatenate(xs)\n"
)


def test_pragma_with_justification_suppresses(tmp_path):
    (tmp_path / "mod.py").write_text(_TRIPPING.format(
        pragma="    # allow[sharded-concat]: host lists, never sharded\n"))
    rep = run_analysis(["mod.py"], root=str(tmp_path), excludes=(),
                       allowlist=None)
    assert rep.ok
    assert [f.rule for f in rep.suppressed] == ["sharded-concat"]


def test_pragma_without_justification_is_a_finding(tmp_path):
    (tmp_path / "mod.py").write_text(_TRIPPING.format(
        pragma="    # allow[sharded-concat]:\n"))
    rep = run_analysis(["mod.py"], root=str(tmp_path), excludes=(),
                       allowlist=None)
    assert not rep.ok
    assert sorted(f.rule for f in rep.findings) == \
        ["bad-pragma", "sharded-concat"]


def test_allowlist_glob_suppresses(tmp_path):
    (tmp_path / "mod.py").write_text(_TRIPPING.format(pragma=""))
    (tmp_path / "al.toml").write_text(
        '[[allow]]\nrule = "sharded-concat"\npath = "mod.py"\n'
        'reason = "fixture operands are host lists"\n')
    rep = run_analysis(["mod.py"], root=str(tmp_path), excludes=(),
                       allowlist="al.toml")
    assert rep.ok and [f.rule for f in rep.suppressed] == ["sharded-concat"]


def test_allowlist_entry_without_reason_aborts(tmp_path):
    al = tmp_path / "al.toml"
    al.write_text('[[allow]]\nrule = "sharded-concat"\npath = "*"\n')
    with pytest.raises(SystemExit, match="no reason"):
        Suppressions.load_toml(str(al))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_json_output(tmp_path, capsys):
    (tmp_path / "mod.py").write_text(
        "import jax\n\n\n@jax.jit\ndef f(x):\n    return float(x)\n")
    rc = runner.main(["mod.py", "--format", "json", "--root", str(tmp_path),
                      "--allowlist", ""])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1 and out["ok"] is False and out["files"] == 1
    assert [f["rule"] for f in out["findings"]] == ["host-sync-in-jit"]
    assert set(out["findings"][0]) == {"file", "line", "rule", "message"}


def test_cli_rule_selection(tmp_path, capsys):
    # same tripping file, but only the bench-timing rule armed -> clean
    (tmp_path / "mod.py").write_text(
        "import jax\n\n\n@jax.jit\ndef f(x):\n    return float(x)\n")
    rc = runner.main(["mod.py", "--rules", "bench-timing", "--root",
                      str(tmp_path), "--allowlist", ""])
    assert rc == 0
    assert "0 finding(s)" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# the tree of record is clean — the exact CI lint-lane invocation
# ---------------------------------------------------------------------------

def test_merged_tree_scans_clean():
    rep = run_analysis(["src", "tests", "benchmarks", "scripts"], root=REPO)
    assert rep.ok, "\n".join(
        f.render() for f in rep.findings + rep.parse_errors)
    assert rep.n_files > 50
