"""CI perf gate: compare a fresh BENCH_regpath.json against the committed
baseline and fail when a gated metric regresses.

Gated metrics (each applied only when present in *both* reports):

* ``frontdoor.warm_s`` (formerly ``engine.warm_s`` — either name is
  accepted on either side of the comparison) — warm wall-clock of the
  screened path through the ``repro.api`` front door, the headline
  number repeated production paths pay (cold time is dominated by XLA
  compiles and is allowed to drift).
* ``distributed.warm_s`` — warm wall-clock of the sparse-distributed
  screened path (the by-feature slab hot path), so the per-iteration
  densify-scatter regression this suite killed can't come back unnoticed.
* ``kernels.slab_*.speedup`` — sparse-native slab kernel vs the densify
  reference at matched shapes; the speedup may not collapse relative to
  baseline in the regimes where the slab kernel is the preferred path.
* ``cycle.*`` — the blocked semi-parallel CD cycle: the per-tile
  blocked-vs-sequential speedup may not collapse (the within-tile chain
  re-serializing — this floor is the primary gate), the blocked path
  must still land on the sequential path's objectives
  (``max_rel_f_gap``, an absolute gate), and the blocked warm path gets
  a wide catastrophic-only ratio gate (2x the normal one — it rides a
  ~1s tiny measurement and would flap at the standard ratio).
* ``serve.batch.*.scores_per_s`` — online path-serving throughput
  (``repro.serve``) per batch size; catastrophic-only floor (same 2x
  widening) so a batched dispatch degenerating into per-request work
  fails while host-side packing jitter does not.
* ``streamed.*`` — the HBM-budgeted streamed-residency path:
  ``bit_identical`` is an absolute gate (streaming changes where buckets
  live, never the math — any drift is a correctness bug, not a perf
  regression), while the streamed warm time gets the same wide
  catastrophic-only ratio gate as the other sub-second tiny sections
  (host->device put latency under CI load flaps far more than compute).

All time gates are ratios so the baseline only needs regenerating when
shapes change:

    python -m benchmarks.compare_bench \
        --fresh BENCH_regpath.json \
        --baseline benchmarks/baselines/BENCH_regpath_tiny.json \
        --max-ratio 1.3

Exits non-zero when any gate fails or when the configs don't match (a
silent shape change would make the ratios meaningless).
"""
from __future__ import annotations

import argparse
import json
import sys

#: sections that exist only when their bench flag is passed; a baseline
#: carrying one the fresh report lacks means the flag was dropped
_FLAGGED_SECTIONS = ("distributed", "kernels", "cycle", "serve", "streamed")


def _gate_time(name, fresh_s, base_s, max_ratio, unit="s") -> bool:
    ratio = fresh_s / max(base_s, 1e-12)
    print(f"{name}: fresh {fresh_s:.3f}{unit} vs baseline {base_s:.3f}{unit}"
          f" -> ratio {ratio:.2f}x (gate {max_ratio}x)")
    if ratio > max_ratio:
        print(f"FAIL: {name} regressed {ratio:.2f}x > {max_ratio}x")
        return False
    return True


def _explain_by_phase(fresh_path, base_path, max_ratio) -> None:
    """Attribute a front-door warm regression to solver phases using the
    obs trace summaries (``regpath_bench --trace-summary`` side files):
    per-span totals for screen_round / restricted_solve / kkt_check /
    point_finish say WHERE the wall time went, turning 'warm_s ratio
    1.4x' into 'restricted_solve doubled, everything else held'."""

    def load(path, role):
        if path is None:
            print(f"  (no --{role}-trace summary given — rerun "
                  f"regpath_bench with --trace-summary for a per-phase "
                  f"breakdown)")
            return None
        try:
            with open(path) as fh:
                return json.load(fh).get("spans", {})
        except (OSError, ValueError) as err:
            print(f"  (could not read --{role}-trace {path}: {err})")
            return None

    fresh_sp = load(fresh_path, "fresh")
    if not fresh_sp:
        return
    base_sp = load(base_path, "base") or {}
    print("per-phase breakdown of the traced warm leg (seconds):")
    for name in sorted(fresh_sp,
                       key=lambda n: -fresh_sp[n].get("total_s", 0.0)):
        ft = fresh_sp[name].get("total_s", 0.0)
        bt = base_sp.get(name, {}).get("total_s")
        if bt is None:
            print(f"  {name:<18} fresh {ft:9.4f}s (no baseline trace)")
            continue
        ratio = ft / max(bt, 1e-12)
        flag = "  <-- regressed" if ratio > max_ratio else ""
        print(f"  {name:<18} fresh {ft:9.4f}s vs baseline {bt:9.4f}s "
              f"-> {ratio:5.2f}x{flag}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--max-ratio", type=float, default=1.3,
                    help="fail when a fresh warm_s exceeds baseline by this "
                         "factor, or a kernel speedup falls below baseline "
                         "by it (default 1.3)")
    ap.add_argument("--normalize", action="store_true",
                    help="divide each warm_s by the same run's seed-style "
                         "warm_s before comparing, so raw machine speed "
                         "cancels (use on heterogeneous CI runners). "
                         "Units change accordingly: gated times are "
                         "reported as unitless multiples of that run's "
                         "seed-style warm time ('x seed-style') instead "
                         "of seconds, and serve throughput becomes "
                         "scores-per-seed-warm-unit rather than "
                         "scores/sec — ratios and gates are unaffected")
    ap.add_argument("--fresh-trace", default=None, metavar="PATH",
                    help="obs trace summary for the fresh run (regpath_"
                         "bench --trace-summary); when the front-door "
                         "warm gate fails, the regression is broken down "
                         "per solver phase")
    ap.add_argument("--base-trace", default=None, metavar="PATH",
                    help="obs trace summary for the baseline run, "
                         "compared phase-by-phase against --fresh-trace "
                         "on a front-door gate failure")
    args = ap.parse_args()

    with open(args.fresh) as fh:
        fresh = json.load(fh)
    with open(args.baseline) as fh:
        base = json.load(fh)

    if fresh["config"] != base["config"]:
        print(f"FAIL: config mismatch — fresh {fresh['config']} vs "
              f"baseline {base['config']}; regenerate the baseline")
        return 1

    def norm(report):
        return max(report["seed_style"]["warm_s"], 1e-12) \
            if args.normalize else 1.0

    def section(report, *names):
        # the screened-path section was renamed "engine" -> "frontdoor"
        # when the drivers moved behind repro.api; accept either spelling
        # on either side so baselines and fresh reports can straddle the
        # rename without a regenerate
        for name in names:
            if name in report:
                return report[name]
        print(f"FAIL: report has none of the sections {names}")
        return None

    unit = "x seed-style" if args.normalize else "s"
    fresh_eng = section(fresh, "frontdoor", "engine")
    base_eng = section(base, "frontdoor", "engine")
    if fresh_eng is None or base_eng is None:
        return 1
    ok = _gate_time("front-door warm path",
                    fresh_eng["warm_s"] / norm(fresh),
                    base_eng["warm_s"] / norm(base),
                    args.max_ratio, unit)
    if not ok:
        _explain_by_phase(args.fresh_trace, args.base_trace, args.max_ratio)

    # a section present in the baseline but absent from the fresh report
    # means the bench stopped measuring it — that must fail, not silently
    # skip the gate (e.g. someone dropping --kernels from the CI lane)
    for name in _FLAGGED_SECTIONS:
        if name in base and name not in fresh:
            print(f"FAIL: baseline has a '{name}' section but the fresh "
                  f"report does not — was the bench flag dropped? "
                  f"(flag-gated sections a full run carries: "
                  f"{', '.join(_FLAGGED_SECTIONS)})")
            ok = False

    if "distributed" in fresh and "distributed" in base:
        if fresh["distributed"].get("sparse") != base["distributed"].get("sparse"):
            print("FAIL: distributed sparse flag mismatch vs baseline")
            ok = False
        else:
            ok &= _gate_time("sparse-distributed warm path",
                             fresh["distributed"]["warm_s"] / norm(fresh),
                             base["distributed"]["warm_s"] / norm(base),
                             args.max_ratio, unit)

    if "kernels" in fresh and "kernels" in base:
        for name, row in sorted(base["kernels"].items()):
            if not isinstance(row, dict) or "speedup" not in row:
                continue
            if not row.get("preferred", name.startswith("slab_spmv")):
                continue   # dense-fallback regime: speedup < 1 is expected
            fresh_row = fresh["kernels"].get(name)
            if fresh_row is None:
                print(f"FAIL: kernel entry {name} missing from fresh report")
                ok = False
                continue
            # microbench speedups are noisier than path wall-clock: the
            # floor is capped at 1.1x, which still catches the failure
            # mode that matters (collapse toward 1x = the densify scatter
            # is back) without flapping on sub-100us timing jitter
            floor = min(row["speedup"] / (args.max_ratio ** 2), 1.1)
            print(f"kernel {name}: speedup fresh {fresh_row['speedup']:.2f}x"
                  f" vs baseline {row['speedup']:.2f}x (floor {floor:.2f}x)")
            if fresh_row["speedup"] < floor:
                print(f"FAIL: {name} sparse-native speedup collapsed "
                      f"({fresh_row['speedup']:.2f}x < {floor:.2f}x) — did "
                      f"the densify come back?")
                ok = False

    if "cycle" in fresh and "cycle" in base:
        fc, bc = fresh["cycle"], base["cycle"]
        if fc.get("block") != bc.get("block"):
            print("FAIL: cycle block size mismatch vs baseline")
            ok = False
        else:
            # per-tile blocked-vs-sequential speedup: same capped floor as
            # the slab gate — what matters is collapse toward 1x (the
            # sequential chain back in the hot path), not timing jitter
            floor = min(bc["per_tile"]["speedup"] / (args.max_ratio ** 2),
                        1.1)
            print(f"cycle per-tile: speedup fresh "
                  f"{fc['per_tile']['speedup']:.2f}x vs baseline "
                  f"{bc['per_tile']['speedup']:.2f}x (floor {floor:.2f}x)")
            if fc["per_tile"]["speedup"] < floor:
                print(f"FAIL: blocked per-tile speedup collapsed "
                      f"({fc['per_tile']['speedup']:.2f}x < {floor:.2f}x) — "
                      f"did the soft-threshold chain re-serialize?")
                ok = False
            # the warm path rides a ~1s tiny run and flaps under bursty CI
            # load; the per-tile floor above is the re-serialization
            # guard, so the path time only gets a wide catastrophic gate
            # (2x the normal ratio)
            ok &= _gate_time("blocked-cycle warm path",
                             fc["path"]["warm_s"] / norm(fresh),
                             bc["path"]["warm_s"] / norm(base),
                             2 * args.max_ratio, unit)
            # absolute objective gate: blocked is an acceleration of the
            # sequential path, never an approximation of it
            gap = fc["path"]["max_rel_f_gap"]
            print(f"cycle objective gap vs sequential: {gap:.2e} "
                  f"(gate 1e-3)")
            if gap > 1e-3:
                print(f"FAIL: blocked path objective diverged from the "
                      f"sequential path (max rel gap {gap:.2e} > 1e-3)")
                ok = False

    if "streamed" in fresh:
        # absolute correctness gate, checked even without a baseline
        # section: a streamed path that is not bit-identical to the
        # resident path is broken regardless of how fast it is
        if not fresh["streamed"]["bit_identical"]:
            print("FAIL: streamed path diverged from the resident path — "
                  "residency must never change the math")
            ok = False
    if "streamed" in fresh and "streamed" in base:
        # the streamed section rides sub-second tiny runs dominated by
        # host->device puts; like the blocked warm path it only gets the
        # wide catastrophic gate (2x the normal ratio)
        ok &= _gate_time("streamed-residency warm path",
                         fresh["streamed"]["streamed_warm_s"] / norm(fresh),
                         base["streamed"]["streamed_warm_s"] / norm(base),
                         2 * args.max_ratio, unit)

    if "serve" in fresh and "serve" in base:
        for bs, row in sorted(base["serve"]["batch"].items()):
            fresh_row = fresh["serve"]["batch"].get(bs)
            if fresh_row is None:
                print(f"FAIL: serve batch size {bs} missing from fresh "
                      f"report")
                ok = False
                continue
            # throughput rides host-side packing + sub-second timed loops,
            # so it gets only a catastrophic floor (2x the normal ratio,
            # like the blocked warm path): what must not slip through is
            # the batched dispatch degenerating into per-request work.
            # --normalize multiplies the rate by the same run's seed-style
            # warm_s (slower machine -> lower rate AND higher warm_s, so
            # machine speed cancels).
            f_rate = fresh_row["scores_per_s"] * norm(fresh)
            b_rate = row["scores_per_s"] * norm(base)
            floor = b_rate / (2 * args.max_ratio)
            print(f"serve batch {bs}: fresh {f_rate:,.0f} vs baseline "
                  f"{b_rate:,.0f} scores/sec (floor {floor:,.0f})")
            if f_rate < floor:
                print(f"FAIL: serving throughput at batch {bs} collapsed "
                      f"({f_rate:,.0f} < {floor:,.0f} scores/sec) — is the "
                      f"batched dispatch per-request again?")
                ok = False

    if not ok:
        return 1
    print("OK: all benchmark gates within bounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
