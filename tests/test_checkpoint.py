"""Checkpointer round-trip + durability failure modes (PR 8)."""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointCorruption,
    load_pytree,
    read_meta,
    save_pytree,
    verify_payload,
)
from repro.configs import MODEL_CONFIGS
from repro.resilience import corrupt_checkpoint
from repro.train import make_train_state


def test_round_trip(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
        "list": [jnp.zeros(3), jnp.ones(2)],
    }
    save_pytree(tree, str(tmp_path / "ck"), step=42)
    out = load_pytree(str(tmp_path / "ck"), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_train_state_round_trip(tmp_path):
    cfg = MODEL_CONFIGS["tinyllama-1.1b"].smoke()
    state = make_train_state(jax.random.key(0), cfg)
    save_pytree(state, str(tmp_path / "state"))
    restored = load_pytree(str(tmp_path / "state"), state)
    a = jax.tree.leaves(state["params"])[0]
    b = jax.tree.leaves(restored["params"])[0]
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((2, 2))}
    save_pytree(tree, str(tmp_path / "ck"))
    bad = {"a": jnp.zeros((3, 3))}
    try:
        load_pytree(str(tmp_path / "ck"), bad)
        raise AssertionError("expected ValueError")
    except ValueError:
        pass


# ---------------------------------------------------------------------------
# durability failure modes (PR 8): every corruption is DETECTED, never a
# silent wrong-weights load
# ---------------------------------------------------------------------------

TREE = {"a": jnp.arange(8, dtype=jnp.float32), "b": {"c": jnp.ones(3)}}


def _save(tmp_path):
    d = str(tmp_path / "ck")
    save_pytree(TREE, d, step=7)
    assert verify_payload(d) is True
    return d


def test_truncated_payload_detected(tmp_path):
    d = _save(tmp_path)
    corrupt_checkpoint(d, "truncate")
    with pytest.raises(CheckpointCorruption, match="size|bytes|CRC"):
        verify_payload(d)
    with pytest.raises(CheckpointCorruption):
        load_pytree(d, TREE)


def test_bitflip_detected_by_crc(tmp_path):
    d = _save(tmp_path)
    corrupt_checkpoint(d, "bitflip", seed=5)
    with pytest.raises(CheckpointCorruption, match="CRC"):
        verify_payload(d)
    with pytest.raises(CheckpointCorruption):
        load_pytree(d, TREE)


def test_missing_manifest_detected(tmp_path):
    d = _save(tmp_path)
    os.remove(os.path.join(d, "manifest.json"))
    with pytest.raises(CheckpointCorruption):
        verify_payload(d)
    with pytest.raises(CheckpointCorruption):
        read_meta(d)


def test_dropped_meta_keeps_arrays_loadable(tmp_path):
    d = _save(tmp_path)
    corrupt_checkpoint(d, "drop-meta")
    assert verify_payload(d) is True      # payload integrity is intact
    assert read_meta(d) is None           # but the meta is typed-absent
    out = load_pytree(d, TREE)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(TREE["a"]))


def test_legacy_manifest_without_crc_still_loads(tmp_path):
    d = _save(tmp_path)
    mpath = os.path.join(d, "manifest.json")
    with open(mpath) as fh:
        man = json.load(fh)
    man.pop("crc32"), man.pop("payload_bytes")
    with open(mpath, "w") as fh:
        json.dump(man, fh)
    assert verify_payload(d) is False     # unverifiable, not corrupt
    out = load_pytree(d, TREE)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(TREE["a"]))


def test_concurrent_writers_never_tear_silently(tmp_path):
    """Atomic write-rename under contention: each rename publishes one
    writer's complete bytes, so the final directory either verifies and
    loads as exactly ONE writer's tree, or (a manifest paired with the
    other writer's payload — the crash window the docs describe) raises
    ``CheckpointCorruption``. A silent half-and-half load is impossible."""
    d = str(tmp_path / "ck")
    trees = [{"a": jnp.full(8, float(i)), "b": {"c": jnp.ones(3)}}
             for i in range(4)]
    barrier = threading.Barrier(4)
    errors = []

    def write(i):
        try:
            barrier.wait()
            for _ in range(5):
                save_pytree(trees[i], d, step=i)
        except Exception as e:  # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=write, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors                      # writers never trip each other
    try:
        assert verify_payload(d) is True
        out = load_pytree(d, TREE)
    except CheckpointCorruption:
        return                             # torn pair: DETECTED, not loaded
    winner = float(np.asarray(out["a"])[0])
    assert winner in {0.0, 1.0, 2.0, 3.0}
    assert np.all(np.asarray(out["a"]) == winner)
