"""ShapeDtypeStruct stand-ins for every (arch x input-shape) combination —
weak-type-correct, shardable, zero allocation.

``decode`` shapes lower serve_step: ONE new token + a cache of seq_len.
``long_500k`` is skipped for archs whose config says so (DESIGN.md §2.5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.params import init_cache
from repro.train.state import make_train_state
from repro.train.train_step import IGNORE


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def skip_reason(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and cfg.long_context_mode == "skip":
        return (
            "enc-dec speech translation: 500k-token decode is architecturally "
            "meaningless and the decoder is full-attention (DESIGN.md §2.5)"
        )
    return None


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Returns the kwargs pytree the lowered step function consumes.

    train   -> {"batch": {tokens, labels, [embeds]}}
    prefill -> {"batch": {tokens, [embeds]}}
    decode  -> {"cache": ..., "cache_index": scalar, "tokens": (B,1)}
    """
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind

    if kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.encdec.enabled:
            batch["frame_embeds"] = sds(
                (b, cfg.encdec.encoder_seq_len, cfg.frontend.embed_dim), jnp.bfloat16
            )
            batch["tokens"] = sds((b, s), jnp.int32)
            if kind == "train":
                batch["labels"] = sds((b, s), jnp.int32)
        elif cfg.frontend.kind != "none":
            p = cfg.frontend.tokens_per_item
            key = "patch_embeds" if cfg.frontend.kind == "vision_patches" else "frame_embeds"
            batch[key] = sds((b, p, cfg.frontend.embed_dim), jnp.bfloat16)
            batch["tokens"] = sds((b, s - p), jnp.int32)
            if kind == "train":
                batch["labels"] = sds((b, s), jnp.int32)
        else:
            batch["tokens"] = sds((b, s), jnp.int32)
            if kind == "train":
                batch["labels"] = sds((b, s), jnp.int32)
        return {"batch": batch}

    # decode: cache of seq_len, one new token
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return {
        "cache": cache,
        "cache_index": sds((), jnp.int32),
        "tokens": sds((b, 1), jnp.int32),
    }


def state_specs(cfg: ModelConfig):
    """Train-state ShapeDtypeStructs (params + optimizer state + step)."""
    return jax.eval_shape(lambda: make_train_state(jax.random.key(0), cfg))


def params_specs(cfg: ModelConfig):
    from repro.models.params import init_params

    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
