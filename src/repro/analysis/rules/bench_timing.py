"""bench-timing: perf_counter deltas around async JAX dispatch.

JAX dispatch is asynchronous: a ``perf_counter`` pair around a jitted
call without a ``block_until_ready`` between dispatch and the second
read times the *enqueue*, not the work. Every benchmark number this repo
gates CI on (warm path seconds, per-tile microbenches, scores/sec) is a
perf_counter delta — a missing sync turns a real regression invisible
and the gate into theater.

Scope heuristic: a function (or a class, for ``__enter__``/``__exit__``
timer pairs) in a jax-importing module that reads ``perf_counter`` at
least twice without any ``block_until_ready`` in the same scope. Code
whose timed section is genuinely host-synchronous (e.g. it ends in a
``np.asarray`` of the result) carries an ``allow[bench-timing]`` pragma
saying exactly that.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.context import ModuleInfo, Project
from repro.analysis.findings import Finding

RULE_ID = "bench-timing"
DOC = ("perf_counter delta with no block_until_ready in scope — times "
       "async dispatch, not the work")


def _scope_calls(scope: ast.AST, mod: ModuleInfo):
    perf, sync = [], 0
    for node in ast.walk(scope):
        if not isinstance(node, ast.Call):
            continue
        q = mod.qualname(node.func)
        if q in ("time.perf_counter", "perf_counter", "time.monotonic",
                 "time.time"):
            perf.append(node.lineno)
        elif (q in ("jax.block_until_ready", "block_until_ready")
              or (isinstance(node.func, ast.Attribute)
                  and node.func.attr == "block_until_ready")):
            sync += 1
    return perf, sync


def _check_scope(mod: ModuleInfo, scope, name: str) -> Iterable[Finding]:
    perf, sync = _scope_calls(scope, mod)
    if len(perf) >= 2 and sync == 0:
        yield Finding(
            file=mod.path, line=sorted(perf)[-1], rule=RULE_ID,
            message=(
                f"{name} measures a perf_counter delta with no "
                f"block_until_ready in scope — async dispatch makes this "
                f"time the enqueue, not the JAX work; block on the output "
                f"before stopping the clock (or allow[{RULE_ID}] stating "
                f"why the timed section is host-synchronous)"),
        )


def check(project: Project) -> Iterable[Finding]:
    out: List[Finding] = []
    for mod in project.modules:
        if not mod.imports_jax:
            continue
        # classes first (timer context managers split the pair across
        # methods); member functions of reported classes are skipped
        reported_fns = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                perf, sync = _scope_calls(node, mod)
                if len(perf) >= 2 and sync == 0:
                    out.extend(_check_scope(mod, node,
                                            f"class {node.name}"))
                    for fn in ast.walk(node):
                        if isinstance(fn, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)):
                            reported_fns.add(fn)
        for fn in mod.functions():
            if fn in reported_fns:
                continue
            out.extend(_check_scope(mod, fn, f"{fn.name}()"))
    return out
