"""Leaf types shared by the front door and the legacy shims.

Import-order note: ``repro.core.__init__`` imports ``core.regpath`` (a
shim over :mod:`repro.api.estimator`), while the estimator imports half of
``repro.core`` — a cycle if the shim needed the full estimator at import
time. It only needs :class:`PathPoint`, so that lives here with no
repro-internal imports at all.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp


@dataclass
class PathPoint:
    """One regularization-path point (paper Algorithm 5)."""

    lam: float
    nnz: int
    f: float
    n_iters: int
    beta: jnp.ndarray
    metrics: dict = field(default_factory=dict)
    screen: dict = field(default_factory=dict)   # active-set telemetry
