"""Assigned input shapes (public pool). See DESIGN.md §2.5 for semantics.

train_*   -> lowers train_step (full forward+backward+update)
prefill_* -> lowers a forward that builds the KV cache / SSM state
decode_*  -> lowers serve_step: ONE new token against a cache of seq_len
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; have {sorted(SHAPES)}") from None
