"""Encoder-decoder backbone (seamless-m4t). The audio frontend is a stub:
inputs carry precomputed frame embeddings (B, S_enc, E) per the assignment
carve-out; we own the projector + both transformer stacks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import init_kv_cache
from repro.models.blocks import (
    decoder_layer_forward,
    encoder_layer_forward,
    init_decoder_layer,
    init_encoder_layer,
)
from repro.models.layers import apply_norm, dense_init, embed_init, init_norm
from repro.models.transformer import dtype_of


def init_seq2seq_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    n = cfg.num_layers  # per stack
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], n)
    dec_keys = jax.random.split(ks[1], n)
    return {
        "frontend_proj": dense_init(ks[2], cfg.frontend.embed_dim, cfg.d_model, dtype),
        "embed": embed_init(ks[3], cfg.padded_vocab, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: init_encoder_layer(k, cfg, dtype))(enc_keys),
        "decoder": jax.vmap(lambda k: init_decoder_layer(k, cfg, dtype))(dec_keys),
        "enc_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        "dec_norm": init_norm(cfg.d_model, dtype, cfg.norm),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.padded_vocab, dtype),
    }


def init_seq2seq_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    dtype = dtype or dtype_of(cfg.compute_dtype)
    n = cfg.num_layers
    one = {"kv": init_kv_cache(cfg.attention, cfg.d_model, batch, cache_len, dtype)}
    dec = jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), one)
    # encoder memory is recomputed at prefill and carried in the cache
    mem = jnp.zeros((batch, cfg.encdec.encoder_seq_len, cfg.d_model), dtype)
    return {"decoder": dec, "memory": mem}


def encode(params, frame_embeds, cfg: ModelConfig):
    cdtype = dtype_of(cfg.compute_dtype)
    x = frame_embeds.astype(cdtype) @ params["frontend_proj"].astype(cdtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    def body(carry, p_l):
        return encoder_layer_forward(p_l, carry, cfg=cfg, positions=positions), None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["encoder"])
    else:
        for i in range(cfg.num_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return apply_norm(params["enc_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)


def seq2seq_forward(
    params,
    inputs: Dict[str, jnp.ndarray],
    cfg: ModelConfig,
    *,
    mode: str = "train",
    cache: Optional[dict] = None,
    cache_index=None,
):
    """inputs: frame_embeds (B,S_enc,E) [train/prefill], tokens (B,S_dec).

    Returns (logits, new_cache, aux)."""
    cdtype = dtype_of(cfg.compute_dtype)
    tokens = inputs["tokens"]
    b, s = tokens.shape

    if mode == "decode":
        assert cache is not None and cache_index is not None
        memory = cache["memory"]
        positions = jnp.broadcast_to(
            jnp.asarray(cache_index, jnp.int32)[None, None], (b, s)
        )
    else:
        memory = encode(params, inputs["frame_embeds"], cfg)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    x = jnp.take(params["embed"], tokens, axis=0).astype(cdtype)

    def apply_layer(x, p_l, cache_l):
        return decoder_layer_forward(
            p_l, x, memory, cfg=cfg, positions=positions, mode=mode,
            cache=cache_l, cache_index=cache_index,
        )

    if cfg.remat and mode == "train":
        apply_layer = jax.checkpoint(apply_layer)

    dec_cache = cache["decoder"] if cache is not None else None

    def body(carry, per_layer):
        p_l, cache_l = per_layer
        y, new_cache_l = apply_layer(carry, p_l, cache_l)
        return y, new_cache_l

    if cfg.scan_layers:
        x, new_dec_cache = jax.lax.scan(body, x, (params["decoder"], dec_cache))
    else:
        new_cs = []
        for i in range(cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[i], params["decoder"])
            c_l = jax.tree.map(lambda a: a[i], dec_cache) if dec_cache is not None else None
            x, nc_ = body(x, (p_l, c_l))
            new_cs.append(nc_)
        new_dec_cache = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_cs)
            if new_cs and new_cs[0] is not None else None
        )

    h = apply_norm(params["dec_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = h @ params["lm_head"].astype(h.dtype)

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"decoder": new_dec_cache, "memory": memory.astype(cdtype)}
    return logits, new_cache, {}
