"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import gram_cd, logistic_stats
from repro.kernels.ref import gram_cd_ref, logistic_stats_ref


@pytest.mark.parametrize("f", [8, 32, 128, 256, 512])
@pytest.mark.parametrize("lam", [0.0, 0.3, 10.0])
def test_gram_cd_sweep(f, lam):
    key = jax.random.key(f * 1000 + int(lam * 10))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    A = jax.random.normal(k1, (2 * f, f))
    G = A.T @ A / f
    c = 3.0 * jax.random.normal(k2, (f,))
    beta = 0.5 * jax.random.normal(k3, (f,))
    db0 = 0.1 * jax.random.normal(k4, (f,))
    d_kernel = gram_cd(G, c, beta, db0, lam)
    d_ref = gram_cd_ref(G, c, beta, db0, lam, 1e-6)
    np.testing.assert_allclose(d_kernel, d_ref, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_cd_dtypes(dtype):
    key = jax.random.key(7)
    k1, k2 = jax.random.split(key)
    f = 64
    A = jax.random.normal(k1, (2 * f, f), dtype)
    G = (A.T @ A / f)
    c = jax.random.normal(k2, (f,), dtype)
    beta = jnp.zeros(f, dtype)
    db0 = jnp.zeros(f, dtype)
    d_kernel = gram_cd(G, c, beta, db0, 0.1)
    d_ref = gram_cd_ref(G, c, beta, db0, 0.1, 1e-6)
    np.testing.assert_allclose(
        np.asarray(d_kernel, np.float32), np.asarray(d_ref, np.float32),
        atol=5e-2 if dtype == jnp.bfloat16 else 1e-5, rtol=1e-2)


def test_gram_cd_soft_threshold_zeroing():
    """Huge lambda -> every coordinate driven to -(beta+dbeta0) (exact zero
    of the total coefficient)."""
    f = 32
    G = jnp.eye(f)
    c = jnp.zeros(f)
    beta = jnp.linspace(-1, 1, f)
    db0 = jnp.zeros(f)
    d = gram_cd(G, c, beta, db0, 1e6)
    np.testing.assert_allclose(beta + db0 + d, np.zeros(f), atol=1e-6)


@pytest.mark.parametrize("n,block", [(64, 32), (1000, 256), (8192, 1024),
                                     (5000, 4096)])
def test_logistic_stats_sweep(n, block):
    key = jax.random.key(n)
    k1, k2 = jax.random.split(key)
    m = 4.0 * jax.random.normal(k1, (n,))
    y = jnp.sign(jax.random.normal(k2, (n,)))
    w1, z1, nll1 = logistic_stats(m, y, block=block)
    w2, z2, nll2 = logistic_stats_ref(m, y)
    np.testing.assert_allclose(w1, w2, rtol=1e-6)
    np.testing.assert_allclose(z1, z2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(nll1, nll2, rtol=1e-5)


def test_logistic_stats_extreme_margins():
    """Clamps keep w/z finite at |m| up to 80 (exp overflow territory)."""
    m = jnp.array([-80.0, -10.0, 0.0, 10.0, 80.0])
    y = jnp.array([1.0, -1.0, 1.0, 1.0, -1.0])
    w, z, nll = logistic_stats(m, y, block=8)
    assert np.isfinite(np.asarray(w)).all()
    assert np.isfinite(np.asarray(z)).all()
    assert np.isfinite(float(nll))


@pytest.mark.parametrize("shape,blocks", [
    ((1, 256, 2, 64), (128, 128)),
    ((2, 512, 4, 32), (128, 64)),
    ((1, 128, 1, 128), (64, 128)),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(shape, blocks, causal):
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    b, s, h, d = shape
    bq, bk = blocks
    key = jax.random.key(b * s + d)
    q = jax.random.normal(key, shape)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape)
    o1 = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    o2 = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(o1, o2, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    from repro.kernels.ops import flash_attention
    from repro.kernels.ref import flash_attention_ref

    key = jax.random.key(11)
    shape = (1, 256, 2, 64)
    q = jax.random.normal(key, shape, dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape, dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape, dtype)
    o1 = flash_attention(q, k, v, block_q=128, block_k=128)
    o2 = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(o1, np.float32), np.asarray(o2, np.float32),
        atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)
