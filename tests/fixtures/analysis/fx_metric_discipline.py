"""Golden fixture: trips metric-discipline on both clock reads of the
timing pair — but only when parsed under a synthetic ``src/repro/`` path
(the rule is layer-scoped; see
``test_metric_discipline_fixture_under_synthetic_src_path``). Where this
file actually lives it must stay inert.

The adapter class below must NOT trip: incrementing a legacy stats dict
inside a class that defines ``register_metrics`` is the sanctioned
mirror-don't-rewrite shape.
"""
import time


def timed_step(fn):
    # VIOLATION: raw wall clock outside repro.obs — this measurement is
    # invisible to trace summaries and the report CLI
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


class LegacyAdapter:
    """Legacy counter dict mirrored read-only onto the obs registry."""

    def __init__(self):
        self._stats = {"handled": 0}

    def handle(self):
        self._stats["handled"] += 1      # exempt: adapter class below

    def register_metrics(self, registry=None):
        pass
