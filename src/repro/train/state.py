"""Training state container + constructors."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import init_params
from repro.optim import make_optimizer


def make_train_state(key, cfg: ModelConfig):
    params = init_params(key, cfg)
    opt = make_optimizer(cfg.optimizer)
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def train_state_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the full train state (no allocation)."""
    return jax.eval_shape(lambda k: make_train_state(k, cfg), jax.random.key(0))
