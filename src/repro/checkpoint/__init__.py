from repro.checkpoint.checkpointer import load_pytree, save_pytree  # noqa: F401
