"""End-to-end driver (the paper's kind): distributed d-GLMNET vs distributed
online learning via truncated gradient, full regularization path, on a mesh
of 8 simulated devices (2 data x 4 model). The same code lowers on the
production 16x16 mesh (see repro/launch/dryrun.py).

Each distributed solve is one jitted while_loop on the mesh
(core/engine.py) — no per-iteration host sync. The closing section runs
the *distributed screened path* (strong rule + KKT post-check around
fit_distributed / fit_distributed_sparse): the active-set gather reshards
the feature axis into a capacity-bucketed P(model) layout, and in the
sparse flavor the screen streams by-feature (row_idx, values) slabs so no
dense (n, p) X ever exists — the paper's webspam regime.

    python examples/regpath_distributed.py      # sets XLA flags itself
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    )

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import GLMConfig  # noqa: E402
from repro.core import DGLMNETOptions, TGOptions, lambda_max  # noqa: E402
from repro.core.distributed import fit_distributed  # noqa: E402
from repro.core.truncated_gradient import truncated_gradient_fit  # noqa: E402
from repro.data.synthetic import make_glm_dataset  # noqa: E402
from repro.launch.mesh import make_dev_mesh  # noqa: E402
from repro.train.metrics import auprc  # noqa: E402


def main():
    cfg = GLMConfig(name="dist", num_examples=16384, num_features=1024,
                    density=0.2)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    X, y = ds.X_train, ds.y_train
    n_trim = (X.shape[0] // 2) * 2
    X, y = X[:n_trim], y[:n_trim]
    lmax = float(lambda_max(X, y))
    mesh = make_dev_mesh(2, 4)
    print(f"mesh={dict(mesh.shape)}  n={X.shape[0]}  p={X.shape[1]}")

    print("\n-- d-GLMNET path (feature-sharded over `model`, examples over `data`)")
    beta = None
    best_d = 0.0
    for i in range(1, 9):
        lam = lmax * 2.0 ** (-i)
        res = fit_distributed(
            X, y, lam, mesh, beta0=beta,
            opts=DGLMNETOptions(tile=64, max_iters=40))
        beta = res.beta
        ap = auprc(ds.X_test @ beta[: ds.X_test.shape[1]], ds.y_test)
        best_d = max(best_d, ap)
        nnz = int((jnp.abs(beta) > 0).sum())
        print(f"  lambda={lam:9.3f} nnz={nnz:5d} f={res.f:12.2f} "
              f"iters={res.n_iters:3d} AUPRC={ap:.4f}")

    print("\n-- truncated-gradient baseline (example-sharded, averaged)")
    best_tg = 0.0
    for lr in (0.1, 0.5):
        snaps = truncated_gradient_fit(
            X, y, lmax / 64,
            opts=TGOptions(num_machines=8, passes=6, learning_rate=lr),
            key=jax.random.key(1))
        for pass_idx, b in snaps:
            ap = auprc(ds.X_test @ b, ds.y_test)
            best_tg = max(best_tg, ap)
        print(f"  lr={lr}: best-so-far AUPRC={best_tg:.4f}")

    print(f"\nd-GLMNET best {best_d:.4f} vs TG best {best_tg:.4f} "
          f"-> {'d-GLMNET wins' if best_d >= best_tg else 'TG wins'} "
          f"(paper Figure 1 conclusion)")

    print("\n-- distributed screened path (strong rule + KKT around "
          "fit_distributed)")
    import time

    from repro.core import regularization_path_distributed

    opts = DGLMNETOptions(tile=64, max_iters=40)
    t0 = time.perf_counter()
    pts = regularization_path_distributed(X, y, mesh, path_len=8, opts=opts)
    dt = time.perf_counter() - t0
    for pt in pts:
        print(f"  lambda={pt.lam:9.3f} nnz={pt.nnz:5d} "
              f"active={pt.screen['active']:5d}/{X.shape[1]} "
              f"kkt_rounds={pt.screen['kkt_rounds']}")
    print(f"  path wall-clock {dt:.2f}s (restricted solves stay on the "
          f"mesh, one compiled while_loop per capacity bucket)")

    print("\n-- same path over by-feature sparse slabs (no dense X anywhere)")
    from repro.data.byfeature import to_by_feature, to_slabs

    dp = 2  # data extent of the dev mesh
    row_idx, values, n_loc = to_slabs(to_by_feature(X), dp)
    t0 = time.perf_counter()
    pts_sp = regularization_path_distributed(
        (row_idx, values), y, mesh, path_len=8, opts=opts)
    dt = time.perf_counter() - t0
    for pt, pt_sp in zip(pts, pts_sp):
        drift = abs(pt_sp.f - pt.f) / max(abs(pt.f), 1e-9)
        print(f"  lambda={pt_sp.lam:9.3f} nnz={pt_sp.nnz:5d} "
              f"active={pt_sp.screen['active']:5d} |f-f_dense|/|f|={drift:.2e}")
    print(f"  sparse path wall-clock {dt:.2f}s "
          f"(screen streams (row_idx, values) slabs, psum over data axes)")


if __name__ == "__main__":
    main()
