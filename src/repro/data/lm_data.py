"""Synthetic LM token pipeline: Zipf-distributed corpora with enough
structure (Markov bigram mixing) that loss visibly decreases during the
end-to-end training examples; packing + host-sharded batch iterator.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.train.train_step import IGNORE


def zipf_corpus(
    rng: np.random.Generator, vocab: int, length: int, *, alpha: float = 1.1,
    bigram_coherence: float = 0.6,
) -> np.ndarray:
    """Tokens with Zipf marginals and a deterministic bigram component:
    with prob `bigram_coherence`, next = (prev * 31 + 7) % vocab — learnable
    structure for loss-decrease assertions."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks**alpha
    probs /= probs.sum()
    iid = rng.choice(vocab, size=length, p=probs)
    out = iid.copy()
    coh = rng.random(length) < bigram_coherence
    for t in range(1, length):
        if coh[t]:
            out[t] = (out[t - 1] * 31 + 7) % vocab
    return out.astype(np.int32)


def batches(
    corpus: np.ndarray,
    batch: int,
    seq_len: int,
    *,
    cfg: Optional[ModelConfig] = None,
    rng: Optional[np.random.Generator] = None,
) -> Iterator[Dict[str, jnp.ndarray]]:
    """Yields {"tokens", "labels"} (+frontend embeds for vlm/audio archs)."""
    rng = rng or np.random.default_rng(0)
    n_tok = batch * (seq_len + 1)
    frontend = cfg.frontend if cfg is not None else None
    while True:
        starts = rng.integers(0, len(corpus) - n_tok - 1)
        window = corpus[starts : starts + n_tok].reshape(batch, seq_len + 1)
        tokens = jnp.asarray(window[:, :-1])
        labels = jnp.asarray(window[:, 1:].astype(np.int32))
        out = {"tokens": tokens, "labels": labels}
        if frontend is not None and frontend.kind != "none" and not cfg.encdec.enabled:
            p = frontend.tokens_per_item
            key = "patch_embeds" if frontend.kind == "vision_patches" else "frame_embeds"
            out[key] = jnp.asarray(
                rng.standard_normal((batch, p, frontend.embed_dim)), jnp.float32
            )
            out["labels"] = jnp.concatenate(
                [jnp.full((batch, p), IGNORE, jnp.int32), labels], axis=1
            )
        if cfg is not None and cfg.encdec.enabled:
            out["frame_embeds"] = jnp.asarray(
                rng.standard_normal((batch, 32, cfg.frontend.embed_dim)), jnp.float32
            )
        yield out
