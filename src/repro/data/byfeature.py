"""The paper's "by feature" data layout (§3, Table 1).

d-GLMNET partitions the dataset by features: machine m stores
X_m = {L_j | j in S_m}, L_j = {(i, x_ij) | x_ij != 0}. The paper produces
this with a Map/Reduce pass; here the layout transformation is an explicit,
tested function pair:

* ``to_by_feature`` — CSC-like padded arrays (row_idx (p, K), values (p, K)),
  K = max nnz per feature, sentinel row = n. JAX-friendly fixed shapes; this
  is what lets webspam-scale (16.6M features, 1.2e9 nnz) fit on the mesh
  where a dense X cannot (DESIGN.md §2.3).
* ``densify_tile`` — scatter a tile of features back to a dense (n, F)
  block. The solver hot path no longer uses it (the sparse-native kernel
  suite in ``kernels/sparse_slab.py`` computes tile statistics straight
  from the slabs); it remains the oracle/interop utility.
* text round-trip of the paper's Table-1 line format for interop:
  ``feature_id (example_id:value) (example_id:value) ...``
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, TextIO, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ByFeature:
    row_idx: jnp.ndarray     # (p, K) int32, sentinel = n for padding
    values: jnp.ndarray      # (p, K) float32
    n: int                   # number of examples

    @property
    def p(self) -> int:
        return self.row_idx.shape[0]

    @property
    def nnz(self) -> int:
        return int((self.row_idx < self.n).sum())

    def gather(self, beta, mask, cap: int):
        """Screened working set as a restricted ByFeature (see
        :func:`gather_features`). Returns ``(bf_sub, beta_sub, idx)``."""
        r, v, b, idx = gather_features(
            self.row_idx, self.values, beta, mask, cap, sentinel=self.n
        )
        return ByFeature(r, v, self.n), b, idx


def to_by_feature(X) -> ByFeature:
    """Dense (n, p) -> by-feature padded CSC (the Reduce step of paper §3)."""
    Xn = np.asarray(X)
    n, p = Xn.shape
    cols = [np.nonzero(Xn[:, j])[0] for j in range(p)]
    k = max((len(c) for c in cols), default=1) or 1
    row_idx = np.full((p, k), n, np.int32)
    values = np.zeros((p, k), np.float32)
    for j, c in enumerate(cols):
        row_idx[j, : len(c)] = c
        values[j, : len(c)] = Xn[c, j]
    return ByFeature(jnp.asarray(row_idx), jnp.asarray(values), n)


def densify_tile(bf: ByFeature, start: int, width: int) -> jnp.ndarray:
    """Features [start, start+width) -> dense (n, width) block via scatter."""
    rows = jax.lax.dynamic_slice(bf.row_idx, (start, 0), (width, bf.row_idx.shape[1]))
    vals = jax.lax.dynamic_slice(bf.values, (start, 0), (width, bf.values.shape[1]))
    out = jnp.zeros((bf.n + 1, width), jnp.float32)  # +1 row swallows sentinels
    cols = jnp.broadcast_to(jnp.arange(width)[:, None], rows.shape)
    out = out.at[rows.reshape(-1), cols.reshape(-1)].add(vals.reshape(-1))
    return out[: bf.n]


def densify(bf: ByFeature) -> jnp.ndarray:
    return densify_tile(bf, 0, bf.p)


# ---------------------------------------------------------------------------
# Table-1 text format
# ---------------------------------------------------------------------------

def write_table1(bf: ByFeature, fh: TextIO) -> None:
    ri = np.asarray(bf.row_idx)
    vv = np.asarray(bf.values)
    for j in range(bf.p):
        live = ri[j] < bf.n
        cells = " ".join(f"({int(i)}:{float(v):.9g})" for i, v in zip(ri[j][live], vv[j][live]))
        fh.write(f"{j} {cells}\n".rstrip() + "\n")


def read_table1(fh: TextIO, n: int) -> ByFeature:
    """Parse the Table-1 format honoring the leading feature id.

    Lines may arrive in any order (a Map/Reduce shuffle gives no ordering
    guarantee); the feature id — not the line position — decides where a
    feature lands. Ids absent from the file become empty (all-sentinel)
    features; a repeated id keeps the last occurrence.
    """
    feats = {}
    for line in fh:
        parts = line.split()
        if not parts:
            continue
        j = int(parts[0])
        entries = [p.strip("()").split(":") for p in parts[1:]]
        feats[j] = ([int(i) for i, _ in entries], [float(v) for _, v in entries])
    p = max(feats) + 1 if feats else 0
    k = max((len(r) for r, _ in feats.values()), default=1) or 1
    row_idx = np.full((p, k), n, np.int32)
    values = np.zeros((p, k), np.float32)
    for j, (r, v) in feats.items():
        row_idx[j, : len(r)] = r
        values[j, : len(v)] = v
    return ByFeature(jnp.asarray(row_idx), jnp.asarray(values), n)


def partition_features(p: int, num_machines: int) -> Tuple[np.ndarray, ...]:
    """Contiguous feature blocks S_1..S_M (paper's Reduce-side partitioning)."""
    bounds = np.linspace(0, p, num_machines + 1).astype(int)
    return tuple(np.arange(bounds[i], bounds[i + 1]) for i in range(num_machines))


# ---------------------------------------------------------------------------
# Mesh slabs: the (p, DP, K) layout the distributed sparse step consumes
# ---------------------------------------------------------------------------

@dataclass
class SlabBuckets:
    """nnz-bucketed mesh slabs (the ROADMAP "slab rebalancing" layout).

    Power-law feature frequencies (webspam) make a single global slab
    capacity pad every feature to the heaviest one's nnz; here features
    are grouped into capacity classes — ``buckets[i] = (row_idx
    (p_i, DP, K_i), values, feat_idx (p_i,))`` with per-bucket ``K_i`` on
    a power-of-two ladder — so storage is O(sum_i p_i K_i) ~ O(nnz)
    instead of O(p K_max). ``feat_idx`` maps each bucket row back to the
    original feature id; the concatenated bucket order is the *permuted*
    feature axis the screened distributed path works in.

    Invariant: every slab's K axis must be *front-packed* — live slots
    first, sentinels after. ``to_slab_buckets`` guarantees this;
    hand-built instances must too, because consumers trim the K axis
    positionally (``gather_features(..., k_cap)``) and interleaved
    sentinels would silently drop live entries.
    """
    buckets: tuple                 # of (row_idx, values, feat_idx)
    n_loc: int
    p: int                         # original feature count

    @property
    def k_classes(self):
        return tuple(b[0].shape[-1] for b in self.buckets)

    @property
    def feat_order(self) -> np.ndarray:
        """Original feature ids in concatenated bucket order."""
        return np.concatenate([np.asarray(b[2]) for b in self.buckets])

    @property
    def bucket_nbytes(self) -> Tuple[int, ...]:
        """Per-bucket slab payload bytes (row_idx + values; the host-side
        ``feat_idx`` maps are excluded). This is what the residency budget
        (``repro.data.residency``) and any future heavy-feature split
        account in."""
        return tuple(int(r.nbytes) + int(v.nbytes) for r, v, _ in self.buckets)

    @property
    def nbytes(self) -> int:
        """Total slab payload bytes across buckets (sum of
        :attr:`bucket_nbytes`)."""
        return sum(self.bucket_nbytes)


def _regroup_slabs(bf: ByFeature, dp: int):
    """Shared regroup: global rows -> per-shard local rows + per-(feature,
    shard) nnz counts. Fully vectorized (p can be webspam-scale): flatten
    the live entries, key them by (feature, shard), and compute each
    entry's rank within its group from the stable sort of the keys."""
    n_loc = bf.n // dp
    ri = np.asarray(bf.row_idx)
    vv = np.asarray(bf.values)
    p = bf.p
    j_idx, k_idx = np.nonzero(ri < bf.n)
    rows = ri[j_idx, k_idx]
    vals = vv[j_idx, k_idx]
    shard = rows // max(n_loc, 1)
    group = j_idx * dp + shard
    counts = np.bincount(group, minlength=p * dp)
    order = np.argsort(group, kind="stable")
    group_sorted = group[order]
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    rank = np.arange(len(group_sorted)) - starts[group_sorted]
    jj, ss = group_sorted // dp, group_sorted % dp
    loc_rows = (rows - shard * n_loc)[order]
    loc_vals = vals[order]
    return (jj, ss, rank, loc_rows, loc_vals,
            counts.reshape(p, dp), n_loc)


def to_slabs(bf: ByFeature, dp: int):
    """Re-key a by-feature layout for ``dp`` data shards.

    Examples are split into ``dp`` contiguous shards of n_loc = n/dp rows
    each; every feature's entries are regrouped per shard with *local* row
    indices (sentinel n_loc). Returns ``(row_idx (p, dp, K'), values
    (p, dp, K'), n_loc)`` — exactly the operands of
    ``core.distributed.make_dglmnet_step_sparse`` / ``fit_distributed_sparse``
    under sharding P(model, data, None). Entries are front-packed along K
    (live slots first, then sentinels), which is what lets downstream
    consumers trim the K axis to a smaller capacity class.
    """
    if bf.n % dp:
        raise ValueError(
            f"data shard count {dp} must divide n={bf.n} (trim or pad upstream)"
        )
    jj, ss, rank, loc_rows, loc_vals, counts, n_loc = _regroup_slabs(bf, dp)
    p = bf.p
    k = max(1, int(counts.max()) if counts.size else 1)
    row_idx = np.full((p, dp, k), n_loc, np.int32)
    values = np.zeros((p, dp, k), np.float32)
    row_idx[jj, ss, rank] = loc_rows
    values[jj, ss, rank] = loc_vals
    return jnp.asarray(row_idx), jnp.asarray(values), n_loc


def k_class(k_need: int, k_max: int, *, k_min: int = 8) -> int:
    """Round a slab capacity up to its power-of-two class (min ``k_min``,
    capped at ``k_max``). Bounds the number of distinct slab shapes — and
    hence solver retraces — to O(log(K_max)); the feature-axis twin is
    ``core.screening.capacity_bucket``."""
    cap = max(k_min, 1)
    while cap < min(k_need, k_max):
        cap *= 2
    return min(cap, max(k_max, 1))


def to_slab_buckets(bf: ByFeature, dp: int, *, k_min: int = 8) -> SlabBuckets:
    """``to_slabs`` with nnz-bucketed capacities (multiple K classes).

    Features are grouped by their per-shard max nnz into power-of-two
    capacity classes; each class stores its own (p_i, dp, K_i) slab pair
    padded only to K_i. Heavy (power-law head) features no longer inflate
    every slab to the global max: storage drops from O(p K_max) to
    ~O(nnz), and the screened path solves each restricted problem at the
    smallest class that holds its active features. The returned layout
    carries its own byte accounting (:attr:`SlabBuckets.bucket_nbytes` /
    :attr:`SlabBuckets.nbytes`) — the inputs to the device-residency
    budget (``repro.data.residency``).
    """
    if bf.n % dp:
        raise ValueError(
            f"data shard count {dp} must divide n={bf.n} (trim or pad upstream)"
        )
    jj, ss, rank, loc_rows, loc_vals, counts, n_loc = _regroup_slabs(bf, dp)
    p = bf.p
    k_feat = counts.max(axis=1) if p else np.zeros(0, np.int64)  # (p,)
    k_max = max(1, int(k_feat.max()) if p else 1)
    classes = sorted({k_class(int(k), k_max, k_min=k_min) for k in k_feat})
    if not classes:
        classes = [k_class(1, 1, k_min=k_min)]
    # assign every feature the smallest class that holds it
    feat_class = np.searchsorted(np.asarray(classes), k_feat)
    buckets = []
    pos_of_feat = np.zeros(p, np.int64)
    for ci, kc in enumerate(classes):
        feats = np.flatnonzero(feat_class == ci)
        if feats.size == 0:
            continue
        pos_of_feat[feats] = np.arange(feats.size)
        row_idx = np.full((feats.size, dp, kc), n_loc, np.int32)
        values = np.zeros((feats.size, dp, kc), np.float32)
        sel = feat_class[jj] == ci
        row_idx[pos_of_feat[jj[sel]], ss[sel], rank[sel]] = loc_rows[sel]
        values[pos_of_feat[jj[sel]], ss[sel], rank[sel]] = loc_vals[sel]
        buckets.append((jnp.asarray(row_idx), jnp.asarray(values),
                        feats.astype(np.int64)))
    return SlabBuckets(buckets=tuple(buckets), n_loc=n_loc, p=p)


def _trim_k(arr, k_cap: int, fill):
    """Slice (or pad) the trailing slab-capacity axis to ``k_cap``. Safe
    because slab entries are front-packed (live slots first)."""
    k = arr.shape[-1]
    if k_cap >= k:
        pad = [(0, 0)] * (arr.ndim - 1) + [(0, k_cap - k)]
        return jnp.pad(arr, pad, constant_values=fill) if k_cap > k else arr
    return jax.lax.slice_in_dim(arr, 0, k_cap, axis=arr.ndim - 1)


def gather_features(row_idx, values, beta, mask, cap: int, *, sentinel: int,
                    k_cap: Optional[int] = None):
    """Feature-axis gather of the screened working set into slab form.

    ``row_idx``/``values`` are feature-major — ``(p, K)`` (single ByFeature)
    or ``(p, DP, K)`` (mesh slabs); selection happens on axis 0 only, so the
    restricted problem stays in slab form end-to-end (no densification).
    Returns ``(row_idx_sub, values_sub, beta_sub, idx)`` with ``idx`` of
    shape ``(cap,)`` carrying sentinel ``p`` for padding; padded features are
    all-sentinel/zero slabs, so their coordinates provably stay at zero and
    the restricted solve equals the masked full solve. On a mesh this gather
    *is* the active-set reshard: the working set's slabs land back in a
    capacity-bucketed P(model) layout.

    ``k_cap`` additionally trims the slab-capacity axis to the active
    set's own class (front-packed entries make the slice exact): a solve
    whose working set holds only light features stops paying the heavy
    (power-law head) features' global K — the second half of the ROADMAP
    slab-rebalancing item, and what drops restricted solves into the
    sparse-native kernel regime.
    """
    from repro.core.screening import pack_indices

    idx = pack_indices(mask, cap)
    row_idx_sub = jnp.take(row_idx, idx, axis=0, mode="fill",
                           fill_value=sentinel)
    values_sub = jnp.take(values, idx, axis=0, mode="fill", fill_value=0.0)
    beta_sub = jnp.take(beta, idx, mode="fill", fill_value=0.0)
    if k_cap is not None:
        row_idx_sub = _trim_k(row_idx_sub, k_cap, sentinel)
        values_sub = _trim_k(values_sub, k_cap, 0.0)
    return row_idx_sub, values_sub, beta_sub, idx


def take_buckets_iter(buckets, n_loc: int, idx, k_cap: int):
    """Core of :func:`take_features_buckets` over *any* iterable of
    ``(row_idx, values, ...)`` buckets.

    Resident tuples and the streamed iteration of
    :class:`repro.data.residency.BucketResidencyManager` feed the exact
    same op sequence through here — same bucket order, same
    take/trim/where-combine — which is what keeps streamed gathers
    bit-identical to resident ones.
    """
    rows_sub = vals_sub = None
    off = 0
    for bucket in buckets:
        r_b, v_b = bucket[0], bucket[1]
        p_b = r_b.shape[0]
        ok = jnp.logical_and(idx >= off, idx < off + p_b)
        li = jnp.where(ok, idx - off, p_b)
        rb = jnp.take(r_b, li, axis=0, mode="fill", fill_value=n_loc)
        vb = jnp.take(v_b, li, axis=0, mode="fill", fill_value=0.0)
        rb = _trim_k(rb, k_cap, n_loc)
        vb = _trim_k(vb, k_cap, 0.0)
        if rows_sub is None:
            rows_sub, vals_sub = rb, vb
        else:
            sel = ok[:, None, None]
            rows_sub = jnp.where(sel, rb, rows_sub)
            vals_sub = jnp.where(sel, vb, vals_sub)
        off += p_b
    return rows_sub, vals_sub


def take_features_buckets(slabs: "SlabBuckets", idx, k_cap: int):
    """Explicit-index feature take over an nnz-bucketed layout.

    ``idx`` holds concatenated-bucket-axis positions (sentinel >= the
    concatenated extent marks padding). Each bucket is taken with the
    indices remapped into its own range (out-of-range -> all-sentinel
    fill) and trimmed/padded to ``k_cap``; since every index lands in
    exactly one bucket, a where-combine assembles a single
    (len(idx), DP, k_cap) slab pair.
    """
    return take_buckets_iter(slabs.buckets, slabs.n_loc, idx, k_cap)


def gather_features_buckets(slabs: "SlabBuckets", beta, mask, cap: int,
                            k_cap: int):
    """:func:`gather_features` over an nnz-bucketed layout.

    ``mask``/``beta`` live on the concatenated (bucket-permuted, padded)
    feature axis. The packed working-set indices are taken bucket-by-bucket
    (:func:`take_features_buckets`) into the single restricted (cap, DP,
    k_cap) slab pair the solver consumes.
    """
    from repro.core.screening import pack_indices

    idx = pack_indices(mask, cap)
    beta_sub = jnp.take(beta, idx, mode="fill", fill_value=0.0)
    rows_sub, vals_sub = take_features_buckets(slabs, idx, k_cap)
    return rows_sub, vals_sub, beta_sub, idx


def scatter_features(beta_sub, idx, p: int):
    """Inverse of :func:`gather_features`: restricted solution -> full beta.
    The coefficient scatter is layout-agnostic, so this is exactly the dense
    column scatter."""
    from repro.core.screening import scatter_columns

    return scatter_columns(beta_sub, idx, p)
