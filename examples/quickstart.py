"""Quickstart: L1-regularized logistic regression through the one front
door (``repro.api.LogisticL1`` over a ``Design``).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import DenseDesign, LogisticL1, SlabDesign, lambda_max_design
from repro.configs.base import GLMConfig
from repro.core import DGLMNETOptions
from repro.data.synthetic import make_glm_dataset
from repro.train.metrics import glm_eval_fn


def main():
    cfg = GLMConfig(name="quickstart", num_examples=8192, num_features=256,
                    density=1.0)
    ds = make_glm_dataset(cfg, jax.random.key(0))
    design = DenseDesign(ds.X_train)
    y = ds.y_train
    lmax = float(lambda_max_design(design, y))
    n, p = design.shape
    print(f"n={n}  p={p}  lambda_max={lmax:.2f}")

    # single solve, simulating 8 machines (feature blocks)
    est = LogisticL1(opts=DGLMNETOptions(num_blocks=8, method="gram", tile=32))
    res = est.fit(design, y, lmax / 64, verbose=True)
    print(f"\nfit: f={res.f:.4f}  nnz={res.nnz}/{p}  "
          f"iters={res.n_iters}  unit-step={res.unit_step_frac:.0%}")

    # the same solve from the by-feature slab layout — one front door,
    # any Design; the strategy resolver picks the execution
    res_slab = est.fit(SlabDesign.from_dense(ds.X_train), y, lmax / 64)
    print(f"slab layout: f={res_slab.f:.4f} (same solve, different Design)")

    # regularization path (paper Algorithm 5) with test metrics
    print("\nregularization path:")
    est = LogisticL1(opts=DGLMNETOptions(num_blocks=8, tile=32))
    pts = est.path(design, y, path_len=8,
                   eval_fn=glm_eval_fn(ds.X_test, ds.y_test), verbose=True)
    best = max(pts, key=lambda pt: pt.metrics["auprc"])
    print(f"\nbest: lambda={best.lam:.3f} nnz={best.nnz} "
          f"AUPRC={best.metrics['auprc']:.4f}")

    # score through the estimator (margins via the Design)
    proba = est.predict_proba(DenseDesign(ds.X_test), beta=best.beta)
    print(f"test P(y=+1) range: [{float(proba.min()):.3f}, "
          f"{float(proba.max()):.3f}]")


if __name__ == "__main__":
    main()
